# Ensures python/ (this directory) is on sys.path so `compile.*` imports
# resolve when pytest is invoked from anywhere in the repo.
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
