"""L2 model-level tests: spd_solve, local_sgd_epoch, als_solve, kmeans.

These validate the graphs that actually get AOT-lowered, including the
pure-HLO Cholesky solve that replaces LAPACK custom-calls.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


class TestSpdSolve:
    def _spd(self, seed, b, k):
        a = jax.random.normal(jax.random.PRNGKey(seed), (b, k, k), dtype=jnp.float32)
        return jnp.einsum("bij,bkj->bik", a, a) + 0.1 * jnp.eye(k)[None]

    def test_matches_linalg_solve(self):
        a = self._spd(0, 4, 10)
        b = jax.random.normal(jax.random.PRNGKey(1), (4, 10), dtype=jnp.float32)
        got = model.spd_solve(a, b)
        want = jnp.linalg.solve(a, b[..., None])[..., 0]
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_identity(self):
        eye = jnp.eye(6, dtype=jnp.float32)[None].repeat(3, 0)
        b = jax.random.normal(jax.random.PRNGKey(2), (3, 6), dtype=jnp.float32)
        np.testing.assert_allclose(model.spd_solve(eye, b), b, rtol=1e-6)

    def test_residual_small(self):
        a = self._spd(3, 8, 16)
        b = jax.random.normal(jax.random.PRNGKey(4), (8, 16), dtype=jnp.float32)
        x = model.spd_solve(a, b)
        resid = jnp.einsum("bij,bj->bi", a, x) - b
        assert float(jnp.max(jnp.abs(resid))) < 1e-2

    def test_unbatched(self):
        a = self._spd(5, 1, 4)[0]
        b = jnp.ones((4,), dtype=jnp.float32)
        x = model.spd_solve(a, b)
        np.testing.assert_allclose(a @ x, b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(k=st.integers(1, 20), b=st.integers(1, 6), seed=st.integers(0, 2**30))
    def test_solve_sweep(self, k, b, seed):
        a = self._spd(seed, b, k)
        rhs = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, k), dtype=jnp.float32)
        x = model.spd_solve(a, rhs)
        resid = jnp.einsum("bij,bj->bi", a, x) - rhs
        scale = float(jnp.max(jnp.abs(rhs))) + 1.0
        assert float(jnp.max(jnp.abs(resid))) < 1e-2 * scale


class TestLocalSgdEpoch:
    def _data(self, seed, n, d):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(k1, (n, d), dtype=jnp.float32)
        y = (jax.random.uniform(k2, (n,)) > 0.5).astype(jnp.float32)
        w = 0.1 * jax.random.normal(k3, (d,), dtype=jnp.float32)
        return x, y, w

    def test_matches_sequential_oracle(self):
        x, y, w0 = self._data(0, 128, 16)
        got = model.local_sgd_epoch(x, y, w0, jnp.float32(0.05), block_n=32)
        want = ref.local_sgd_epoch_ref(x, y, w0, 0.05, 32)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_zero_lr_identity(self):
        x, y, w0 = self._data(1, 64, 8)
        got = model.local_sgd_epoch(x, y, w0, jnp.float32(0.0), block_n=32)
        np.testing.assert_allclose(got, w0, rtol=1e-6)

    def test_decreases_loss(self):
        x, y, w0 = self._data(2, 256, 8)
        # learnable labels: plant a weight vector
        w_true = jnp.ones((8,), dtype=jnp.float32)
        y = (x @ w_true > 0).astype(jnp.float32)
        w1 = model.local_sgd_epoch(x, y, w0, jnp.float32(0.02), block_n=64)
        l0 = ref.logreg_loss_ref(x, y, w0)
        l1 = ref.logreg_loss_ref(x, y, w1)
        assert float(l1) < float(l0)

    def test_grad_batch_outputs(self):
        # n must be a multiple of the kernel's DEFAULT_BLOCK_N (256)
        x, y, w = self._data(3, 256, 16)
        g, l = model.logreg_grad_batch(x, y, w)
        assert g.shape == (16,) and l.shape == (1,)
        np.testing.assert_allclose(g, ref.logreg_grad_ref(x, y, w), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(l[0], ref.logreg_loss_ref(x, y, w), rtol=1e-4)


class TestAlsSolveBatch:
    def _mk(self, seed, u, m, k, frac=0.6):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        mask = (jax.random.uniform(k3, (u, m)) < frac).astype(jnp.float32)
        f = jax.random.normal(k1, (u, m, k), dtype=jnp.float32) * mask[..., None]
        r = jax.random.normal(k2, (u, m), dtype=jnp.float32) * mask
        return f, r, mask

    def test_matches_ref_solver(self):
        f, r, mask = self._mk(0, 16, 32, 8)
        got = model.als_solve_batch(f, r, mask, jnp.float32(0.01))
        want = ref.als_solve_ref(f, r, mask, 0.01)
        np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)

    def test_normal_equation_residual(self):
        f, r, mask = self._mk(1, 8, 16, 4)
        lam = 0.01
        x = model.als_solve_batch(f, r, mask, jnp.float32(lam))
        grams, rhs = ref.als_gram_ref(f, r, mask)
        a = grams + lam * jnp.eye(4)[None]
        resid = jnp.einsum("uij,uj->ui", a, x) - rhs
        assert float(jnp.max(jnp.abs(resid))) < 1e-3

    def test_cold_user_near_zero(self):
        f, r, _ = self._mk(2, 8, 16, 4)
        mask = jnp.zeros((8, 16), dtype=jnp.float32)
        x = model.als_solve_batch(f * 0, r * 0, mask, jnp.float32(0.01))
        np.testing.assert_allclose(x, 0.0, atol=1e-5)

    def test_rmse_batch(self):
        f, r, mask = self._mk(3, 8, 16, 4)
        rows = jax.random.normal(jax.random.PRNGKey(9), (8, 4), dtype=jnp.float32)
        sse, cnt = model.als_rmse_batch(f, r, mask, rows)
        pred = jnp.einsum("umk,uk->um", f, rows)
        want = jnp.sum(((pred - r) * mask) ** 2)
        np.testing.assert_allclose(sse[0], want, rtol=1e-4)
        np.testing.assert_allclose(cnt[0], jnp.sum(mask), rtol=1e-6)

    def test_als_iteration_decreases_objective(self):
        # alternate U and V updates on a small planted low-rank problem and
        # check the regularized objective (paper Eq. 2) is monotone.
        rng = np.random.default_rng(0)
        m, n, k = 24, 16, 4
        u_true = rng.normal(size=(m, k)).astype(np.float32)
        v_true = rng.normal(size=(n, k)).astype(np.float32)
        mask_np = (rng.random((m, n)) < 0.7).astype(np.float32)
        ratings = (u_true @ v_true.T) * mask_np
        lam = 0.01

        u = rng.normal(size=(m, k)).astype(np.float32) * 0.1
        v = rng.normal(size=(n, k)).astype(np.float32) * 0.1

        def objective(u, v):
            resid = (u @ v.T - ratings) * mask_np
            return (
                float(np.sum(resid**2))
                + lam * (float(np.sum(u**2)) + float(np.sum(v**2)))
            )

        objs = [objective(u, v)]
        for _ in range(3):
            # update U: for each user, gather v rows
            fu = np.broadcast_to(v[None], (m, n, k)) * mask_np[..., None]
            u = np.asarray(
                model.als_solve_batch(
                    jnp.asarray(fu), jnp.asarray(ratings), jnp.asarray(mask_np), jnp.float32(lam)
                )
            )
            fv = np.broadcast_to(u[None], (n, m, k)) * mask_np.T[..., None]
            v = np.asarray(
                model.als_solve_batch(
                    jnp.asarray(fv), jnp.asarray(ratings.T), jnp.asarray(mask_np.T), jnp.float32(lam)
                )
            )
            objs.append(objective(u, v))
        assert objs[-1] < objs[0]
        assert all(objs[i + 1] <= objs[i] + 1e-3 for i in range(len(objs) - 1))


class TestKmeansStep:
    def test_statistics_correct(self):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        x = jax.random.normal(k1, (64, 8), dtype=jnp.float32)
        c = jax.random.normal(k2, (4, 8), dtype=jnp.float32)
        sums, counts, sse = model.kmeans_step(x, c)
        d2 = ((np.asarray(x)[:, None] - np.asarray(c)[None]) ** 2).sum(-1)
        assign = d2.argmin(1)
        for j in range(4):
            np.testing.assert_allclose(
                sums[j], np.asarray(x)[assign == j].sum(0), rtol=1e-4, atol=1e-4
            )
            assert int(counts[j]) == int((assign == j).sum())
        np.testing.assert_allclose(sse[0], d2.min(1).sum(), rtol=1e-4)

    def test_counts_sum_to_n(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (128, 16), dtype=jnp.float32)
        c = jax.random.normal(jax.random.PRNGKey(2), (8, 16), dtype=jnp.float32)
        _, counts, _ = model.kmeans_step(x, c)
        assert int(jnp.sum(counts)) == 128

    def test_converged_centroids_fixed_point(self):
        # points exactly at centroids -> sums/counts reproduce centroids
        c = jnp.asarray([[0.0, 0.0], [10.0, 10.0]], dtype=jnp.float32)
        x = jnp.concatenate([jnp.tile(c[0], (5, 1)), jnp.tile(c[1], (7, 1))])
        sums, counts, sse = model.kmeans_step(x, c)
        np.testing.assert_allclose(sums / counts[:, None], c, atol=1e-6)
        assert float(sse[0]) < 1e-6
