"""Kernel-vs-oracle correctness: the CORE numeric signal of the repo.

Every Pallas kernel must match its pure-jnp oracle (kernels/ref.py) to
float32 tolerance, across a hypothesis sweep of shapes and data scales.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import als_gram, logreg_grad, ref

jax.config.update("jax_platform_name", "cpu")

RTOL, ATOL = 1e-4, 1e-5


def _rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


class TestLogregGrad:
    def test_matches_ref_basic(self):
        k = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(k, 3)
        x = _rand(k1, 256, 32)
        y = (jax.random.uniform(k2, (256,)) > 0.5).astype(jnp.float32)
        w = _rand(k3, 32)
        got = logreg_grad.logreg_grad(x, y, w, block_n=64)
        want = ref.logreg_grad_ref(x, y, w)
        np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)

    def test_single_block(self):
        k = jax.random.PRNGKey(1)
        k1, k2, k3 = jax.random.split(k, 3)
        x, w = _rand(k1, 64, 16), _rand(k3, 16)
        y = (jax.random.uniform(k2, (64,)) > 0.5).astype(jnp.float32)
        got = logreg_grad.logreg_grad(x, y, w, block_n=64)
        np.testing.assert_allclose(
            got, ref.logreg_grad_ref(x, y, w), rtol=RTOL, atol=ATOL
        )

    def test_zero_weights_gradient_direction(self):
        # at w=0, sigmoid=0.5 so grad = X^T (0.5 - y)
        x = jnp.ones((64, 8), dtype=jnp.float32)
        y = jnp.ones((64,), dtype=jnp.float32)
        w = jnp.zeros((8,), dtype=jnp.float32)
        got = logreg_grad.logreg_grad(x, y, w, block_n=64)
        np.testing.assert_allclose(got, -0.5 * 64 * jnp.ones(8), rtol=RTOL)

    def test_rejects_misaligned_block(self):
        x = jnp.zeros((100, 8), dtype=jnp.float32)
        y = jnp.zeros((100,), dtype=jnp.float32)
        w = jnp.zeros((8,), dtype=jnp.float32)
        with pytest.raises(AssertionError):
            logreg_grad.logreg_grad(x, y, w, block_n=64)

    @settings(max_examples=20, deadline=None)
    @given(
        blocks=st.integers(1, 5),
        bn=st.sampled_from([8, 16, 32]),
        d=st.integers(1, 48),
        scale=st.sampled_from([0.01, 1.0, 10.0]),
        seed=st.integers(0, 2**30),
    )
    def test_matches_ref_sweep(self, blocks, bn, d, scale, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        n = blocks * bn
        x = _rand(k1, n, d, scale=scale)
        y = (jax.random.uniform(k2, (n,)) > 0.5).astype(jnp.float32)
        w = _rand(k3, d, scale=scale)
        got = logreg_grad.logreg_grad(x, y, w, block_n=bn)
        want = ref.logreg_grad_ref(x, y, w)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3 * scale)


class TestLogregLoss:
    def test_matches_ref(self):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
        x = _rand(k1, 128, 16)
        y = (jax.random.uniform(k2, (128,)) > 0.5).astype(jnp.float32)
        w = _rand(k3, 16)
        got = logreg_grad.logreg_loss(x, y, w, block_n=32)
        np.testing.assert_allclose(
            got, ref.logreg_loss_ref(x, y, w), rtol=RTOL, atol=ATOL
        )

    def test_loss_at_zero_weights(self):
        # NLL at w=0 is n*log(2)
        x = _rand(jax.random.PRNGKey(3), 64, 8)
        y = jnp.zeros((64,), dtype=jnp.float32)
        w = jnp.zeros((8,), dtype=jnp.float32)
        got = logreg_grad.logreg_loss(x, y, w, block_n=64)
        np.testing.assert_allclose(got, 64 * np.log(2), rtol=1e-5)

    def test_extreme_margins_finite(self):
        # softplus form must not overflow for large margins
        x = 100.0 * jnp.ones((32, 4), dtype=jnp.float32)
        y = jnp.ones((32,), dtype=jnp.float32)
        w = 10.0 * jnp.ones((4,), dtype=jnp.float32)
        got = logreg_grad.logreg_loss(x, y, w, block_n=32)
        assert np.isfinite(float(got))


class TestAlsGram:
    def _mk(self, seed, u, m, k, frac=0.5):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        f = _rand(k1, u, m, k)
        r = _rand(k2, u, m)
        mask = (jax.random.uniform(k3, (u, m)) < frac).astype(jnp.float32)
        return f * mask[..., None], r * mask, mask

    def test_matches_ref_basic(self):
        f, r, mask = self._mk(0, 16, 32, 8)
        ga, gb = als_gram.als_gram(f, r, mask, block_u=8)
        wa, wb = ref.als_gram_ref(f, r, mask)
        np.testing.assert_allclose(ga, wa, rtol=RTOL, atol=ATOL)
        np.testing.assert_allclose(gb, wb, rtol=RTOL, atol=ATOL)

    def test_gram_symmetry(self):
        f, r, mask = self._mk(1, 8, 16, 4)
        ga, _ = als_gram.als_gram(f, r, mask, block_u=8)
        np.testing.assert_allclose(ga, np.swapaxes(np.asarray(ga), 1, 2), rtol=1e-6)

    def test_gram_psd_diagonal_nonneg(self):
        f, r, mask = self._mk(2, 8, 16, 4)
        ga, _ = als_gram.als_gram(f, r, mask, block_u=8)
        diag = np.diagonal(np.asarray(ga), axis1=1, axis2=2)
        assert (diag >= -1e-6).all()

    def test_empty_user_all_zero(self):
        f, r, _ = self._mk(3, 8, 16, 4)
        mask = jnp.zeros((8, 16), dtype=jnp.float32)
        ga, gb = als_gram.als_gram(f * 0, r * 0, mask, block_u=8)
        np.testing.assert_allclose(ga, 0.0)
        np.testing.assert_allclose(gb, 0.0)

    @settings(max_examples=15, deadline=None)
    @given(
        ub=st.integers(1, 3),
        m=st.sampled_from([8, 24, 40]),
        k=st.integers(2, 12),
        frac=st.floats(0.1, 1.0),
        seed=st.integers(0, 2**30),
    )
    def test_matches_ref_sweep(self, ub, m, k, frac, seed):
        f, r, mask = self._mk(seed, ub * 8, m, k, frac)
        ga, gb = als_gram.als_gram(f, r, mask, block_u=8)
        wa, wb = ref.als_gram_ref(f, r, mask)
        np.testing.assert_allclose(ga, wa, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(gb, wb, rtol=1e-3, atol=1e-3)
