"""AOT path tests: the lowering contract the rust runtime depends on.

Checks that entry points lower to valid HLO *text* (the interchange format
xla_extension 0.5.1 can parse), that outputs are tuples, and that the
manifest records shapes faithfully.
"""

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_smoke():
    lowered = jax.jit(model.logreg_predict).lower(
        aot.spec(8, 4), aot.spec(4)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # return_tuple=True: the root computation returns a tuple
    assert "ROOT" in text
    assert len(text) > 100


def test_no_lapack_custom_calls_in_als():
    # the standalone runtime cannot resolve LAPACK custom-calls; the ALS
    # solve must lower to pure HLO math (model.spd_solve)
    lowered = jax.jit(model.als_solve_batch).lower(
        aot.spec(8, 16, 4), aot.spec(8, 16), aot.spec(8, 16), aot.spec()
    )
    text = aot.to_hlo_text(lowered)
    assert "custom-call" not in text.lower(), "LAPACK custom-call leaked into ALS HLO"


def test_entries_cover_all_entry_points():
    names = {e[0] for e in aot._entries()}
    assert names == {
        "local_sgd_epoch",
        "logreg_grad_batch",
        "logreg_predict",
        "als_solve_batch",
        "als_gram_batch",
        "als_rmse_batch",
        "kmeans_step",
    }


def test_entries_shapes_consistent():
    for entry in aot._entries():
        name, variant, fn, specs = entry[:4]
        aux = entry[4] if len(entry) > 4 else {}
        # every spec is f32
        for s in specs:
            assert s.dtype == jnp.float32, f"{name}/{variant}"
        if name == "local_sgd_epoch":
            n = specs[0].shape[0]
            b = aux.get("block")
            assert b is not None and n % b == 0, f"{variant}: n={n} block={b}"


def test_sgd_epoch_block_semantics():
    # the manifest block is the actual minibatch size: one epoch with
    # block=n equals one full-batch GD step
    k = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(k, 3)
    n, d = 64, 8
    x = jax.random.normal(k1, (n, d), dtype=jnp.float32)
    y = (jax.random.uniform(k2, (n,)) > 0.5).astype(jnp.float32)
    w = 0.1 * jax.random.normal(k3, (d,), dtype=jnp.float32)
    lr = jnp.float32(0.05)
    got = model.local_sgd_epoch(x, y, w, lr, block_n=n)
    from compile.kernels import ref

    want = w - lr * ref.logreg_grad_ref(x, y, w)
    import numpy as np

    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
