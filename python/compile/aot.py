"""AOT compile path: lower every L2 entry point to HLO text artifacts.

Run once by ``make artifacts`` (and never at serve time):

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<entry>__<variant>.hlo.txt`` per (entry point, shape variant)
plus a ``manifest.json`` the rust runtime uses to locate artifacts and
validate argument shapes.

Interchange format is HLO *text*, NOT ``lowered.compile()`` /
``.serialize()``: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md). We lower to stablehlo first and
convert via xla_client so we can force ``return_tuple=True`` - the rust
side then always unwraps a tuple regardless of output arity.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32


def spec(*shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _entries():
    """(name, variant, fn, example_args) for every artifact we ship.

    Shape variants:
      * ``small``  - fast shapes for tests and the quickstart example.
      * ``bench``  - default benchmark shapes (d scaled down from the
        paper's 160K dense features; DESIGN.md §3 substitutions).
      * ``wide``   - a wider-d variant to let benches sweep feature count.
    ALS ranks follow the paper (k=10) plus a small test rank.
    """
    e = []

    # NOTE: entries are (name, variant, fn, specs) or
    # (name, variant, fn, specs, aux) where aux keys are copied into the
    # manifest record (e.g. the SGD minibatch block, which the rust
    # fallback must match for bit-compatible differential tests).
    # -- logistic regression ------------------------------------------------
    for variant, n, d, b in [
        ("small", 256, 64, 64),
        ("bench", 2048, 512, 256),
        ("wide", 1024, 2048, 256),
        # strong-scaling ladder: fixed total data spread over more
        # machines => fewer rows per partition; these variants keep the
        # XLA work proportional to *real* rows instead of padding waste
        ("strong256", 256, 512, 256),
        ("strong512", 512, 512, 256),
        ("strong1024", 1024, 512, 256),
    ]:
        sgd = lambda x, y, w, lr, _b=b: model.local_sgd_epoch(x, y, w, lr, block_n=_b)
        e.append(
            (
                "local_sgd_epoch",
                variant,
                sgd,
                (spec(n, d), spec(n), spec(d), spec()),
                {"block": b},
            )
        )
        grad = lambda x, y, w, _b=b: model.logreg_grad_batch(x, y, w)
        e.append(
            ("logreg_grad_batch", variant, grad, (spec(n, d), spec(n), spec(d)))
        )
        e.append(
            ("logreg_predict", variant, model.logreg_predict, (spec(n, d), spec(d)))
        )

    # -- ALS ------------------------------------------------------------
    for variant, u, m, k in [
        ("small", 32, 64, 8),
        ("bench", 256, 128, 10),
    ]:
        e.append(
            (
                "als_solve_batch",
                variant,
                model.als_solve_batch,
                (spec(u, m, k), spec(u, m), spec(u, m), spec()),
            )
        )
        # gram-only variant: entities whose rating count exceeds m are
        # chunked into m-wide slots; grams are additive, so the rust side
        # sums chunk grams and does the tiny k x k solve itself.
        from compile.kernels import als_gram as _ag

        e.append(
            (
                "als_gram_batch",
                variant,
                _ag.als_gram,
                (spec(u, m, k), spec(u, m), spec(u, m)),
            )
        )
        e.append(
            (
                "als_rmse_batch",
                variant,
                model.als_rmse_batch,
                (spec(u, m, k), spec(u, m), spec(u, m), spec(u, k)),
            )
        )

    # -- K-means ----------------------------------------------------------
    for variant, n, d, c in [("small", 256, 64, 8), ("bench", 2048, 512, 50)]:
        e.append(
            ("kmeans_step", variant, model.kmeans_step, (spec(n, d), spec(c, d)))
        )

    return e


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation (return_tuple=True) -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-sep entry name filter")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"format": "hlo-text", "artifacts": []}
    for entry in _entries():
        name, variant, fn, specs = entry[:4]
        aux = entry[4] if len(entry) > 4 else {}
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}__{variant}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        out_info = lowered.out_info
        outs = jax.tree_util.tree_leaves(out_info)
        manifest["artifacts"].append(
            {
                **aux,
                "entry": name,
                "variant": variant,
                "file": fname,
                "inputs": [
                    {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
                ],
                "outputs": [
                    {"shape": [int(x) for x in o.shape], "dtype": str(o.dtype)}
                    for o in outs
                ],
            }
        )
        print(f"  wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
