"""L2: the paper's per-partition compute graphs, written in jax.

These are the functions the rust L3 coordinator calls on the request path
(after AOT lowering by aot.py - python itself never runs at serve time):

* ``local_sgd_epoch``  - the paper's localSGD (Fig. A4 bottom): sequential
  minibatch SGD over one MLTable partition, gradient per minibatch computed
  by the L1 pallas kernel. One call per worker per round; L3 averages the
  returned weight vectors (the MapReduce gather/broadcast step).
* ``logreg_grad_batch`` - full-partition gradient + loss for the
  gradient-descent variant (the MATLAB baseline) and for loss logging.
* ``logreg_predict``   - sigmoid margins for a partition (Model.predict).
* ``als_solve_batch``  - the paper's localALS (Fig. A9): per-user normal
  equations via the L1 gram kernel, then a batched SPD solve.
* ``kmeans_step``      - assignment + per-centroid sums/counts for one
  partition (the Fig. A2 pipeline's learner); L3 sums across partitions.

AOT constraint: everything here must lower to *pure HLO math ops*. In
particular jnp.linalg.solve / lax.linalg.cholesky lower to LAPACK
custom-calls on CPU jaxlib, which the standalone xla_extension 0.5.1
runtime the rust side uses cannot resolve. ``spd_solve`` below is therefore
a hand-unrolled Cholesky + triangular solve over the static rank k (k<=32),
emitting only adds/muls/divs/sqrts.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import als_gram as _als
from compile.kernels import logreg_grad as _lr


# --------------------------------------------------------------------------
# Pure-HLO batched SPD solve (no LAPACK custom-calls)
# --------------------------------------------------------------------------

def spd_solve(a, b):
    """Solve a @ x = b for SPD a, batched over leading dims.

    a: (..., k, k) symmetric positive definite; b: (..., k).
    Unrolled Cholesky (a = L L^T) + two triangular solves. k is a static
    trace-time constant so the python loops unroll into straight-line HLO;
    for k <= 32 this is ~k^3/3 fused mul-adds per matrix and beats any
    custom-call roundtrip.
    """
    k = a.shape[-1]
    # Cholesky: build L column by column. rows[i][j] holds L[..., i, j].
    rows = [[None] * k for _ in range(k)]
    for j in range(k):
        s = a[..., j, j]
        for p in range(j):
            s = s - rows[j][p] * rows[j][p]
        # clamp for numerical safety: padded all-zero entities would
        # otherwise hit sqrt(0) and poison the batch with NaNs
        diag = jnp.sqrt(jnp.maximum(s, 1e-30))
        rows[j][j] = diag
        for i in range(j + 1, k):
            s = a[..., i, j]
            for p in range(j):
                s = s - rows[i][p] * rows[j][p]
            rows[i][j] = s / diag
    # forward solve L z = b
    z = [None] * k
    for i in range(k):
        s = b[..., i]
        for p in range(i):
            s = s - rows[i][p] * z[p]
        z[i] = s / rows[i][i]
    # backward solve L^T x = z
    x = [None] * k
    for i in reversed(range(k)):
        s = z[i]
        for p in range(i + 1, k):
            s = s - rows[p][i] * x[p]
        x[i] = s / rows[i][i]
    return jnp.stack(x, axis=-1)


# --------------------------------------------------------------------------
# Logistic regression (paper §IV-A)
# --------------------------------------------------------------------------

def logreg_grad_batch(x, y, w, *, grad_impl=None, loss_impl=None):
    """Full-partition gradient and NLL: one GD round's local contribution.

    Returns (grad, loss[1]). L3 sums grads and losses across partitions
    (the paper's master-side average is sum/num_partitions).
    """
    grad_impl = grad_impl or _lr.logreg_grad
    loss_impl = loss_impl or _lr.logreg_loss
    g = grad_impl(x, y, w)
    l = loss_impl(x, y, w)
    return g, jnp.reshape(l, (1,))


def local_sgd_epoch(x, y, w0, lr, *, block_n=None, grad_impl=None):
    """localSGD (Fig. A4): sequential minibatch passes over a partition.

    x: (n, d), y: (n,), w0: (d,), lr: () learning rate (traced, so the
    rust side can anneal it without recompiling).

    Implemented as a lax.scan over n/block_n minibatches - scan (not
    unroll) keeps the lowered HLO size O(1) in n (DESIGN.md §Perf L2).
    Each scan step invokes the pallas gradient kernel with grid=1 on its
    (block_n, d) slice.
    """
    n, d = x.shape
    block_n = block_n or _lr.DEFAULT_BLOCK_N
    assert n % block_n == 0
    grad_impl = grad_impl or functools.partial(_lr.logreg_grad, block_n=block_n)
    steps = n // block_n
    xs = x.reshape(steps, block_n, d)
    ys = y.reshape(steps, block_n)

    def step(w, xy):
        xb, yb = xy
        g = grad_impl(xb, yb, w)
        return w - lr * g, None

    w, _ = jax.lax.scan(step, w0, (xs, ys))
    return w


def logreg_predict(x, w):
    """Sigmoid margins for a partition: (n,) probabilities."""
    return jax.nn.sigmoid(x @ w)


# --------------------------------------------------------------------------
# ALS (paper §IV-B)
# --------------------------------------------------------------------------

def als_solve_batch(factors, ratings, mask, lam, *, gram_impl=None):
    """localALS: updated factor rows for a batch of users (or items).

    factors: (u, m, k) gathered counterpart factors per entity,
    ratings/mask: (u, m), lam: () ridge strength (traced).
    Returns (u, k) solved factor rows. Entities with zero ratings get
    ~zero vectors (their gram is lam*I and rhs is 0), matching the
    cold-start convention of the reference MATLAB code.
    """
    gram_impl = gram_impl or _als.als_gram
    grams, rhs = gram_impl(factors, ratings, mask)
    k = factors.shape[-1]
    ridge = lam * jnp.eye(k, dtype=factors.dtype)
    return spd_solve(grams + ridge[None], rhs)


def als_rmse_batch(factors, ratings, mask, rows):
    """Partition-local sum of squared residuals + count, for RMSE logging.

    rows: (u, k) current factors of the entities being evaluated.
    Returns ([sse], [count]).
    """
    pred = jnp.einsum("umk,uk->um", factors, rows)
    resid = (pred - ratings) * mask
    return jnp.reshape(jnp.sum(resid * resid), (1,)), jnp.reshape(
        jnp.sum(mask), (1,)
    )


# --------------------------------------------------------------------------
# K-means (Fig. A2 pipeline learner)
# --------------------------------------------------------------------------

def kmeans_step(x, centroids):
    """One Lloyd iteration's partition-local statistics.

    x: (n, d), centroids: (c, d).
    Returns (sums (c, d), counts (c,), sse (1,)). L3 sums all three across
    partitions and forms new centroids = sums / counts.
    """
    # squared distances via the expansion ||x||^2 - 2 x.c + ||c||^2;
    # the x.c term is the MXU matmul that dominates.
    xc = x @ centroids.T  # (n, c)
    cn = jnp.sum(centroids * centroids, axis=1)  # (c,)
    xn = jnp.sum(x * x, axis=1)  # (n,)
    d2 = xn[:, None] - 2.0 * xc + cn[None, :]
    assign = jnp.argmin(d2, axis=1)  # (n,)
    onehot = jax.nn.one_hot(assign, centroids.shape[0], dtype=x.dtype)
    sums = onehot.T @ x  # (c, d)
    counts = jnp.sum(onehot, axis=0)  # (c,)
    sse = jnp.sum(jnp.min(d2, axis=1))
    return sums, counts, jnp.reshape(sse, (1,))
