"""L1 Pallas kernel: tiled logistic-regression gradient.

Computes  grad = X^T (sigmoid(X w) - y)  for a partition-local minibatch.
This is the compute hot-spot of the paper's `localSGD` inner loop
(Fig. A4): every SGD step evaluates the gradient of the negative
log-likelihood on a (mini)batch that lives in one MLTable partition.

TPU mapping (DESIGN.md §Hardware-Adaptation): the batch matrix X is tiled
row-wise HBM->VMEM with a BlockSpec over the n dimension; each grid step
computes a partial X_tile^T (sigmoid(X_tile w) - y_tile) on the MXU and
accumulates into the output block, which stays resident in VMEM across the
grid (out index_map is constant). d is kept whole per tile: for the default
d=2048, a (128, 2048) f32 tile is 1 MiB of VMEM, and the running (2048,)
accumulator is 8 KiB - comfortably inside the ~16 MiB VMEM budget with
double buffering.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the interpreter to plain HLO
(see /opt/xla-example/README.md). Correctness is pinned against the
pure-jnp oracle in ref.py by python/tests/test_kernel.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile size along the batch dimension. 256 measured fastest on the
# CPU-PJRT path (EXPERIMENTS.md §Perf: 128->256 = +11%, 512 flat, 1024+
# regress); it also matches a (256, d) f32 VMEM tile = 0.5 MiB at d=512 on
# the TPU mental model.
DEFAULT_BLOCK_N = 256


def _grad_kernel(x_ref, y_ref, w_ref, o_ref):
    """One grid step: accumulate the gradient of one row-tile.

    x_ref: (bn, d) tile of the design matrix (VMEM)
    y_ref: (bn,)   tile of labels in {0,1}
    w_ref: (d,)    full weight vector (broadcast to every grid step)
    o_ref: (d,)    gradient accumulator (same block every step)
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    # margin: (bn,) = X_tile @ w  -- MXU matvec
    margin = x @ w_ref[...]
    resid = jax.nn.sigmoid(margin) - y_ref[...]
    # partial gradient: (d,) = X_tile^T @ resid -- second MXU pass
    o_ref[...] += x.T @ resid


@functools.partial(jax.jit, static_argnames=("block_n",))
def logreg_grad(x, y, w, *, block_n=DEFAULT_BLOCK_N):
    """Pallas logistic gradient: X^T (sigmoid(Xw) - y).

    x: (n, d) float32, y: (n,) float32 in {0,1}, w: (d,) float32.
    n must be divisible by block_n (callers pad; aot.py fixes shapes).
    """
    n, d = x.shape
    assert n % block_n == 0, f"n={n} not divisible by block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, y, w)


def _loss_kernel(x_ref, y_ref, w_ref, o_ref):
    """Accumulate the negative log-likelihood of one row-tile."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    margin = x_ref[...] @ w_ref[...]
    y = y_ref[...]
    # numerically-stable log(1+exp(-z)) formulation
    nll = jnp.sum(jax.nn.softplus(margin) - y * margin)
    o_ref[...] += nll[None]


@functools.partial(jax.jit, static_argnames=("block_n",))
def logreg_loss(x, y, w, *, block_n=DEFAULT_BLOCK_N):
    """Pallas negative log-likelihood, tiled like logreg_grad."""
    n, d = x.shape
    assert n % block_n == 0
    grid = (n // block_n,)
    out = pl.pallas_call(
        _loss_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), x.dtype),
        interpret=True,
    )(x, y, w)
    return out[0]
