"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every kernel in this package must
match its oracle to float32 tolerance across the hypothesis shape/dtype
sweep in python/tests/test_kernel.py, and the L2 model functions are built
so a pallas<->ref swap is a one-line change (model.py takes the kernel impl
as a parameter for exactly that reason).
"""

import jax
import jax.numpy as jnp


def logreg_grad_ref(x, y, w):
    """grad = X^T (sigmoid(Xw) - y); the paper's Eq. (1)."""
    return x.T @ (jax.nn.sigmoid(x @ w) - y)


def logreg_loss_ref(x, y, w):
    """Negative log-likelihood, stable softplus form."""
    margin = x @ w
    return jnp.sum(jax.nn.softplus(margin) - y * margin)


def als_gram_ref(factors, ratings, mask):
    """Per-user gram matrices and right-hand sides.

    factors: (u, m, k); ratings, mask: (u, m).
    Returns ((u,k,k), (u,k)) matching als_gram.als_gram.
    """
    ym = factors * mask[..., None]
    grams = jnp.einsum("umk,uml->ukl", ym, ym)
    rhs = jnp.einsum("umk,um->uk", ym, ratings)
    return grams, rhs


def als_solve_ref(factors, ratings, mask, lam):
    """Full per-user ALS update: solve (Y^T Y + lam*I) x = Y^T r.

    Matches the paper's objective (2): plain L2 ridge, lambda fixed.
    """
    grams, rhs = als_gram_ref(factors, ratings, mask)
    k = factors.shape[-1]
    ridge = lam * jnp.eye(k, dtype=factors.dtype)
    return jnp.linalg.solve(grams + ridge[None], rhs[..., None])[..., 0]


def local_sgd_epoch_ref(x, y, w0, lr, block_n):
    """Oracle for model.local_sgd_epoch: sequential minibatch SGD.

    Walks the partition in minibatches of block_n rows, applying
    w -= lr * grad(minibatch) - the paper's localSGD (Fig. A4, bottom).
    """
    n = x.shape[0]
    w = w0
    for s in range(0, n, block_n):
        xs, ys = x[s : s + block_n], y[s : s + block_n]
        w = w - lr * logreg_grad_ref(xs, ys, w)
    return w
