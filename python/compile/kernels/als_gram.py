"""L1 Pallas kernel: batched ALS normal-equation assembly.

The hot spot of the paper's `localALS` (Fig. A9) is, per user (or item) q:

    A_q = Y_q^T Y_q + lambda*I      (k x k gram matrix over rated items)
    b_q = Y_q^T r_q                 (k,)  right-hand side

followed by solving A_q x = b_q. With rank k ~= 10 the solve is tiny; the
cost is assembling A_q/b_q from the rated rows. We batch users: the L3
coordinator gathers, for each user in a partition, its rated item factors
into a dense (batch, max_nnz, k) tensor with a 0/1 validity mask (rows
beyond the user's nnz are zero), and this kernel computes all gram
matrices + rhs in one MXU-friendly pass.

TPU mapping: one grid step per user-tile; a (bu, m, k) slab of factors is
staged into VMEM and contracted on the MXU as batched (k,m)x(m,k) matmuls.
For bu=8, m=128, k=16 the tile is 64 KiB - tiny; the real win on TPU is
keeping the factor slab resident while both the gram and the rhs
contraction read it.

The k x k solve itself stays in L2 jax (jnp.linalg.solve) - it is O(k^3)
with k<=32 and gains nothing from a custom kernel.

interpret=True as required for CPU PJRT (see logreg_grad.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_U = 8


def _gram_kernel(yf_ref, r_ref, mask_ref, a_ref, b_ref):
    """One grid step: gram + rhs for a tile of users.

    yf_ref:   (bu, m, k) gathered item factors per user (rows >= nnz are 0)
    r_ref:    (bu, m)    ratings per user (0 beyond nnz)
    mask_ref: (bu, m)    1.0 for valid rows
    a_ref:    (bu, k, k) output gram matrices (without the lambda ridge)
    b_ref:    (bu, k)    output right-hand sides
    """
    yf = yf_ref[...]
    mask = mask_ref[...]
    ym = yf * mask[..., None]
    # batched gram: (bu,k,k) = ym^T ym per user, one einsum -> MXU
    a_ref[...] = jnp.einsum("umk,uml->ukl", ym, ym)
    b_ref[...] = jnp.einsum("umk,um->uk", ym, r_ref[...])


@functools.partial(jax.jit, static_argnames=("block_u",))
def als_gram(factors, ratings, mask, *, block_u=DEFAULT_BLOCK_U):
    """Batched gram-matrix assembly for ALS.

    factors: (u, m, k) float32 - per-user gathered item factors
    ratings: (u, m)    float32 - per-user ratings, 0-padded
    mask:    (u, m)    float32 - 1.0 where the slot is a real rating
    returns (grams, rhs): (u, k, k), (u, k)
    """
    u, m, k = factors.shape
    assert u % block_u == 0, f"u={u} not divisible by block_u={block_u}"
    grid = (u // block_u,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_u, m, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_u, m), lambda i: (i, 0)),
            pl.BlockSpec((block_u, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_u, k, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_u, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((u, k, k), factors.dtype),
            jax.ShapeDtypeStruct((u, k), factors.dtype),
        ],
        interpret=True,
    )(factors, ratings, mask)
