//! Comparison systems (paper §IV): Vowpal Wabbit, MATLAB / MATLAB-mex,
//! Mahout, and GraphLab, rebuilt as *system profiles* over the same
//! algorithm implementations.
//!
//! What differs between the paper's systems — and what these profiles
//! encode — is:
//!
//! | System   | Language    | Topology            | Storage        | Placement |
//! |----------|-------------|---------------------|----------------|-----------|
//! | MLI      | Scala/JVM   | star gather/bcast   | in-memory RDD  | cluster   |
//! | VW       | C++         | AllReduce tree      | local files    | cluster   |
//! | MATLAB   | native BLAS | —                   | in-memory      | 1 machine |
//! | Mahout   | Java/Hadoop | MapReduce           | HDFS per iter  | cluster   |
//! | GraphLab | C++/MPI     | p2p vertex msgs     | in-memory      | cluster   |
//!
//! Per-partition *compute* is really executed and timed on this host; a
//! per-system `compute_factor` models the language/runtime constant
//! factor, calibrated once against the paper's reported gaps (VW ~0.65x
//! of MLI per §IV-A "on average 35% faster"; GraphLab <=4x faster per
//! §IV-B; Mahout's JVM MapReduce ~2.5x slower plus its HDFS traffic).
//! Scaling *shape* is never hard-coded: it emerges from the topology +
//! cost model. See DESIGN.md §3.

pub mod graphlab;
pub mod mahout;
pub mod matlab;
pub mod vw;

use crate::cluster::{CommTopology, MachineSpec, NetworkModel, SimCluster};

/// Outcome of running one system on one workload configuration.
#[derive(Debug, Clone)]
pub struct SystemRun {
    pub system: String,
    pub machines: usize,
    /// Simulated walltime; `None` = did not finish (simulated OOM),
    /// matching the paper's MATLAB entries at the largest scales.
    pub sim_seconds: Option<f64>,
    /// Final loss / RMSE where applicable (correctness cross-check:
    /// "ALS methods from all systems achieved comparable error").
    pub quality: Option<f64>,
}

/// A system profile: everything that distinguishes one of the paper's
/// systems in the simulation.
#[derive(Debug, Clone)]
pub struct SystemProfile {
    pub name: &'static str,
    pub compute_factor: f64,
    pub topology: CommTopology,
    pub disk_spill: bool,
    /// Single-machine systems (MATLAB) ignore the machine count.
    pub single_machine: bool,
    /// Simulated per-machine memory (bytes) — scaled-down m2.4xlarge.
    pub mem_bytes: u64,
}

/// Default simulated memory: the paper's 68 GB node scaled by the ~375x
/// dataset scale-down (200 GB ImageNet -> ~540 MB synthetic), i.e. 180 MB.
/// With this one constant, MATLAB OOMs exactly where the paper reports it
/// (the largest logreg weak-scaling point; 16x/25x Netflix but not 9x) —
/// verified by tests in `matlab.rs`.
pub const SCALED_NODE_MEM: u64 = 180_000_000;

impl SystemProfile {
    pub fn mli() -> SystemProfile {
        SystemProfile {
            name: "MLI",
            compute_factor: 1.0,
            topology: CommTopology::StarGatherBroadcast,
            disk_spill: false,
            single_machine: false,
            mem_bytes: SCALED_NODE_MEM,
        }
    }

    pub fn vw() -> SystemProfile {
        SystemProfile {
            name: "VW",
            compute_factor: 0.65,
            topology: CommTopology::AllReduceTree,
            disk_spill: false,
            single_machine: false,
            mem_bytes: SCALED_NODE_MEM,
        }
    }

    pub fn matlab() -> SystemProfile {
        SystemProfile {
            name: "MATLAB",
            // vectorized MATLAB = native BLAS, but interpreter overhead on
            // the update loop; net ~1.2x our hot path
            compute_factor: 1.2,
            topology: CommTopology::StarGatherBroadcast,
            disk_spill: false,
            single_machine: true,
            mem_bytes: SCALED_NODE_MEM,
        }
    }

    pub fn matlab_mex() -> SystemProfile {
        SystemProfile {
            name: "MATLAB-mex",
            compute_factor: 0.8, // C++ inner loops via mex
            topology: CommTopology::StarGatherBroadcast,
            disk_spill: false,
            single_machine: true,
            mem_bytes: SCALED_NODE_MEM,
        }
    }

    pub fn mahout() -> SystemProfile {
        SystemProfile {
            name: "Mahout",
            compute_factor: 2.5, // JVM MapReduce per-record overhead
            topology: CommTopology::StarGatherBroadcast,
            disk_spill: true,
            single_machine: false,
            mem_bytes: SCALED_NODE_MEM,
        }
    }

    pub fn graphlab() -> SystemProfile {
        SystemProfile {
            name: "GraphLab",
            compute_factor: 0.3, // optimized C++ vertex programs
            topology: CommTopology::PeerToPeer,
            disk_spill: false,
            single_machine: false,
            mem_bytes: SCALED_NODE_MEM,
        }
    }

    /// Build the simulated cluster this profile runs on. Benchmarks run
    /// homogeneous synthetic partitions, so the Median straggler model is
    /// used to keep host noise out of the barrier (see
    /// `cluster::StragglerModel`).
    pub fn cluster(&self, machines: usize) -> SimCluster {
        let m = if self.single_machine { 1 } else { machines };
        SimCluster::new(
            m,
            MachineSpec::default()
                .with_compute_factor(self.compute_factor)
                .with_mem_bytes(self.mem_bytes),
            NetworkModel::ec2_2013(),
        )
        .with_straggler(crate::cluster::StragglerModel::Median)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_reflect_paper_claims() {
        // VW faster than MLI per unit compute
        assert!(SystemProfile::vw().compute_factor < SystemProfile::mli().compute_factor);
        // GraphLab fastest compute
        assert!(
            SystemProfile::graphlab().compute_factor < SystemProfile::vw().compute_factor
        );
        // Mahout slowest and disk-bound
        let mahout = SystemProfile::mahout();
        assert!(mahout.compute_factor > 2.0);
        assert!(mahout.disk_spill);
        // MATLAB single machine
        assert!(SystemProfile::matlab().single_machine);
        assert_eq!(SystemProfile::matlab().cluster(32).num_machines(), 1);
        assert_eq!(SystemProfile::mli().cluster(8).num_machines(), 8);
    }
}
