//! MATLAB / MATLAB-mex baselines (paper §IV): single-machine reference
//! implementations with the simulated 68 GB (scaled) memory cap that
//! reproduces the paper's out-of-memory DNFs at the largest workloads.
//!
//! Logistic regression: "In MATLAB, we implement gradient descent instead
//! of SGD ... implemented in a 'vectorized' fashion" — full-batch GD on
//! one machine, all 8 cores.
//! ALS: the Fig. A9 MATLAB code (parfor over users/items), and the mex
//! variant with C++ inner loops.

use super::{SystemProfile, SystemRun};
use crate::algorithms::als::{AlsParams, ALS};
use crate::cluster::SimCluster;
use crate::data::netflix::RatingsData;
use crate::error::Result;
use crate::mltable::MLNumericTable;
use crate::optim::{GdParams, GD};

/// MATLAB's resident-set model for the logreg workload: the dense design
/// matrix (n x (d+1) doubles); the vectorized X*w / X'*r temporaries are
/// O(n) and O(d), negligible next to X itself.
pub fn logreg_mem_bytes(n: usize, d: usize) -> u64 {
    (n * (d + 1) * 8) as u64
}

/// MATLAB's resident set for ALS: the sparse ratings (two copies — M and
/// M'), dense factors, and the per-worker gather workspace of the parfor
/// body (Vq / Uq copies; ~2x the largest gather).
pub fn als_mem_bytes(users: usize, items: usize, nnz: usize, k: usize, max_nnz: usize) -> u64 {
    let ratings = 2 * nnz * 16;
    let factors = (users + items) * k * 8;
    let workspace = 2 * users * max_nnz * k * 8;
    (ratings + factors + workspace) as u64
}

/// Run single-machine MATLAB GD for logistic regression.
///
/// Compute is measured through the SAME provider backend as the other
/// systems (vectorized MATLAB calls optimized BLAS — the analogue of the
/// XLA batch-gradient artifact), so cross-system gaps come only from the
/// profile's compute factor + single-machine placement. All partitions
/// land on the one machine's 8 cores.
pub fn run_logreg(
    data: &MLNumericTable,
    gd: &GdParams,
    mex: bool,
    xla: bool,
) -> Result<SystemRun> {
    let profile = if mex {
        SystemProfile::matlab_mex()
    } else {
        SystemProfile::matlab()
    };
    let cluster = profile.cluster(1);
    let n = data.num_rows()?;
    let d = data.num_cols() - 1;
    // simulated allocation: OOM -> DNF (the paper's 200K-point MATLAB row)
    if let Err(e) = cluster.alloc(0, logreg_mem_bytes(n, d)) {
        debug_assert!(e.is_oom());
        return Ok(SystemRun {
            system: profile.name.to_string(),
            machines: 1,
            sim_seconds: None,
            quality: None,
        });
    }
    let provider = crate::algorithms::glm::make_logreg_provider(data, xla)?;
    let res = GD::run(provider.as_ref(), &cluster, gd)?;
    Ok(SystemRun {
        system: profile.name.to_string(),
        machines: 1,
        sim_seconds: Some(cluster.total_sim_seconds()),
        quality: res.loss_history.last().copied(),
    })
}

/// Run single-machine MATLAB (or mex) ALS.
pub fn run_als(data: &RatingsData, params: &AlsParams, mex: bool) -> Result<SystemRun> {
    let profile = if mex {
        SystemProfile::matlab_mex()
    } else {
        SystemProfile::matlab()
    };
    let cluster: SimCluster = profile.cluster(1);
    let max_nnz = (0..data.ratings.rows)
        .map(|r| data.ratings.row_nnz(r))
        .max()
        .unwrap_or(0);
    let need = als_mem_bytes(
        data.users,
        data.items,
        data.ratings.nnz(),
        params.rank,
        max_nnz,
    );
    if let Err(e) = cluster.alloc(0, need) {
        debug_assert!(e.is_oom());
        return Ok(SystemRun {
            system: profile.name.to_string(),
            machines: 1,
            sim_seconds: None,
            quality: None,
        });
    }
    // keep the caller's compute backend (same-provider principle; see
    // run_logreg above) — only the profile factors and placement differ
    let mut p = params.clone();
    p.track_rmse = true;
    let model = ALS::new(p).train_ratings(data, &cluster)?;
    Ok(SystemRun {
        system: profile.name.to_string(),
        machines: 1,
        sim_seconds: Some(cluster.total_sim_seconds()),
        quality: model.rmse_history.last().copied(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::SCALED_NODE_MEM;
    use crate::data::netflix::{self, NetflixConfig};
    use crate::data::dense_gen;
    use crate::engine::EngineContext;

    #[test]
    fn matlab_logreg_completes_small() {
        let ctx = EngineContext::new();
        let data = dense_gen::generate(&ctx, 128, 8, 4, 2).unwrap();
        let run = run_logreg(
            &data.table,
            &GdParams {
                iters: 5,
                track_loss: true,
                ..Default::default()
            },
            false,
            false,
        )
        .unwrap();
        assert_eq!(run.system, "MATLAB");
        assert!(run.sim_seconds.is_some());
        assert!(run.quality.is_some());
    }

    #[test]
    fn matlab_ooms_at_paper_scale() {
        // the paper's largest weak-scaling point: 32 machines' worth of
        // data on one MATLAB box -> OOM. 32 * 2048 rows * 513 cols:
        let n = 32 * 2048;
        let d = 512;
        assert!(logreg_mem_bytes(n, d) > SCALED_NODE_MEM);
        // while the 16-machine point fits (paper: MATLAB completes every
        // point except the largest):
        assert!(logreg_mem_bytes(16 * 2048, d) < SCALED_NODE_MEM);
    }

    #[test]
    fn matlab_als_oom_at_16x_not_9x() {
        let base = netflix::generate(&NetflixConfig::default());
        let t9 = netflix::tile(&base, 9);
        let t16 = netflix::tile(&base, 16);
        let max9 = (0..t9.ratings.rows).map(|r| t9.ratings.row_nnz(r)).max().unwrap();
        let max16 = (0..t16.ratings.rows).map(|r| t16.ratings.row_nnz(r)).max().unwrap();
        let m9 = als_mem_bytes(t9.users, t9.items, t9.ratings.nnz(), 10, max9);
        let m16 = als_mem_bytes(t16.users, t16.items, t16.ratings.nnz(), 10, max16);
        assert!(
            m9 < SCALED_NODE_MEM,
            "9x should fit: {} vs {}",
            m9,
            SCALED_NODE_MEM
        );
        assert!(
            m16 > SCALED_NODE_MEM,
            "16x should OOM: {} vs {}",
            m16,
            SCALED_NODE_MEM
        );
    }

    #[test]
    fn matlab_als_dnf_is_reported_not_error() {
        let base = netflix::generate(&NetflixConfig::default());
        let t16 = netflix::tile(&base, 16);
        let run = run_als(
            &t16,
            &AlsParams {
                iters: 1,
                ..Default::default()
            },
            false,
        )
        .unwrap();
        assert!(run.sim_seconds.is_none(), "expected DNF");
    }
}
