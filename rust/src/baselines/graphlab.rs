//! GraphLab baseline (paper §IV-B): ALS as vertex programs on the
//! user-item bipartite graph over MPI — peer-to-peer factor exchange (no
//! master bottleneck) and optimized C++ compute. The paper measures
//! GraphLab <= 4x faster than MLI with a similar scaling slope; here that
//! emerges from the p2p topology + the C++ compute factor.

use super::{SystemProfile, SystemRun};
use crate::algorithms::als::{AlsParams, ALS};
use crate::data::netflix::RatingsData;
use crate::error::Result;

pub fn run_als(data: &RatingsData, machines: usize, params: &AlsParams) -> Result<SystemRun> {
    let profile = SystemProfile::graphlab();
    let cluster = profile.cluster(machines);
    // same compute backend as the caller (same-provider principle)
    let mut p = params.clone();
    p.topology = profile.topology; // PeerToPeer
    p.track_rmse = true;
    let model = ALS::new(p).train_ratings(data, &cluster)?;
    Ok(SystemRun {
        system: profile.name.to_string(),
        machines,
        sim_seconds: Some(cluster.total_sim_seconds()),
        quality: model.rmse_history.last().copied(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::CommTopology;
    use crate::data::netflix::{self, NetflixConfig};

    #[test]
    fn graphlab_uses_p2p_and_completes() {
        assert_eq!(
            SystemProfile::graphlab().topology,
            CommTopology::PeerToPeer
        );
        let data = netflix::generate(&NetflixConfig {
            users: 96,
            items: 32,
            mean_nnz_per_user: 6,
            max_nnz_per_user: 12,
            rank: 4,
            ..Default::default()
        });
        let run = run_als(
            &data,
            4,
            &AlsParams {
                rank: 4,
                iters: 2,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(run.system, "GraphLab");
        assert!(run.sim_seconds.unwrap() > 0.0);
        assert!(run.quality.is_some());
    }
}
