//! Mahout baseline (paper §IV-B): ALS as Hadoop MapReduce jobs. Every
//! half-round is a fresh job — JVM startup, ratings re-read from HDFS,
//! factors written back 3x-replicated — which is exactly the iteration
//! overhead the paper attributes Mahout's numbers to.

use super::{SystemProfile, SystemRun};
use crate::algorithms::als::{AlsParams, ALS};
use crate::data::netflix::RatingsData;
use crate::error::Result;

pub fn run_als(data: &RatingsData, machines: usize, params: &AlsParams) -> Result<SystemRun> {
    let profile = SystemProfile::mahout();
    let cluster = profile.cluster(machines);
    // same compute backend as the caller (same-provider principle);
    // mahout-ness = MapReduce topology + HDFS spill + JVM factor
    let mut p = params.clone();
    p.topology = profile.topology;
    p.disk_spill = true;
    p.track_rmse = true;
    let model = ALS::new(p).train_ratings(data, &cluster)?;
    Ok(SystemRun {
        system: profile.name.to_string(),
        machines,
        sim_seconds: Some(cluster.total_sim_seconds()),
        quality: model.rmse_history.last().copied(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::graphlab;
    use crate::data::netflix::{self, NetflixConfig};

    fn small() -> RatingsData {
        netflix::generate(&NetflixConfig {
            users: 128,
            items: 48,
            mean_nnz_per_user: 8,
            max_nnz_per_user: 16,
            rank: 4,
            ..Default::default()
        })
    }

    #[test]
    fn mahout_pays_per_iteration_overhead() {
        let data = small();
        let params = AlsParams {
            rank: 4,
            iters: 3,
            ..Default::default()
        };
        let mahout = run_als(&data, 4, &params).unwrap();
        let graphlab = graphlab::run_als(&data, 4, &params).unwrap();
        let tm = mahout.sim_seconds.unwrap();
        let tg = graphlab.sim_seconds.unwrap();
        // 3 iters x 2 half-rounds x ~10s startup => Mahout is dominated
        // by job overhead and far slower than GraphLab (paper Fig. 3b)
        assert!(tm > 50.0, "mahout time {tm}");
        assert!(tm > 10.0 * tg, "mahout {tm} vs graphlab {tg}");
        // but converges to comparable quality (paper: "ALS methods from
        // all systems achieved comparable error rates")
        let qm = mahout.quality.unwrap();
        let qg = graphlab.quality.unwrap();
        assert!((qm - qg).abs() < 0.05, "{qm} vs {qg}");
    }
}
