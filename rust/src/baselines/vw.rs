//! Vowpal Wabbit baseline (paper §IV-A).
//!
//! "Algorithmically, our implementation is identical to VW, with one
//! meaningful difference, namely aggregating results across worker nodes
//! after each round. VW uses an 'AllReduce' communication primitive to
//! build an aggregation tree ... In contrast, we take a more traditional
//! MapReduce approach and average all parameters at the cluster's master
//! node." — so the VW baseline runs the *same* local-SGD provider with
//! the AllReduce-tree topology and the C++ compute factor.

use super::{SystemProfile, SystemRun};
use crate::algorithms::logreg::{Backend, LogRegParams, LogisticRegression};
use crate::algorithms::Algorithm;
use crate::error::Result;
use crate::mltable::MLNumericTable;
use crate::optim::SgdParams;

/// Run VW-profile logistic regression; returns the run record plus the
/// trained model's final loss for cross-system quality checks.
pub fn run_logreg(
    data: &MLNumericTable,
    machines: usize,
    sgd: &SgdParams,
    backend: Backend,
) -> Result<SystemRun> {
    let profile = SystemProfile::vw();
    let cluster = profile.cluster(machines);
    let mut params = sgd.clone();
    params.topology = profile.topology;
    let algo = LogisticRegression::new(LogRegParams { sgd: params, backend });
    let model = algo.train(data, &cluster)?;
    Ok(SystemRun {
        system: profile.name.to_string(),
        machines,
        sim_seconds: Some(cluster.total_sim_seconds()),
        quality: model.loss_history.last().copied(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::SystemProfile;
    use crate::cluster::CommTopology;
    use crate::data::dense_gen;
    use crate::engine::EngineContext;

    #[test]
    fn vw_runs_and_uses_tree_topology() {
        let ctx = EngineContext::new();
        let data = dense_gen::generate(&ctx, 128, 8, 4, 1).unwrap();
        let run = run_logreg(
            &data.table,
            4,
            &SgdParams {
                iters: 3,
                ..Default::default()
            },
            Backend::Rust,
        )
        .unwrap();
        assert_eq!(run.system, "VW");
        assert!(run.sim_seconds.unwrap() > 0.0);
        assert_eq!(SystemProfile::vw().topology, CommTopology::AllReduceTree);
    }
}
