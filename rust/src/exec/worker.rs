//! Worker thread loop + per-worker execution metrics.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::pool::{Shared, Task};

/// Per-worker counters, written by the worker thread with relaxed atomics
/// and snapshotted by [`super::ThreadPool::worker_stats`].
#[derive(Default)]
pub struct WorkerMetrics {
    pub tasks: AtomicU64,
    pub steals: AtomicU64,
    pub busy_nanos: AtomicU64,
    pub idle_nanos: AtomicU64,
}

/// Read-only snapshot of one worker's metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerStats {
    pub worker: usize,
    pub tasks: u64,
    pub steals: u64,
    pub busy_nanos: u64,
    pub idle_nanos: u64,
}

impl WorkerMetrics {
    pub fn snapshot(&self, worker: usize) -> WorkerStats {
        WorkerStats {
            worker,
            tasks: self.tasks.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            busy_nanos: self.busy_nanos.load(Ordering::Relaxed),
            idle_nanos: self.idle_nanos.load(Ordering::Relaxed),
        }
    }
}

thread_local! {
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread is a pool worker. Scoped stages use this
/// to run nested stages inline instead of re-submitting to the pool (which
/// could deadlock a task that blocks on its own pool).
pub fn is_pool_thread() -> bool {
    IS_POOL_WORKER.with(|f| f.get())
}

/// The worker main loop: drain own deque (LIFO), then the shared injector,
/// then steal from siblings (FIFO); park when there is nothing anywhere.
pub(crate) fn run(shared: Arc<Shared>, idx: usize) {
    IS_POOL_WORKER.with(|f| f.set(true));
    loop {
        if let Some(task) = find_task(&shared, idx) {
            execute(&shared, idx, task);
            continue;
        }
        // Park. The lock-ordering dance matters: submitters notify while
        // holding `park_lock`, and we re-check for work while holding it,
        // so a task pushed between our failed scan and the wait cannot be
        // missed.
        let guard = shared.park_lock.lock().unwrap();
        if shared.is_shutdown() {
            break;
        }
        if shared.has_work() {
            continue;
        }
        let sw = crate::util::timer::Stopwatch::start();
        // Timeout is belt-and-braces only; correctness comes from the
        // re-check above.
        let _ = shared
            .park_cv
            .wait_timeout(guard, Duration::from_millis(100))
            .unwrap();
        shared.metrics[idx]
            .idle_nanos
            .fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }
}

fn execute(shared: &Arc<Shared>, idx: usize, task: Task) {
    let sw = crate::util::timer::Stopwatch::start();
    let Task { job, done } = task;
    // A panicking task must not kill the worker or wedge its stage: catch
    // the unwind (the stage re-raises it on the submitting thread via the
    // task's empty result slot), and signal completion only after the job
    // and everything it borrowed have been dropped.
    let _ = catch_unwind(AssertUnwindSafe(job));
    let m = &shared.metrics[idx];
    m.tasks.fetch_add(1, Ordering::Relaxed);
    m.busy_nanos
        .fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
    if let Some(done) = done {
        done.signal();
    }
}

fn find_task(shared: &Arc<Shared>, idx: usize) -> Option<Task> {
    if let Some(t) = shared.queues[idx].pop() {
        return Some(t);
    }
    if let Some(t) = shared.injector.steal() {
        return Some(t);
    }
    let n = shared.queues.len();
    for k in 1..n {
        if let Some(t) = shared.queues[(idx + k) % n].steal() {
            shared.metrics[idx].steals.fetch_add(1, Ordering::Relaxed);
            return Some(t);
        }
    }
    None
}
