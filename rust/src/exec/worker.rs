//! Worker thread loop + per-worker execution metrics.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::pool::{Shared, Task};

/// Per-worker counters, written by the worker thread with relaxed atomics
/// and snapshotted by [`super::ThreadPool::worker_stats`].
#[derive(Default)]
pub struct WorkerMetrics {
    pub tasks: AtomicU64,
    /// Successful steals from sibling deques.
    pub steals: AtomicU64,
    /// Sibling-scan rounds entered (whether or not anything was found).
    pub steal_attempts: AtomicU64,
    /// Times this worker parked on the condvar.
    pub parks: AtomicU64,
    /// Tasks taken from the shared injector.
    pub injector_pops: AtomicU64,
    /// Tasks whose job panicked (caught; reported via the owning stage).
    pub panics: AtomicU64,
    pub busy_nanos: AtomicU64,
    pub idle_nanos: AtomicU64,
}

/// Read-only snapshot of one worker's metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerStats {
    pub worker: usize,
    pub tasks: u64,
    pub steals: u64,
    pub steal_attempts: u64,
    pub parks: u64,
    pub injector_pops: u64,
    pub panics: u64,
    pub busy_nanos: u64,
    pub idle_nanos: u64,
}

impl WorkerMetrics {
    pub fn snapshot(&self, worker: usize) -> WorkerStats {
        WorkerStats {
            worker,
            tasks: self.tasks.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            steal_attempts: self.steal_attempts.load(Ordering::Relaxed),
            parks: self.parks.load(Ordering::Relaxed),
            injector_pops: self.injector_pops.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            busy_nanos: self.busy_nanos.load(Ordering::Relaxed),
            idle_nanos: self.idle_nanos.load(Ordering::Relaxed),
        }
    }
}

thread_local! {
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True when the current thread is a pool worker. Scoped stages use this
/// to run nested stages inline instead of re-submitting to the pool (which
/// could deadlock a task that blocks on its own pool).
pub fn is_pool_thread() -> bool {
    IS_POOL_WORKER.with(|f| f.get())
}

/// Render a panic payload as text (for [`super::ExecError`]).
pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// The worker main loop: drain own deque (LIFO), then the shared injector,
/// then steal from siblings (FIFO); park when there is nothing anywhere.
pub(crate) fn run(shared: Arc<Shared>, idx: usize) {
    IS_POOL_WORKER.with(|f| f.set(true));
    loop {
        if let Some(task) = find_task(&shared, idx) {
            execute(&shared, idx, task);
            continue;
        }
        // Park. The lock-ordering dance matters: submitters notify — and
        // pool shutdown both stores its flag and notifies — while holding
        // `park_lock`, and we re-check both conditions while holding it,
        // so neither a task pushed nor a shutdown raised between our
        // failed scan and the wait can be missed.
        let guard = shared.park_lock.lock();
        if shared.is_shutdown() {
            break;
        }
        if shared.has_work() {
            continue;
        }
        shared.metrics[idx].parks.fetch_add(1, Ordering::Relaxed);
        let tracer = shared.tracer();
        let t0 = tracer.start();
        let sw = crate::util::timer::Stopwatch::start();
        // Timeout is belt-and-braces only; correctness comes from the
        // re-check above.
        let (g, _timed_out) =
            shared
                .park_lock
                .wait_timeout(&shared.park_cv, guard, Duration::from_millis(100));
        drop(g);
        shared.metrics[idx]
            .idle_nanos
            .fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
        if let Some(t0) = t0 {
            tracer.span("park", "exec", idx as u32 + 1, t0, &[]);
        }
    }
}

fn execute(shared: &Arc<Shared>, idx: usize, task: Task) {
    let tracer = shared.tracer();
    let t0 = tracer.start();
    let sw = crate::util::timer::Stopwatch::start();
    let Task {
        job,
        label,
        enqueued_ns,
        done,
    } = task;
    // A panicking task must not kill the worker or wedge its stage: catch
    // the unwind (the stage surfaces it via the completion's panic slot),
    // and signal completion only after the job and everything it borrowed
    // have been dropped.
    let result = catch_unwind(AssertUnwindSafe(job));
    let m = &shared.metrics[idx];
    m.tasks.fetch_add(1, Ordering::Relaxed);
    m.busy_nanos
        .fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
    if let Err(payload) = &result {
        m.panics.fetch_add(1, Ordering::Relaxed);
        if let Some(done) = done.as_ref() {
            done.record_panic(panic_message(payload.as_ref()));
        }
    }
    if let Some(t0) = t0 {
        let name = match &label {
            Some(l) => format!("task:{l}"),
            None => "task".to_string(),
        };
        let queue_wait_ms = enqueued_ns
            .map(|e| t0.saturating_sub(e) as f64 / 1e6)
            .unwrap_or(0.0);
        tracer.span(
            name,
            "exec",
            idx as u32 + 1,
            t0,
            &[("queue_wait_ms", queue_wait_ms)],
        );
    }
    if let Some(done) = done {
        done.signal();
    }
}

fn find_task(shared: &Arc<Shared>, idx: usize) -> Option<Task> {
    if let Some(t) = shared.queues[idx].pop() {
        return Some(t);
    }
    if let Some(t) = shared.injector.steal() {
        shared.metrics[idx]
            .injector_pops
            .fetch_add(1, Ordering::Relaxed);
        return Some(t);
    }
    let n = shared.queues.len();
    if n > 1 {
        shared.metrics[idx]
            .steal_attempts
            .fetch_add(1, Ordering::Relaxed);
    }
    for k in 1..n {
        if let Some(t) = shared.queues[(idx + k) % n].steal() {
            shared.metrics[idx].steals.fetch_add(1, Ordering::Relaxed);
            return Some(t);
        }
    }
    None
}
