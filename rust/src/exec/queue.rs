//! Per-worker task deques + the shared injector queue.
//!
//! Each worker owns one [`TaskQueue`]. The owner pushes and pops at the
//! *back* (LIFO: freshly-submitted partition tasks stay cache-warm);
//! thieves steal from the *front* (FIFO: the oldest — and on skewed
//! stages, typically the largest-remaining — work migrates first). This is
//! the classic work-stealing discipline (Chase–Lev), implemented over a
//! `Mutex<VecDeque>` rather than a lock-free ring: partition tasks here are
//! milliseconds, not nanoseconds, so queue overhead is irrelevant and the
//! mutex keeps the code obviously correct.

use std::collections::VecDeque;

use super::pool::Task;
use crate::util::lockdep::TrackedMutex;

/// A mutex-protected double-ended task queue. The mutex is a
/// [`TrackedMutex`] so debug builds order-check every acquisition; queue
/// locks are leaves (each op locks and releases without nesting), so the
/// tracker only ever records edges *into* them.
pub struct TaskQueue {
    inner: TrackedMutex<VecDeque<Task>>,
}

impl Default for TaskQueue {
    fn default() -> TaskQueue {
        TaskQueue::new()
    }
}

impl TaskQueue {
    pub fn new() -> TaskQueue {
        TaskQueue {
            inner: TrackedMutex::new("exec.queue", VecDeque::new()),
        }
    }

    /// Owner-side push (back of the deque).
    pub(crate) fn push(&self, task: Task) {
        self.inner.lock().push_back(task);
    }

    /// Owner-side pop (back of the deque, LIFO).
    pub(crate) fn pop(&self) -> Option<Task> {
        self.inner.lock().pop_back()
    }

    /// Thief-side steal (front of the deque, FIFO).
    pub(crate) fn steal(&self) -> Option<Task> {
        self.inner.lock().pop_front()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noop() -> Task {
        Task::detached(Box::new(|| {}))
    }

    #[test]
    fn lifo_for_owner_fifo_for_thief() {
        let q = TaskQueue::new();
        assert!(q.is_empty());
        q.push(noop());
        q.push(noop());
        q.push(noop());
        assert_eq!(q.len(), 3);
        assert!(q.pop().is_some());
        assert!(q.steal().is_some());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none());
        assert!(q.steal().is_none());
    }
}
