//! `exec`: the multi-threaded work-stealing task executor.
//!
//! The paper's MLI sits on Spark precisely because a real execution engine
//! schedules one task per partition onto parallel workers. This module is
//! that substrate for our Spark surrogate: a fixed-size [`ThreadPool`]
//! with per-worker deques and work stealing ([`queue::TaskQueue`]), a
//! [`TaskSet`] abstraction for one-task-per-partition stages, and
//! per-worker execution metrics ([`WorkerStats`]: tasks run, steals,
//! busy/idle nanos) exportable into [`crate::metrics::Metrics`].
//!
//! Two layers attach a pool:
//!
//! * [`crate::engine::EngineContext::with_executor`] — `Dataset` actions
//!   (`collect`, `count`, `reduce`, `aggregate`, `materialize`) evaluate
//!   partitions in parallel.
//! * [`crate::cluster::SimCluster::with_executor`] — the algorithm hot
//!   loops (SGD/GD local steps, ALS factor solves, k-means stats) fan
//!   their per-partition tasks out.
//!
//! **Determinism contract:** scheduling order varies with thread count and
//! stealing, but every stage merges results *by task index*, so all
//! actions produce bitwise-identical results for any thread count
//! (including the serial no-pool path). Real wall-clock time shrinks;
//! *simulated* time (the `SimCluster` ledger) is unchanged by
//! construction — see `cluster/sim.rs` for the distinction.

pub mod pool;
pub mod queue;
pub mod worker;

pub use pool::{TaskSet, ThreadPool};
pub use queue::TaskQueue;
pub use worker::{is_pool_thread, WorkerStats};
