//! `exec`: the multi-threaded work-stealing task executor.
//!
//! The paper's MLI sits on Spark precisely because a real execution engine
//! schedules one task per partition onto parallel workers. This module is
//! that substrate for our Spark surrogate: a fixed-size [`ThreadPool`]
//! with per-worker deques and work stealing ([`queue::TaskQueue`]), a
//! [`TaskSet`] abstraction for one-task-per-partition stages, and
//! per-worker execution metrics ([`WorkerStats`]: tasks run, steals,
//! busy/idle nanos) exportable into [`crate::metrics::Metrics`].
//!
//! Two layers attach a pool:
//!
//! * [`crate::engine::EngineContext::with_executor`] — `Dataset` actions
//!   (`collect`, `count`, `reduce`, `aggregate`, `materialize`) evaluate
//!   partitions in parallel.
//! * [`crate::cluster::SimCluster::with_executor`] — the algorithm hot
//!   loops (SGD/GD local steps, ALS factor solves, k-means stats) fan
//!   their per-partition tasks out.
//!
//! **Determinism contract:** scheduling order varies with thread count and
//! stealing, but every stage merges results *by task index*, so all
//! actions produce bitwise-identical results for any thread count
//! (including the serial no-pool path). Real wall-clock time shrinks;
//! *simulated* time (the `SimCluster` ledger) is unchanged by
//! construction — see `cluster/sim.rs` for the distinction.
//!
//! **Failure contract:** a panicking task fails *its own* stage — the
//! panic is caught on the worker, surfaced as an [`ExecError`] from
//! [`TaskSet::try_run`] / [`ThreadPool::try_run`], and the pool keeps
//! running subsequent stages. Internal locks recover from poisoning
//! (every guarded structure is valid at every await point), so one bad
//! task can never abort the process via a poisoned mutex.
//!
//! **Observability:** attach a [`crate::trace::Tracer`] via
//! [`ThreadPool::set_tracer`] to record per-task spans (with queue-wait
//! attribution) and export per-worker counters (tasks, steals, steal
//! attempts, parks, injector pops, panics) with
//! [`ThreadPool::export_trace`].

pub mod pool;
pub mod queue;
pub mod worker;

use std::fmt;

pub use pool::{TaskSet, ThreadPool};
pub use queue::TaskQueue;
pub use worker::{is_pool_thread, WorkerStats};

// Poison-recovering lock helper, now shared repo-wide from `util`; the
// pool's internal hot-path mutexes additionally run under the debug-only
// lock-order cycle detector (`util::lockdep::TrackedMutex`).
pub(crate) use crate::util::lock_unpoisoned;

/// A task in a stage panicked. Carries the stage label and the panic
/// payload rendered as text.
#[derive(Debug, Clone)]
pub struct ExecError {
    pub stage: String,
    pub message: String,
}

impl fmt::Display for ExecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "stage '{}': task panicked: {}", self.stage, self.message)
    }
}

impl std::error::Error for ExecError {}

impl From<ExecError> for crate::error::Error {
    fn from(e: ExecError) -> Self {
        crate::error::Error::Exec(e.to_string())
    }
}
