//! The work-stealing thread pool and its scoped stage API.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::queue::TaskQueue;
use super::worker::{self, WorkerMetrics, WorkerStats};
use super::{lock_unpoisoned, ExecError};
use crate::metrics::Metrics;
use crate::trace::{TraceSink, Tracer};
use crate::util::lockdep::TrackedMutex;
use crate::util::timer::Stopwatch;

/// A unit of work: the boxed job plus an optional stage label (for trace
/// spans), the enqueue timestamp (for queue-wait attribution) and an
/// optional stage-completion handle. The worker signals `done` strictly
/// *after* the job (and everything it borrowed) has been dropped — that
/// ordering is what makes the scoped lifetime erasure in
/// [`ThreadPool::run`] sound.
pub struct Task {
    pub(crate) job: Box<dyn FnOnce() + Send + 'static>,
    pub(crate) label: Option<Arc<str>>,
    pub(crate) enqueued_ns: Option<u64>,
    pub(crate) done: Option<Arc<Completion>>,
}

impl Task {
    /// A fire-and-forget task (no stage tracking).
    pub(crate) fn detached(job: Box<dyn FnOnce() + Send + 'static>) -> Task {
        Task {
            job,
            label: None,
            enqueued_ns: None,
            done: None,
        }
    }
}

/// Countdown latch for one scoped stage, plus the first panic message any
/// of the stage's tasks produced (workers catch the unwind and record it
/// here; the submitting thread turns it into an [`ExecError`]).
pub(crate) struct Completion {
    remaining: TrackedMutex<usize>,
    cv: Condvar,
    panic: TrackedMutex<Option<String>>,
}

impl Completion {
    fn new(n: usize) -> Completion {
        Completion {
            remaining: TrackedMutex::new("exec.completion.remaining", n),
            cv: Condvar::new(),
            panic: TrackedMutex::new("exec.completion.panic", None),
        }
    }

    /// Record a panic message for the stage (first one wins).
    pub(crate) fn record_panic(&self, msg: String) {
        let mut p = self.panic.lock();
        if p.is_none() {
            *p = Some(msg);
        }
    }

    fn take_panic(&self) -> Option<String> {
        self.panic.lock().take()
    }

    pub(crate) fn signal(&self) {
        let mut r = self.remaining.lock();
        *r = r.saturating_sub(1);
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock();
        while *r > 0 {
            r = self.remaining.wait(&self.cv, r);
        }
    }
}

/// State shared between the pool handle and its workers.
pub(crate) struct Shared {
    pub(crate) queues: Vec<TaskQueue>,
    pub(crate) injector: TaskQueue,
    pub(crate) metrics: Vec<WorkerMetrics>,
    pub(crate) park_lock: TrackedMutex<()>,
    pub(crate) park_cv: Condvar,
    tracer: TrackedMutex<Arc<Tracer>>,
    shutdown: AtomicBool,
}

impl Shared {
    pub(crate) fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    pub(crate) fn has_work(&self) -> bool {
        !self.injector.is_empty() || self.queues.iter().any(|q| !q.is_empty())
    }

    pub(crate) fn tracer(&self) -> Arc<Tracer> {
        self.tracer.lock().clone()
    }
}

/// A fixed-size work-stealing thread pool.
///
/// Submission round-robins tasks across per-worker deques; idle workers
/// steal from the shared injector and from each other (see
/// [`super::queue::TaskQueue`] for the stealing discipline). Dropping the
/// pool shuts the workers down and joins them.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: TrackedMutex<Vec<JoinHandle<()>>>,
    next: AtomicUsize,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Arc<ThreadPool> {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queues: (0..threads).map(|_| TaskQueue::new()).collect(),
            injector: TaskQueue::new(),
            metrics: (0..threads).map(|_| WorkerMetrics::default()).collect(),
            park_lock: TrackedMutex::new("exec.park", ()),
            park_cv: Condvar::new(),
            tracer: TrackedMutex::new("exec.tracer", Tracer::disabled()),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..threads)
            .map(|idx| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("mli-exec-{idx}"))
                    .spawn(move || worker::run(shared, idx))
                    .expect("spawn exec worker")
            })
            .collect();
        Arc::new(ThreadPool {
            shared,
            handles: TrackedMutex::new("exec.handles", handles),
            next: AtomicUsize::new(0),
        })
    }

    /// Number of worker threads available to this process, for
    /// `--threads 0` style "use the whole machine" defaults.
    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    pub fn threads(&self) -> usize {
        self.shared.queues.len()
    }

    /// Attach a tracer: workers record per-task spans (with queue-wait
    /// attribution) and park spans into it. A disabled tracer (the
    /// default) costs one relaxed load per task.
    pub fn set_tracer(&self, tracer: Arc<Tracer>) {
        *self.shared.tracer.lock() = tracer;
    }

    pub fn tracer(&self) -> Arc<Tracer> {
        self.shared.tracer()
    }

    /// Fire-and-forget submission (no result, no stage tracking). Goes
    /// through the shared injector so any idle worker picks it up (the
    /// `injector_pops` counter attributes it).
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.injector.push(Task::detached(Box::new(job)));
        let _g = self.shared.park_lock.lock();
        self.shared.park_cv.notify_all();
    }

    /// Next worker index for round-robin submission. `fetch_update` keeps
    /// the counter inside `0..threads` so the distribution stays uniform
    /// across wraparound for any thread count: the previous
    /// `fetch_add(1) % n` skewed toward low indices after the counter
    /// wrapped at `usize::MAX` whenever `n` is not a power of two.
    fn next_index(&self) -> usize {
        let n = self.threads();
        self.next
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.wrapping_add(1) % n)
            })
            .unwrap_or(0)
            % n
    }

    fn submit(&self, task: Task) {
        let i = self.next_index();
        self.shared.queues[i].push(task);
        let _g = self.shared.park_lock.lock();
        self.shared.park_cv.notify_all();
    }

    /// Run `f(0), f(1), …, f(n-1)` on the pool and return the results in
    /// index order. Blocks until every task has finished, which is what
    /// allows `f` to borrow from the caller's stack (the closure is
    /// lifetime-erased internally; a completion latch signalled only after
    /// each job is dropped guarantees no borrow outlives this call).
    ///
    /// Deterministic by construction: task *scheduling* order varies with
    /// thread count and stealing, but results are placed by index, so the
    /// returned vector is identical for any pool size.
    ///
    /// Calling this from inside a pool task runs the stage inline (serial)
    /// instead of re-submitting — nested stages cannot deadlock the pool.
    ///
    /// If a task panics, this re-raises after the whole stage has drained.
    /// Prefer [`ThreadPool::try_run`] where the caller can handle errors.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        match self.try_run_labeled("run", n, f) {
            Ok(v) => v,
            Err(e) => panic!("exec: {e}"),
        }
    }

    /// Like [`ThreadPool::run`], but a panicking task surfaces as an
    /// [`ExecError`] for this stage instead of unwinding. The pool stays
    /// fully usable for subsequent stages either way.
    pub fn try_run<T, F>(&self, n: usize, f: F) -> std::result::Result<Vec<T>, ExecError>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.try_run_labeled("run", n, f)
    }

    pub(crate) fn try_run_labeled<T, F>(
        &self,
        label: &str,
        n: usize,
        f: F,
    ) -> std::result::Result<Vec<T>, ExecError>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Ok(Vec::new());
        }
        if worker::is_pool_thread() {
            // Nested stage: run inline (serial) to avoid self-deadlock,
            // with the same failure contract — a panicking task fails this
            // stage, not the worker it runs on.
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))) {
                    Ok(v) => out.push(v),
                    Err(p) => {
                        return Err(ExecError {
                            stage: label.to_string(),
                            message: worker::panic_message(p.as_ref()),
                        })
                    }
                }
            }
            return Ok(out);
        }
        let tracer = self.tracer();
        let stage_start = tracer.start();
        let task_label: Arc<str> = Arc::from(label);
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let done = Arc::new(Completion::new(n));
        {
            let f = &f;
            let slots = &slots;
            for i in 0..n {
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let r = f(i);
                    *lock_unpoisoned(&slots[i]) = Some(r);
                });
                // SAFETY: lifetime erasure to 'static. The job borrows only
                // `f` and `slots`, both alive until this function returns;
                // `done.wait()` below blocks until every worker has dropped
                // its job (workers signal the latch strictly after the job
                // is consumed), so no borrow escapes this call.
                let job: Box<dyn FnOnce() + Send + 'static> =
                    unsafe { std::mem::transmute(job) };
                self.submit(Task {
                    job,
                    label: Some(task_label.clone()),
                    enqueued_ns: tracer.start(),
                    done: Some(done.clone()),
                });
            }
        }
        done.wait();
        if let Some(t0) = stage_start {
            tracer.span(
                format!("stage:{label}"),
                "exec",
                0,
                t0,
                &[("tasks", n as f64)],
            );
        }
        if let Some(msg) = done.take_panic() {
            return Err(ExecError {
                stage: label.to_string(),
                message: msg,
            });
        }
        let mut out = Vec::with_capacity(n);
        for m in slots {
            match m.into_inner().unwrap_or_else(|e| e.into_inner()) {
                Some(v) => out.push(v),
                None => {
                    return Err(ExecError {
                        stage: label.to_string(),
                        message: "task produced no result".to_string(),
                    })
                }
            }
        }
        Ok(out)
    }

    /// Speculative variant of [`ThreadPool::try_run`] (Spark's
    /// `spark.speculation` in miniature). Tasks are `f(i, attempt)` with
    /// `attempt == 0` for the original copies. Once at least half the
    /// stage has finished, any task still outstanding after `threshold` x
    /// the median finished-task wall time gets one backup copy
    /// (`attempt == 1`) resubmitted to the pool. Results stay
    /// deterministic for any timing: each index keeps the result of its
    /// LOWEST-numbered attempt, and the stage drains every copy before
    /// returning, so the output is identical to the non-speculative path
    /// whenever `f(i, _)` ignores the attempt number in its return value.
    pub fn try_run_speculative<T, F>(
        &self,
        label: &str,
        n: usize,
        threshold: f64,
        f: F,
    ) -> std::result::Result<Vec<T>, ExecError>
    where
        T: Send,
        F: Fn(usize, usize) -> T + Sync,
    {
        assert!(threshold > 1.0, "speculation threshold must exceed 1.0");
        if n == 0 {
            return Ok(Vec::new());
        }
        if worker::is_pool_thread() {
            // nested stage: run originals inline (serial); there is no
            // straggling worker to speculate against
            let mut out = Vec::with_capacity(n);
            for i in 0..n {
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i, 0))) {
                    Ok(v) => out.push(v),
                    Err(p) => {
                        return Err(ExecError {
                            stage: label.to_string(),
                            message: worker::panic_message(p.as_ref()),
                        })
                    }
                }
            }
            return Ok(out);
        }
        struct SpecState {
            /// Per-index: some attempt has finished (success or panic).
            done: Vec<bool>,
            completed: usize,
            /// Wall times of first-finishing attempts, for the median.
            finished_secs: Vec<f64>,
            /// Per-index: a backup copy was already launched.
            launched: Vec<bool>,
            /// Backups that finished before their original.
            wins: u64,
            panic: Option<String>,
        }
        let tracer = self.tracer();
        let stage_start = tracer.start();
        let task_label: Arc<str> = Arc::from(label);
        // each slot keeps (attempt, result) of the lowest attempt seen
        let slots: Vec<Mutex<Option<(usize, T)>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let state = (
            TrackedMutex::new("exec.spec.state", SpecState {
                done: vec![false; n],
                completed: 0,
                finished_secs: Vec::with_capacity(n),
                launched: vec![false; n],
                wins: 0,
                panic: None,
            }),
            Condvar::new(),
        );
        let mut completions: Vec<Arc<Completion>> = Vec::new();
        let mut spec_launched = 0u64;
        {
            let f = &f;
            let slots = &slots;
            let state = &state;
            let tracer_ref = &tracer;
            let task_label = &task_label;
            let submit_attempt =
                |this: &ThreadPool, i: usize, attempt: usize, cs: &mut Vec<Arc<Completion>>| {
                    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        let sw = Stopwatch::start();
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            f(i, attempt)
                        }));
                        let (lock, cv) = state;
                        match r {
                            Ok(v) => {
                                let backup_first = {
                                    let mut slot = lock_unpoisoned(&slots[i]);
                                    let was_empty = slot.is_none();
                                    let replace = match &*slot {
                                        Some((a, _)) => attempt < *a,
                                        None => true,
                                    };
                                    if replace {
                                        *slot = Some((attempt, v));
                                    }
                                    was_empty && attempt > 0
                                };
                                let mut st = lock.lock();
                                if backup_first {
                                    st.wins += 1;
                                }
                                if !st.done[i] {
                                    st.done[i] = true;
                                    st.completed += 1;
                                    st.finished_secs.push(sw.elapsed_secs());
                                }
                                cv.notify_all();
                            }
                            Err(p) => {
                                let mut st = lock.lock();
                                if st.panic.is_none() {
                                    st.panic = Some(worker::panic_message(p.as_ref()));
                                }
                                if !st.done[i] {
                                    st.done[i] = true;
                                    st.completed += 1;
                                }
                                cv.notify_all();
                            }
                        }
                    });
                    // SAFETY: lifetime erasure to 'static under the same
                    // contract as `try_run_labeled`: the job borrows only
                    // `f`, `slots` and `state`, all alive until this
                    // function returns, and every per-attempt completion
                    // latch below is waited on before returning (workers
                    // signal strictly after dropping the job), so no borrow
                    // escapes this call.
                    let job: Box<dyn FnOnce() + Send + 'static> =
                        unsafe { std::mem::transmute(job) };
                    let done = Arc::new(Completion::new(1));
                    cs.push(done.clone());
                    this.submit(Task {
                        job,
                        label: Some(task_label.clone()),
                        enqueued_ns: tracer_ref.start(),
                        done: Some(done),
                    });
                };
            for i in 0..n {
                submit_attempt(self, i, 0, &mut completions);
            }
            let stage_sw = Stopwatch::start();
            loop {
                let to_speculate: Vec<usize> = {
                    let st = state.0.lock();
                    if st.completed >= n {
                        break;
                    }
                    let (mut st, _timeout) =
                        state
                            .0
                            .wait_timeout(&state.1, st, std::time::Duration::from_millis(2));
                    if st.completed >= n {
                        break;
                    }
                    // speculate only once a majority has finished (a
                    // meaningful median exists) and the stage has run past
                    // threshold x that median
                    if st.completed < (n / 2).max(1) {
                        continue;
                    }
                    let med = crate::util::median(&st.finished_secs);
                    if med <= 0.0 || stage_sw.elapsed_secs() < threshold * med {
                        continue;
                    }
                    let mut picks = Vec::new();
                    for i in 0..n {
                        if !st.done[i] && !st.launched[i] {
                            st.launched[i] = true;
                            picks.push(i);
                        }
                    }
                    picks
                };
                for i in to_speculate {
                    spec_launched += 1;
                    submit_attempt(self, i, 1, &mut completions);
                }
            }
        }
        // drain every attempt before touching borrowed state (soundness);
        // losing backups are simply discarded by the lowest-attempt rule
        for c in &completions {
            c.wait();
        }
        let (wins, panic) = {
            let st = state.0.lock();
            (st.wins, st.panic.clone())
        };
        if let Some(t0) = stage_start {
            tracer.span(
                format!("stage:{label}"),
                "exec",
                0,
                t0,
                &[("tasks", n as f64), ("speculated", spec_launched as f64)],
            );
            if spec_launched > 0 {
                tracer.count("exec.spec.launched", spec_launched);
                tracer.count("exec.spec.wins", wins);
                tracer.count("exec.spec.losses", spec_launched.saturating_sub(wins));
            }
        }
        if let Some(msg) = panic {
            return Err(ExecError {
                stage: label.to_string(),
                message: msg,
            });
        }
        let mut out = Vec::with_capacity(n);
        for m in slots {
            match m.into_inner().unwrap_or_else(|e| e.into_inner()) {
                Some((_, v)) => out.push(v),
                None => {
                    return Err(ExecError {
                        stage: label.to_string(),
                        message: "task produced no result".to_string(),
                    })
                }
            }
        }
        Ok(out)
    }

    /// Snapshot the per-worker metrics.
    pub fn worker_stats(&self) -> Vec<WorkerStats> {
        self.shared
            .metrics
            .iter()
            .enumerate()
            .map(|(i, m)| m.snapshot(i))
            .collect()
    }

    /// Export per-worker + aggregate counters into a [`Metrics`] registry
    /// (`exec.workerN.*` and `exec.total.*`).
    pub fn export_metrics(&self, m: &Metrics) {
        let mut tot_tasks = 0;
        let mut tot_steals = 0;
        let mut tot_busy = 0;
        let mut tot_idle = 0;
        for s in self.worker_stats() {
            m.add(&format!("exec.worker{}.tasks", s.worker), s.tasks);
            m.add(&format!("exec.worker{}.steals", s.worker), s.steals);
            m.add(
                &format!("exec.worker{}.steal_attempts", s.worker),
                s.steal_attempts,
            );
            m.add(&format!("exec.worker{}.parks", s.worker), s.parks);
            m.add(
                &format!("exec.worker{}.injector_pops", s.worker),
                s.injector_pops,
            );
            m.add(&format!("exec.worker{}.panics", s.worker), s.panics);
            m.add(&format!("exec.worker{}.busy_nanos", s.worker), s.busy_nanos);
            m.add(&format!("exec.worker{}.idle_nanos", s.worker), s.idle_nanos);
            tot_tasks += s.tasks;
            tot_steals += s.steals;
            tot_busy += s.busy_nanos;
            tot_idle += s.idle_nanos;
        }
        m.add("exec.total.tasks", tot_tasks);
        m.add("exec.total.steals", tot_steals);
        m.add("exec.total.busy_nanos", tot_busy);
        m.add("exec.total.idle_nanos", tot_idle);
    }

    /// Export per-worker counters into a trace sink
    /// (`exec.workerN.{tasks,steals,steal_attempts,parks,injector_pops,panics}`).
    pub fn export_trace(&self, sink: &dyn TraceSink) {
        for s in self.worker_stats() {
            let w = s.worker;
            sink.add_counter(&format!("exec.worker{w}.tasks"), s.tasks);
            sink.add_counter(&format!("exec.worker{w}.steals"), s.steals);
            sink.add_counter(&format!("exec.worker{w}.steal_attempts"), s.steal_attempts);
            sink.add_counter(&format!("exec.worker{w}.parks"), s.parks);
            sink.add_counter(&format!("exec.worker{w}.injector_pops"), s.injector_pops);
            sink.add_counter(&format!("exec.worker{w}.panics"), s.panics);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            // Raise the flag *inside* the park critical section: any worker
            // holding `park_lock` has either already observed shutdown or is
            // about to wait on `park_cv` (releasing the lock atomically with
            // the wait), so the notify below cannot land in the window
            // between a worker's shutdown check and its park.
            let _g = self.shared.park_lock.lock();
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.park_cv.notify_all();
        }
        for h in self.handles.lock().drain(..) {
            let _ = h.join();
        }
    }
}

/// One-task-per-partition stage descriptor: the unit the engine and the
/// algorithm layer hand to the executor (a Spark `TaskSet` in miniature —
/// one stage, `tasks` tasks, results merged by task index).
pub struct TaskSet {
    label: String,
    tasks: usize,
}

impl TaskSet {
    pub fn new(label: impl Into<String>, tasks: usize) -> TaskSet {
        TaskSet {
            label: label.into(),
            tasks,
        }
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn len(&self) -> usize {
        self.tasks
    }

    pub fn is_empty(&self) -> bool {
        self.tasks == 0
    }

    /// Run the stage: on `Some(pool)` the tasks execute in parallel with
    /// work stealing; on `None` they run serially on the calling thread.
    /// Either way the results come back in task-index order, so callers
    /// merge deterministically regardless of thread count.
    ///
    /// A panicking task re-raises here; prefer [`TaskSet::try_run`] where
    /// the caller can propagate errors.
    pub fn run<T, F>(&self, pool: Option<&ThreadPool>, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        match pool {
            Some(pool) => match pool.try_run_labeled(&self.label, self.tasks, f) {
                Ok(v) => v,
                Err(e) => panic!("exec: {e}"),
            },
            None => (0..self.tasks).map(f).collect(),
        }
    }

    /// Run the stage, surfacing a panicking task as a typed error for
    /// *this stage* instead of unwinding: the pool (or the serial caller)
    /// stays fully usable for subsequent stages.
    pub fn try_run<T, F>(&self, pool: Option<&ThreadPool>, f: F) -> crate::error::Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        match pool {
            Some(pool) => Ok(pool.try_run_labeled(&self.label, self.tasks, f)?),
            None => {
                let mut out = Vec::with_capacity(self.tasks);
                for i in 0..self.tasks {
                    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))) {
                        Ok(v) => out.push(v),
                        Err(p) => {
                            return Err(ExecError {
                                stage: self.label.clone(),
                                message: worker::panic_message(p.as_ref()),
                            }
                            .into())
                        }
                    }
                }
                Ok(out)
            }
        }
    }

    /// Speculative variant of [`TaskSet::try_run`]: tasks are
    /// `f(i, attempt)` and stragglers past `threshold` x the stage median
    /// get one backup copy (see [`ThreadPool::try_run_speculative`]).
    /// Serial (no pool) runs originals only — there is nothing to
    /// speculate against on one thread.
    pub fn try_run_speculative<T, F>(
        &self,
        pool: Option<&ThreadPool>,
        threshold: f64,
        f: F,
    ) -> crate::error::Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize, usize) -> T + Sync,
    {
        match pool {
            Some(pool) => {
                Ok(pool.try_run_speculative(&self.label, self.tasks, threshold, f)?)
            }
            None => self.try_run(None, |i| f(i, 0)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_returns_results_in_index_order() {
        let pool = ThreadPool::new(4);
        let out = pool.run(64, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn run_borrows_caller_stack() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..100).collect();
        let sums = pool.run(10, |i| data[i * 10..(i + 1) * 10].iter().sum::<u64>());
        assert_eq!(sums.iter().sum::<u64>(), 4950);
    }

    #[test]
    fn single_thread_pool_matches_serial() {
        let p1 = ThreadPool::new(1);
        let p4 = ThreadPool::new(4);
        let serial: Vec<u64> = (0..33).map(|i| i as u64 * 7 + 1).collect();
        assert_eq!(p1.run(33, |i| i as u64 * 7 + 1), serial);
        assert_eq!(p4.run(33, |i| i as u64 * 7 + 1), serial);
    }

    #[test]
    fn nested_run_from_worker_is_inline() {
        let pool = ThreadPool::new(2);
        let pool2 = pool.clone();
        let out = pool.run(4, move |i| pool2.run(3, |j| i * 10 + j));
        assert_eq!(out[2], vec![20, 21, 22]);
    }

    #[test]
    fn metrics_count_tasks() {
        let pool = ThreadPool::new(2);
        let _ = pool.run(20, |i| i);
        let stats = pool.worker_stats();
        let total: u64 = stats.iter().map(|s| s.tasks).sum();
        assert_eq!(total, 20);
        let m = Metrics::default();
        pool.export_metrics(&m);
        assert_eq!(m.counter("exec.total.tasks"), 20);
    }

    #[test]
    fn spawn_fire_and_forget() {
        let pool = ThreadPool::new(2);
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let hits = hits.clone();
            pool.spawn(move || {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        for _ in 0..1000 {
            if hits.load(Ordering::SeqCst) == 8 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(hits.load(Ordering::SeqCst), 8);
        // spawn routes through the shared injector, so the pops counter
        // attributes every one of them
        let pops: u64 = pool.worker_stats().iter().map(|s| s.injector_pops).sum();
        assert_eq!(pops, 8);
    }

    #[test]
    fn panic_in_task_propagates_after_stage_drains() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, |i| {
                if i == 2 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(r.is_err());
        // pool still usable afterwards
        assert_eq!(pool.run(3, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn try_run_surfaces_panic_as_error_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let r = pool.try_run(8, |i| {
            if i == 3 {
                panic!("injected task panic");
            }
            i * 2
        });
        let e = r.expect_err("stage with a panicking task must fail");
        assert!(e.to_string().contains("injected task panic"), "{e}");
        // subsequent stages keep executing on the same pool — no poisoned
        // lock, no dead worker
        for _ in 0..3 {
            assert_eq!(pool.run(4, |i| i + 1), vec![1, 2, 3, 4]);
        }
    }

    #[test]
    fn taskset_try_run_serial_catches_panic() {
        let ts = TaskSet::new("bad-stage", 4);
        let r = ts.try_run::<usize, _>(None, |i| {
            if i == 1 {
                panic!("serial boom");
            }
            i
        });
        let e = r.expect_err("serial stage with a panicking task must fail");
        let msg = e.to_string();
        assert!(msg.contains("bad-stage") && msg.contains("serial boom"), "{msg}");
    }

    #[test]
    fn submit_distribution_uniform_across_wraparound() {
        // 3 workers (not a power of two): the old `fetch_add(1) % n`
        // scheme hands out `(usize::MAX - 1) % 3 == 2`, `usize::MAX % 3
        // == 0`, `0 % 3 == 0` back to back across wraparound — worker 0
        // gets a double share. `next_index` keeps the counter in `0..n`.
        let pool = ThreadPool::new(3);
        pool.next.store(usize::MAX - 1, Ordering::Relaxed);
        let mut counts = [0usize; 3];
        for _ in 0..9 {
            counts[pool.next_index()] += 1;
        }
        assert_eq!(counts, [3, 3, 3]);
    }

    #[test]
    fn repeated_shutdown_under_load_terminates() {
        // Regression guard for the shutdown–park race: create/load/drop
        // pools repeatedly; a missed wakeup would hang the join in Drop.
        // The watchdog turns a hang into a failure instead of wedging the
        // whole test run.
        let work = std::thread::spawn(|| {
            for round in 0..60usize {
                let pool = ThreadPool::new(4);
                for _ in 0..8 {
                    pool.spawn(|| {
                        std::hint::black_box(());
                    });
                }
                let _ = pool.run(16, |i| i + round);
                drop(pool);
            }
        });
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        while !work.is_finished() {
            assert!(
                std::time::Instant::now() < deadline,
                "shutdown under load hung (park/shutdown race)"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        work.join().unwrap();
    }

    #[test]
    fn parks_and_steal_attempts_counted() {
        let pool = ThreadPool::new(2);
        let _ = pool.run(4, |_| {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        // give the now-idle workers time to fail a scan and park
        std::thread::sleep(std::time::Duration::from_millis(30));
        let stats = pool.worker_stats();
        let parks: u64 = stats.iter().map(|s| s.parks).sum();
        let attempts: u64 = stats.iter().map(|s| s.steal_attempts).sum();
        assert!(parks > 0, "no parks recorded: {stats:?}");
        assert!(attempts > 0, "no steal attempts recorded: {stats:?}");
    }

    #[test]
    fn traced_run_records_task_spans_and_counters() {
        let (tracer, sink) = Tracer::recording();
        let pool = ThreadPool::new(2);
        pool.set_tracer(tracer);
        let ts = TaskSet::new("traced-stage", 6);
        let out = ts.try_run(Some(&pool), |i| i * i).unwrap();
        assert_eq!(out, (0..6).map(|i| i * i).collect::<Vec<_>>());
        let spans = sink.spans();
        let task_spans = spans
            .iter()
            .filter(|s| s.name == "task:traced-stage")
            .count();
        assert_eq!(task_spans, 6);
        assert!(
            spans.iter().any(|s| s.name == "stage:traced-stage"),
            "stage span missing"
        );
        pool.export_trace(sink.as_ref());
        let tasks = sink.counter("exec.worker0.tasks") + sink.counter("exec.worker1.tasks");
        assert_eq!(tasks, 6);
    }

    #[test]
    fn speculative_run_matches_plain_run() {
        let pool = ThreadPool::new(4);
        let out = pool
            .try_run_speculative("spec", 32, 4.0, |i, _attempt| i * 3 + 1)
            .unwrap();
        assert_eq!(out, (0..32).map(|i| i * 3 + 1).collect::<Vec<_>>());
        // serial TaskSet path runs originals only
        let ts = TaskSet::new("spec-serial", 5);
        let serial = ts.try_run_speculative(None, 2.0, |i, a| i * 10 + a).unwrap();
        assert_eq!(serial, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn speculative_backup_launches_and_lowest_attempt_wins() {
        // Task 7's original sleeps far past threshold x median, so the
        // driver launches a backup (attempt 1) that finishes first. The
        // lowest-attempt rule still selects the original's result, so the
        // output is bitwise-identical to a non-speculative run.
        let pool = ThreadPool::new(4);
        let (tracer, sink) = Tracer::recording();
        pool.set_tracer(tracer);
        let out = pool
            .try_run_speculative("straggle", 8, 2.0, |i, attempt| {
                if i == 7 && attempt == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(150));
                } else {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                i * 10 + attempt
            })
            .unwrap();
        assert_eq!(out, (0..8).map(|i| i * 10).collect::<Vec<_>>());
        assert!(
            sink.counter("exec.spec.launched") >= 1,
            "straggler never got a backup copy"
        );
    }

    #[test]
    fn speculative_run_surfaces_panic_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let r = pool.try_run_speculative("spec-boom", 6, 3.0, |i, _a| {
            if i == 4 {
                panic!("speculative boom");
            }
            i
        });
        let e = r.expect_err("stage with a panicking task must fail");
        assert!(e.to_string().contains("speculative boom"), "{e}");
        assert_eq!(pool.run(3, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn taskset_serial_and_parallel_agree() {
        let pool = ThreadPool::new(4);
        let ts = TaskSet::new("stage", 17);
        assert_eq!(ts.label(), "stage");
        assert_eq!(ts.len(), 17);
        assert!(!ts.is_empty());
        let serial = ts.run::<usize, _>(None, |i| i * 3);
        let parallel = ts.run(Some(&pool), |i| i * 3);
        assert_eq!(serial, parallel);
    }
}
