//! The work-stealing thread pool and its scoped stage API.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::queue::TaskQueue;
use super::worker::{self, WorkerMetrics, WorkerStats};
use crate::metrics::Metrics;

/// A unit of work: the boxed job plus an optional stage-completion handle.
/// The worker signals `done` strictly *after* the job (and everything it
/// borrowed) has been dropped — that ordering is what makes the scoped
/// lifetime erasure in [`ThreadPool::run`] sound.
pub struct Task {
    pub(crate) job: Box<dyn FnOnce() + Send + 'static>,
    pub(crate) done: Option<Arc<Completion>>,
}

impl Task {
    /// A fire-and-forget task (no stage tracking).
    pub(crate) fn detached(job: Box<dyn FnOnce() + Send + 'static>) -> Task {
        Task { job, done: None }
    }
}

/// Countdown latch for one scoped stage.
pub(crate) struct Completion {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Completion {
    fn new(n: usize) -> Completion {
        Completion {
            remaining: Mutex::new(n),
            cv: Condvar::new(),
        }
    }

    pub(crate) fn signal(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.cv.wait(r).unwrap();
        }
    }
}

/// State shared between the pool handle and its workers.
pub(crate) struct Shared {
    pub(crate) queues: Vec<TaskQueue>,
    pub(crate) injector: TaskQueue,
    pub(crate) metrics: Vec<WorkerMetrics>,
    pub(crate) park_lock: Mutex<()>,
    pub(crate) park_cv: Condvar,
    shutdown: AtomicBool,
}

impl Shared {
    pub(crate) fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
    }

    pub(crate) fn has_work(&self) -> bool {
        !self.injector.is_empty() || self.queues.iter().any(|q| !q.is_empty())
    }
}

/// A fixed-size work-stealing thread pool.
///
/// Submission round-robins tasks across per-worker deques; idle workers
/// steal from the shared injector and from each other (see
/// [`super::queue::TaskQueue`] for the stealing discipline). Dropping the
/// pool shuts the workers down and joins them.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    next: AtomicUsize,
}

impl ThreadPool {
    /// Spawn a pool with `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Arc<ThreadPool> {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queues: (0..threads).map(|_| TaskQueue::new()).collect(),
            injector: TaskQueue::new(),
            metrics: (0..threads).map(|_| WorkerMetrics::default()).collect(),
            park_lock: Mutex::new(()),
            park_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..threads)
            .map(|idx| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("mli-exec-{idx}"))
                    .spawn(move || worker::run(shared, idx))
                    .expect("spawn exec worker")
            })
            .collect();
        Arc::new(ThreadPool {
            shared,
            handles: Mutex::new(handles),
            next: AtomicUsize::new(0),
        })
    }

    /// Number of worker threads available to this process, for
    /// `--threads 0` style "use the whole machine" defaults.
    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    pub fn threads(&self) -> usize {
        self.shared.queues.len()
    }

    /// Fire-and-forget submission (no result, no stage tracking).
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.submit(Task::detached(Box::new(job)));
    }

    fn submit(&self, task: Task) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.threads();
        self.shared.queues[i].push(task);
        let _g = self.shared.park_lock.lock().unwrap();
        self.shared.park_cv.notify_all();
    }

    /// Run `f(0), f(1), …, f(n-1)` on the pool and return the results in
    /// index order. Blocks until every task has finished, which is what
    /// allows `f` to borrow from the caller's stack (the closure is
    /// lifetime-erased internally; a completion latch signalled only after
    /// each job is dropped guarantees no borrow outlives this call).
    ///
    /// Deterministic by construction: task *scheduling* order varies with
    /// thread count and stealing, but results are placed by index, so the
    /// returned vector is identical for any pool size.
    ///
    /// Calling this from inside a pool task runs the stage inline (serial)
    /// instead of re-submitting — nested stages cannot deadlock the pool.
    ///
    /// If a task panics, the panic is re-raised here on the submitting
    /// thread after the whole stage has drained.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        if worker::is_pool_thread() {
            return (0..n).map(f).collect();
        }
        let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let done = Arc::new(Completion::new(n));
        {
            let f = &f;
            let slots = &slots;
            for i in 0..n {
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let r = f(i);
                    *slots[i].lock().unwrap() = Some(r);
                });
                // SAFETY: lifetime erasure to 'static. The job borrows only
                // `f` and `slots`, both alive until this function returns;
                // `done.wait()` below blocks until every worker has dropped
                // its job (workers signal the latch strictly after the job
                // is consumed), so no borrow escapes this call.
                let job: Box<dyn FnOnce() + Send + 'static> =
                    unsafe { std::mem::transmute(job) };
                self.submit(Task {
                    job,
                    done: Some(done.clone()),
                });
            }
        }
        done.wait();
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap_or_else(|e| e.into_inner())
                    .unwrap_or_else(|| panic!("exec: a pool task panicked"))
            })
            .collect()
    }

    /// Snapshot the per-worker metrics.
    pub fn worker_stats(&self) -> Vec<WorkerStats> {
        self.shared
            .metrics
            .iter()
            .enumerate()
            .map(|(i, m)| m.snapshot(i))
            .collect()
    }

    /// Export per-worker + aggregate counters into a [`Metrics`] registry
    /// (`exec.workerN.{tasks,steals,busy_nanos,idle_nanos}` and
    /// `exec.total.*`).
    pub fn export_metrics(&self, m: &Metrics) {
        let mut tot_tasks = 0;
        let mut tot_steals = 0;
        let mut tot_busy = 0;
        let mut tot_idle = 0;
        for s in self.worker_stats() {
            m.add(&format!("exec.worker{}.tasks", s.worker), s.tasks);
            m.add(&format!("exec.worker{}.steals", s.worker), s.steals);
            m.add(&format!("exec.worker{}.busy_nanos", s.worker), s.busy_nanos);
            m.add(&format!("exec.worker{}.idle_nanos", s.worker), s.idle_nanos);
            tot_tasks += s.tasks;
            tot_steals += s.steals;
            tot_busy += s.busy_nanos;
            tot_idle += s.idle_nanos;
        }
        m.add("exec.total.tasks", tot_tasks);
        m.add("exec.total.steals", tot_steals);
        m.add("exec.total.busy_nanos", tot_busy);
        m.add("exec.total.idle_nanos", tot_idle);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _g = self.shared.park_lock.lock().unwrap();
            self.shared.park_cv.notify_all();
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// One-task-per-partition stage descriptor: the unit the engine and the
/// algorithm layer hand to the executor (a Spark `TaskSet` in miniature —
/// one stage, `tasks` tasks, results merged by task index).
pub struct TaskSet {
    label: String,
    tasks: usize,
}

impl TaskSet {
    pub fn new(label: impl Into<String>, tasks: usize) -> TaskSet {
        TaskSet {
            label: label.into(),
            tasks,
        }
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn len(&self) -> usize {
        self.tasks
    }

    pub fn is_empty(&self) -> bool {
        self.tasks == 0
    }

    /// Run the stage: on `Some(pool)` the tasks execute in parallel with
    /// work stealing; on `None` they run serially on the calling thread.
    /// Either way the results come back in task-index order, so callers
    /// merge deterministically regardless of thread count.
    pub fn run<T, F>(&self, pool: Option<&ThreadPool>, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        match pool {
            Some(pool) => pool.run(self.tasks, f),
            None => (0..self.tasks).map(f).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_returns_results_in_index_order() {
        let pool = ThreadPool::new(4);
        let out = pool.run(64, |i| i * i);
        assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn run_borrows_caller_stack() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..100).collect();
        let sums = pool.run(10, |i| data[i * 10..(i + 1) * 10].iter().sum::<u64>());
        assert_eq!(sums.iter().sum::<u64>(), 4950);
    }

    #[test]
    fn single_thread_pool_matches_serial() {
        let p1 = ThreadPool::new(1);
        let p4 = ThreadPool::new(4);
        let serial: Vec<u64> = (0..33).map(|i| i as u64 * 7 + 1).collect();
        assert_eq!(p1.run(33, |i| i as u64 * 7 + 1), serial);
        assert_eq!(p4.run(33, |i| i as u64 * 7 + 1), serial);
    }

    #[test]
    fn nested_run_from_worker_is_inline() {
        let pool = ThreadPool::new(2);
        let pool2 = pool.clone();
        let out = pool.run(4, move |i| pool2.run(3, |j| i * 10 + j));
        assert_eq!(out[2], vec![20, 21, 22]);
    }

    #[test]
    fn metrics_count_tasks() {
        let pool = ThreadPool::new(2);
        let _ = pool.run(20, |i| i);
        let stats = pool.worker_stats();
        let total: u64 = stats.iter().map(|s| s.tasks).sum();
        assert_eq!(total, 20);
        let m = Metrics::default();
        pool.export_metrics(&m);
        assert_eq!(m.counter("exec.total.tasks"), 20);
    }

    #[test]
    fn spawn_fire_and_forget() {
        let pool = ThreadPool::new(2);
        let hits = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let hits = hits.clone();
            pool.spawn(move || {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        // run() drains the same queues, so by completion the spawns ran too
        // (same pool, FIFO steal order) — poll briefly to be safe.
        for _ in 0..1000 {
            if hits.load(Ordering::SeqCst) == 8 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn panic_in_task_propagates_after_stage_drains() {
        let pool = ThreadPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(4, |i| {
                if i == 2 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(r.is_err());
        // pool still usable afterwards
        assert_eq!(pool.run(3, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn taskset_serial_and_parallel_agree() {
        let pool = ThreadPool::new(4);
        let ts = TaskSet::new("stage", 17);
        assert_eq!(ts.label(), "stage");
        assert_eq!(ts.len(), 17);
        assert!(!ts.is_empty());
        let serial = ts.run::<usize, _>(None, |i| i * 3);
        let parallel = ts.run(Some(&pool), |i| i * 3);
        assert_eq!(serial, parallel);
    }
}
