//! Dataset<T>: the RDD surrogate — lazy, partitioned, lineage-tracked.
//!
//! Everything here is `Send + Sync` (compute closures, cache, lineage) so
//! actions can evaluate one task per partition on the [`crate::exec`]
//! thread pool when [`EngineContext::with_executor`] attached one. The
//! merge order of every action is fixed (partition index), so results are
//! bitwise-identical for any thread count, including the serial path.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::{Arc, Mutex};

use super::shuffle;
use super::EngineContext;
use crate::cluster::SimCluster;
use crate::error::{Error, Result};
use crate::exec::TaskSet;
use crate::util::lock_unpoisoned;
use crate::util::timer::Stopwatch;
use std::sync::atomic::Ordering;

/// The compute closure: produce partition `p` from parents (captured).
type ComputeFn<T> = Arc<dyn Fn(usize) -> Result<Vec<T>> + Send + Sync>;

struct Core<T> {
    id: usize,
    ctx: Arc<EngineContext>,
    num_partitions: usize,
    compute: ComputeFn<T>,
    /// Some(slots) iff cached. A slot is None until computed or after
    /// invalidation (simulated executor loss).
    cache: Mutex<Option<Vec<Option<Arc<Vec<T>>>>>>,
    /// Some(parts) once `checkpoint` has materialized this dataset to
    /// simulated stable storage: recovery reads these instead of
    /// replaying lineage (and bypasses task-failure injection — stable
    /// reads don't re-run the compute).
    checkpoint: Mutex<Option<Vec<Arc<Vec<T>>>>>,
}

/// An immutable, partitioned, lineage-tracked collection.
///
/// Cloning is O(1) (shares the core). All transformations are lazy: they
/// build a new `Dataset` whose compute closure pulls parent partitions on
/// demand. Without `cache()`, every action recomputes the full chain —
/// exactly Spark's semantics (and the reason the Mahout baseline, which
/// rereads HDFS instead, loses on iterative workloads).
pub struct Dataset<T> {
    core: Arc<Core<T>>,
}

impl<T> Clone for Dataset<T> {
    fn clone(&self) -> Self {
        Dataset {
            core: self.core.clone(),
        }
    }
}

impl<T: Clone + Send + Sync + 'static> Dataset<T> {
    // ---- constructors ---------------------------------------------------

    pub(crate) fn from_vec(
        ctx: Arc<EngineContext>,
        data: Vec<T>,
        partitions: usize,
    ) -> Dataset<T> {
        assert!(partitions > 0, "need at least one partition");
        let n = data.len();
        let chunks: Vec<Vec<T>> = if n == 0 {
            vec![Vec::new(); partitions]
        } else {
            // balanced contiguous split: first (n % p) chunks get +1
            let base = n / partitions;
            let extra = n % partitions;
            let mut out = Vec::with_capacity(partitions);
            let mut it = data.into_iter();
            for p in 0..partitions {
                let take = base + usize::from(p < extra);
                out.push(it.by_ref().take(take).collect());
            }
            out
        };
        let chunks = Arc::new(chunks);
        Dataset::new(ctx, partitions, {
            let chunks = chunks.clone();
            move |p| Ok(chunks[p].clone())
        })
    }

    pub(crate) fn new(
        ctx: Arc<EngineContext>,
        num_partitions: usize,
        compute: impl Fn(usize) -> Result<Vec<T>> + Send + Sync + 'static,
    ) -> Dataset<T> {
        let id = ctx.fresh_id();
        Dataset {
            core: Arc::new(Core {
                id,
                ctx,
                num_partitions,
                compute: Arc::new(compute),
                cache: Mutex::new(None),
                checkpoint: Mutex::new(None),
            }),
        }
    }

    // ---- topology ------------------------------------------------------

    pub fn num_partitions(&self) -> usize {
        self.core.num_partitions
    }

    pub fn id(&self) -> usize {
        self.core.id
    }

    pub fn context(&self) -> Arc<EngineContext> {
        self.core.ctx.clone()
    }

    // ---- materialization -------------------------------------------------

    /// Compute (or fetch cached) partition `p`.
    pub fn partition(&self, p: usize) -> Result<Arc<Vec<T>>> {
        if p >= self.core.num_partitions {
            return Err(Error::Engine(format!(
                "partition {p} out of range (dataset has {})",
                self.core.num_partitions
            )));
        }
        // cached? was this a cached dataset whose slot was invalidated?
        // (checked under the lock, computed outside it so sibling
        // partitions don't serialize)
        let was_invalidated = {
            let cache = lock_unpoisoned(&self.core.cache);
            if let Some(slots) = cache.as_ref() {
                if let Some(v) = &slots[p] {
                    self.core.ctx.cache_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(v.clone());
                }
            }
            cache.as_ref().is_some_and(|s| s[p].is_none())
                && self.core.ctx.failures.was_lost(self.core.id, p)
        };
        // checkpointed? serve from simulated stable storage: bounded
        // recovery that never replays lineage or consults the task
        // failure plan
        let from_checkpoint = {
            let ck = lock_unpoisoned(&self.core.checkpoint);
            ck.as_ref().map(|parts| parts[p].clone())
        };
        if let Some(v) = from_checkpoint {
            self.core.ctx.checkpoint_hits.fetch_add(1, Ordering::Relaxed);
            if was_invalidated {
                self.core.ctx.recoveries.fetch_add(1, Ordering::Relaxed);
            }
            let mut cache = lock_unpoisoned(&self.core.cache);
            if let Some(slots) = cache.as_mut() {
                if let Some(existing) = &slots[p] {
                    return Ok(existing.clone());
                }
                slots[p] = Some(v.clone());
            }
            return Ok(v);
        }
        // compute through lineage, honoring task-failure injection
        let v = Arc::new(self.compute_with_retries(p)?);
        if was_invalidated {
            // count a lineage recomputation after simulated loss
            self.core.ctx.recoveries.fetch_add(1, Ordering::Relaxed);
        }
        let mut cache = lock_unpoisoned(&self.core.cache);
        if let Some(slots) = cache.as_mut() {
            // if a racing task cached this slot first, serve its copy so
            // every consumer shares one allocation
            if let Some(existing) = &slots[p] {
                return Ok(existing.clone());
            }
            slots[p] = Some(v.clone());
        }
        Ok(v)
    }

    /// Materialize every partition — one task per partition on the
    /// attached executor (serially without one) — returned in partition
    /// index order. The first error, by lowest partition index, wins; a
    /// panicking partition task fails this evaluation (typed
    /// `Error::Exec`), not the pool.
    pub fn partitions(&self) -> Result<Vec<Arc<Vec<T>>>> {
        let pool = self.core.ctx.executor();
        let tracer = self.core.ctx.tracer();
        let t0 = tracer.start();
        let out: Result<Vec<Arc<Vec<T>>>> = TaskSet::new(
            format!("dataset-{}-eval", self.core.id),
            self.core.num_partitions,
        )
        .try_run(pool.as_deref(), |p| self.partition(p))?
        .into_iter()
        .collect();
        if let Some(t0) = t0 {
            tracer.span(
                format!("eval:dataset-{}", self.core.id),
                "engine",
                0,
                t0,
                &[("partitions", self.core.num_partitions as f64)],
            );
        }
        out
    }

    fn compute_with_retries(&self, p: usize) -> Result<Vec<T>> {
        let policy = self.core.ctx.retry_policy();
        let attempts = policy.max_attempts.max(1);
        // mli-lint: allow(D002) RetryPolicy timeout is a real wall-clock budget
        let budget = Stopwatch::start();
        let mut last_err: Option<Error> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                // exponential backoff before each retry (scheduler
                // re-launch delay); a backoff that cannot complete inside
                // the remaining wall-clock budget is refused outright, so
                // exhaustion is reported before a futile final sleep
                // instead of after overshooting the timeout
                match policy.next_backoff(attempt, budget.elapsed()) {
                    Some(backoff) => std::thread::sleep(backoff),
                    None => {
                        let last = last_err
                            .as_ref()
                            .map(|e| e.to_string())
                            .unwrap_or_else(|| "no prior error".into());
                        return Err(Error::FaultRecovery(format!(
                            "retry budget timed out after {attempt} attempts \
                             (dataset {}, partition {p}): {last}",
                            self.core.id
                        )));
                    }
                }
            }
            self.core.ctx.tasks_run.fetch_add(1, Ordering::Relaxed);
            if self.core.ctx.failures.should_fail(self.core.id, p) {
                last_err = Some(Error::Engine(format!(
                    "injected task failure (dataset {}, partition {p})",
                    self.core.id
                )));
                continue;
            }
            return (self.core.compute)(p);
        }
        let last = last_err
            .as_ref()
            .map(|e| e.to_string())
            .unwrap_or_else(|| "no error recorded".into());
        Err(Error::FaultRecovery(format!(
            "gave up after {attempts} attempts (dataset {}, partition {p}): {last}",
            self.core.id
        )))
    }

    /// Enable caching (Spark `.cache()`); returns self for chaining.
    pub fn cache(self) -> Dataset<T> {
        {
            let mut c = lock_unpoisoned(&self.core.cache);
            if c.is_none() {
                *c = Some(vec![None; self.core.num_partitions]);
            }
        }
        self
    }

    /// Simulate losing a cached partition (executor death). The next
    /// `partition(p)` recomputes through lineage and re-caches.
    pub fn invalidate_partition(&self, p: usize) {
        let mut c = lock_unpoisoned(&self.core.cache);
        if let Some(slots) = c.as_mut() {
            if slots[p].take().is_some() {
                self.core.ctx.failures.mark_lost(self.core.id, p);
            }
        }
    }

    /// True if partition `p` is resident in cache.
    pub fn is_cached(&self, p: usize) -> bool {
        lock_unpoisoned(&self.core.cache)
            .as_ref()
            .is_some_and(|s| s[p].is_some())
    }

    // ---- fault tolerance -------------------------------------------------

    /// Materialize every partition to simulated stable storage (the HDFS
    /// surrogate) and truncate lineage, Spark's `RDD.checkpoint`: later
    /// recoveries re-read the snapshot instead of replaying the compute
    /// chain, bounding recovery depth under repeated machine loss. The
    /// write runs as one dedicated round on `cluster`: per-partition
    /// compute on the partition's assigned machine, plus a 3x-replicated
    /// HDFS write and read-back of the snapshot bytes (shallow
    /// `size_of::<T>()` estimate) via `charge_hdfs_roundtrip`. Must be
    /// called between rounds. Idempotent: re-checkpointing an already
    /// checkpointed dataset is a no-op and charges nothing.
    pub fn checkpoint(&self, cluster: &SimCluster) -> Result<()> {
        if lock_unpoisoned(&self.core.checkpoint).is_some() {
            return Ok(());
        }
        let tracer = self.core.ctx.tracer();
        let t0 = tracer.start();
        cluster.begin_round();
        let result = (|| -> Result<(Vec<Arc<Vec<T>>>, u64)> {
            let n = self.core.num_partitions;
            let mut parts = Vec::with_capacity(n);
            let mut bytes = 0u64;
            for p in 0..n {
                let machine = cluster.assign_machine(p)?;
                let part = cluster.run_task(machine, || self.partition(p))?;
                bytes += (part.len() * std::mem::size_of::<T>()) as u64;
                parts.push(part);
            }
            Ok((parts, bytes))
        })();
        let (parts, bytes) = match result {
            Ok(v) => v,
            Err(e) => {
                // close the round even on failure so the ledger is never
                // left wedged inside an open round
                cluster.end_round();
                return Err(e);
            }
        };
        cluster.charge_hdfs_roundtrip(bytes / cluster.num_machines() as u64);
        cluster.end_round();
        *lock_unpoisoned(&self.core.checkpoint) = Some(parts);
        if let Some(t0) = t0 {
            tracer.span(
                format!("checkpoint:dataset-{}", self.core.id),
                "engine",
                0,
                t0,
                &[("bytes", bytes as f64)],
            );
            tracer.count("engine.checkpoints", 1);
        }
        Ok(())
    }

    /// True once [`Dataset::checkpoint`] has materialized this dataset.
    pub fn is_checkpointed(&self) -> bool {
        lock_unpoisoned(&self.core.checkpoint).is_some()
    }

    /// Wire machine-loss events from `cluster` into this dataset's cache:
    /// when a machine dies, every cached partition resident on it under
    /// round-robin placement (`p % machines`) is invalidated, so the next
    /// access recovers through the checkpoint (if one exists) or lineage.
    /// The registration lives as long as the cluster.
    pub fn bind_cluster(&self, cluster: &SimCluster) {
        let ds = self.clone();
        let machines = cluster.num_machines();
        cluster.on_machine_loss(move |m| {
            let mut p = m;
            while p < ds.num_partitions() {
                ds.invalidate_partition(p);
                p += machines;
            }
        });
    }

    // ---- actions ----------------------------------------------------------

    /// Record a per-action span (`action:<name>:dataset-<id>`) if the
    /// context has an enabled tracer.
    fn action_span(&self, name: &str, t0: Option<u64>) {
        if let Some(t0) = t0 {
            self.core.ctx.tracer().span(
                format!("action:{name}:dataset-{}", self.core.id),
                "engine",
                0,
                t0,
                &[],
            );
        }
    }

    /// Materialize all partitions, in order.
    pub fn collect(&self) -> Result<Vec<T>> {
        let t0 = self.core.ctx.tracer().start();
        let parts = self.partitions()?;
        let mut out = Vec::new();
        for part in parts {
            out.extend(part.iter().cloned());
        }
        self.action_span("collect", t0);
        Ok(out)
    }

    /// Force-compute every partition (into cache if enabled).
    pub fn materialize(&self) -> Result<()> {
        let t0 = self.core.ctx.tracer().start();
        self.partitions()?;
        self.action_span("materialize", t0);
        Ok(())
    }

    pub fn count(&self) -> Result<usize> {
        let t0 = self.core.ctx.tracer().start();
        let n = self.partitions()?.iter().map(|p| p.len()).sum();
        self.action_span("count", t0);
        Ok(n)
    }

    /// Tree-free associative reduce over all elements (Fig. A1 `reduce`).
    ///
    /// Partitions are *computed* in parallel (when a pool is attached) but
    /// *folded* on the calling thread in element order, so the result is
    /// identical to the serial path even for non-associative `f`.
    pub fn reduce(&self, f: impl Fn(T, T) -> T) -> Result<Option<T>> {
        let t0 = self.core.ctx.tracer().start();
        let parts = self.partitions()?;
        let mut acc: Option<T> = None;
        for part in parts {
            for x in part.iter().cloned() {
                acc = Some(match acc {
                    None => x,
                    Some(a) => f(a, x),
                });
            }
        }
        self.action_span("reduce", t0);
        Ok(acc)
    }

    /// Per-partition fold then combine — the engine primitive behind
    /// MLTable's `matrixBatchMap(...).reduce` pattern in Fig. A4. Combine
    /// runs in partition index order (deterministic merge).
    pub fn aggregate<U: Clone + 'static>(
        &self,
        zero: U,
        seq: impl Fn(U, &T) -> U,
        comb: impl Fn(U, U) -> U,
    ) -> Result<U> {
        let t0 = self.core.ctx.tracer().start();
        let parts = self.partitions()?;
        let mut acc = zero.clone();
        for part in parts {
            let mut local = zero.clone();
            for x in part.iter() {
                local = seq(local, x);
            }
            acc = comb(acc, local);
        }
        self.action_span("aggregate", t0);
        Ok(acc)
    }

    // ---- narrow transformations ------------------------------------------

    pub fn map<U: Clone + Send + Sync + 'static>(
        &self,
        f: impl Fn(&T) -> U + Send + Sync + 'static,
    ) -> Dataset<U> {
        let parent = self.clone();
        Dataset::new(self.core.ctx.clone(), self.num_partitions(), move |p| {
            Ok(parent.partition(p)?.iter().map(|x| f(x)).collect())
        })
    }

    pub fn filter(&self, f: impl Fn(&T) -> bool + Send + Sync + 'static) -> Dataset<T> {
        let parent = self.clone();
        Dataset::new(self.core.ctx.clone(), self.num_partitions(), move |p| {
            Ok(parent
                .partition(p)?
                .iter()
                .filter(|x| f(x))
                .cloned()
                .collect())
        })
    }

    pub fn flat_map<U: Clone + Send + Sync + 'static>(
        &self,
        f: impl Fn(&T) -> Vec<U> + Send + Sync + 'static,
    ) -> Dataset<U> {
        let parent = self.clone();
        Dataset::new(self.core.ctx.clone(), self.num_partitions(), move |p| {
            Ok(parent.partition(p)?.iter().flat_map(|x| f(x)).collect())
        })
    }

    /// Whole-partition transformation — the engine primitive behind
    /// `matrixBatchMap` (Fig. A1). `f` receives (partition_index, rows).
    pub fn map_partitions<U: Clone + Send + Sync + 'static>(
        &self,
        f: impl Fn(usize, &[T]) -> Result<Vec<U>> + Send + Sync + 'static,
    ) -> Dataset<U> {
        let parent = self.clone();
        Dataset::new(self.core.ctx.clone(), self.num_partitions(), move |p| {
            f(p, &parent.partition(p)?)
        })
    }

    /// Concatenate two datasets (Fig. A1 `union`); partitions appended.
    pub fn union(&self, other: &Dataset<T>) -> Dataset<T> {
        let a = self.clone();
        let b = other.clone();
        let na = a.num_partitions();
        Dataset::new(
            self.core.ctx.clone(),
            na + b.num_partitions(),
            move |p| {
                if p < na {
                    a.partition(p).map(|r| r.as_ref().clone())
                } else {
                    b.partition(p - na).map(|r| r.as_ref().clone())
                }
            },
        )
    }

    /// Zip co-partitioned datasets elementwise.
    pub fn zip<U: Clone + Send + Sync + 'static>(
        &self,
        other: &Dataset<U>,
    ) -> Result<Dataset<(T, U)>> {
        if self.num_partitions() != other.num_partitions() {
            return Err(Error::Engine(format!(
                "zip: partition counts differ ({} vs {})",
                self.num_partitions(),
                other.num_partitions()
            )));
        }
        let a = self.clone();
        let b = other.clone();
        Ok(Dataset::new(
            self.core.ctx.clone(),
            self.num_partitions(),
            move |p| {
                let pa = a.partition(p)?;
                let pb = b.partition(p)?;
                if pa.len() != pb.len() {
                    return Err(Error::Engine(format!(
                        "zip: partition {p} lengths differ ({} vs {})",
                        pa.len(),
                        pb.len()
                    )));
                }
                Ok(pa.iter().cloned().zip(pb.iter().cloned()).collect())
            },
        ))
    }

    /// Redistribute into `parts` partitions (round-robin) — a shuffle.
    pub fn repartition(&self, parts: usize) -> Dataset<T> {
        assert!(parts > 0);
        let parent = self.clone();
        let buckets: Arc<Mutex<Option<Vec<Vec<T>>>>> = Arc::new(Mutex::new(None));
        Dataset::new(self.core.ctx.clone(), parts, move |p| {
            let mut b = lock_unpoisoned(&buckets);
            if b.is_none() {
                let src = parent.partitions()?;
                let mut out = vec![Vec::new(); parts];
                let mut i = 0usize;
                for part in &src {
                    for x in part.iter() {
                        out[i % parts].push(x.clone());
                        i += 1;
                    }
                }
                *b = Some(out);
            }
            Ok(b.as_ref().unwrap()[p].clone())
        })
    }
}

// ---- key-value (shuffle) transformations --------------------------------

impl<K, V> Dataset<(K, V)>
where
    K: Clone + Hash + Eq + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    /// Combine values per key with an associative, commutative function
    /// (Fig. A1 `reduceByKey`). Hash-partitions keys across the existing
    /// partition count (a wide dependency: first access materializes all
    /// parent partitions, as a real shuffle would). Output order is
    /// first-seen order by (source partition, position) — deterministic
    /// and independent of thread count.
    pub fn reduce_by_key(
        &self,
        f: impl Fn(V, V) -> V + Send + Sync + 'static,
    ) -> Dataset<(K, V)> {
        let parent = self.clone();
        let parts = self.num_partitions();
        let shuffled: Arc<Mutex<Option<Vec<Vec<(K, V)>>>>> = Arc::new(Mutex::new(None));
        let f = Arc::new(f);
        Dataset::new(self.core.ctx.clone(), parts, move |p| {
            let mut s = lock_unpoisoned(&shuffled);
            if s.is_none() {
                *s = Some(shuffle::shuffle_reduce(&parent, parts, f.as_ref())?);
            }
            Ok(s.as_ref().unwrap()[p].clone())
        })
    }

    /// Group values per key.
    pub fn group_by_key(&self) -> Dataset<(K, Vec<V>)> {
        let parent = self.clone();
        let parts = self.num_partitions();
        let shuffled: Arc<Mutex<Option<Vec<Vec<(K, Vec<V>)>>>>> = Arc::new(Mutex::new(None));
        Dataset::new(self.core.ctx.clone(), parts, move |p| {
            let mut s = lock_unpoisoned(&shuffled);
            if s.is_none() {
                *s = Some(shuffle::shuffle_group(&parent, parts)?);
            }
            Ok(s.as_ref().unwrap()[p].clone())
        })
    }

    /// Inner join on key (Fig. A1 `join`).
    pub fn join<W: Clone + Send + Sync + 'static>(
        &self,
        other: &Dataset<(K, W)>,
    ) -> Dataset<(K, (V, W))> {
        let a = self.clone();
        let b = other.clone();
        let parts = self.num_partitions();
        let built: Arc<Mutex<Option<Vec<Vec<(K, (V, W))>>>>> = Arc::new(Mutex::new(None));
        Dataset::new(self.core.ctx.clone(), parts, move |p| {
            let mut s = lock_unpoisoned(&built);
            if s.is_none() {
                // build hash map from b, stream a through it in partition
                // order (lookup-only map: output order follows a, so it is
                // deterministic), hash-partition out
                // mli-lint: allow(D001) lookup-only: iteration never touches map order
                let mut rhs: HashMap<K, Vec<W>> = HashMap::new();
                for part in b.partitions()? {
                    for (k, w) in part.iter() {
                        rhs.entry(k.clone()).or_default().push(w.clone());
                    }
                }
                let mut out = vec![Vec::new(); parts];
                for part in a.partitions()? {
                    for (k, v) in part.iter() {
                        if let Some(ws) = rhs.get(k) {
                            let slot = shuffle::bucket_of(k, parts);
                            for w in ws {
                                out[slot].push((k.clone(), (v.clone(), w.clone())));
                            }
                        }
                    }
                }
                *s = Some(out);
            }
            Ok(s.as_ref().unwrap()[p].clone())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::EngineContext;
    use super::*;

    fn ctx() -> Arc<EngineContext> {
        EngineContext::new()
    }

    #[test]
    fn partitioning_is_balanced_and_ordered() {
        let d = ctx().parallelize((0..10).collect::<Vec<i32>>(), 3);
        let sizes: Vec<usize> = (0..3).map(|p| d.partition(p).unwrap().len()).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
        assert_eq!(d.collect().unwrap(), (0..10).collect::<Vec<_>>());
        assert!(d.partition(3).is_err());
    }

    #[test]
    fn lazy_chain_map_filter_flatmap() {
        let d = ctx().parallelize((1..=6).collect::<Vec<i32>>(), 2);
        let out = d
            .map(|x| x * 10)
            .filter(|x| x % 20 == 0)
            .flat_map(|x| vec![*x, *x + 1])
            .collect()
            .unwrap();
        assert_eq!(out, vec![20, 21, 40, 41, 60, 61]);
    }

    #[test]
    fn map_partitions_sees_whole_partition() {
        let d = ctx().parallelize((0..8).collect::<Vec<i32>>(), 4);
        let sums = d
            .map_partitions(|idx, xs| Ok(vec![(idx, xs.iter().sum::<i32>())]))
            .collect()
            .unwrap();
        assert_eq!(sums, vec![(0, 1), (1, 5), (2, 9), (3, 13)]);
    }

    #[test]
    fn union_zip_repartition() {
        let c = ctx();
        let a = c.parallelize(vec![1, 2], 1);
        let b = c.parallelize(vec![3, 4], 1);
        let u = a.union(&b);
        assert_eq!(u.num_partitions(), 2);
        assert_eq!(u.collect().unwrap(), vec![1, 2, 3, 4]);

        let z = a.zip(&b).unwrap().collect().unwrap();
        assert_eq!(z, vec![(1, 3), (2, 4)]);
        assert!(a.zip(&u).is_err());

        let r = u.repartition(4);
        assert_eq!(r.num_partitions(), 4);
        let mut all = r.collect().unwrap();
        all.sort();
        assert_eq!(all, vec![1, 2, 3, 4]);
    }

    #[test]
    fn reduce_and_aggregate() {
        let d = ctx().parallelize((1..=100).collect::<Vec<i64>>(), 7);
        assert_eq!(d.reduce(|a, b| a + b).unwrap(), Some(5050));
        assert_eq!(d.count().unwrap(), 100);
        let (sum, cnt) = d
            .aggregate((0i64, 0usize), |(s, c), x| (s + x, c + 1), |a, b| (a.0 + b.0, a.1 + b.1))
            .unwrap();
        assert_eq!((sum, cnt), (5050, 100));
        let empty: Dataset<i64> = ctx().parallelize(vec![], 2);
        assert_eq!(empty.reduce(|a, b| a + b).unwrap(), None);
    }

    #[test]
    fn reduce_by_key_and_group() {
        let d = ctx().parallelize(
            vec![("a", 1), ("b", 2), ("a", 3), ("c", 4), ("b", 5)],
            2,
        );
        let mut red = d.reduce_by_key(|a, b| a + b).collect().unwrap();
        red.sort();
        assert_eq!(red, vec![("a", 4), ("b", 7), ("c", 4)]);

        let mut grp = d.group_by_key().collect().unwrap();
        grp.sort();
        assert_eq!(grp[0].0, "a");
        assert_eq!(grp[0].1, vec![1, 3]);
    }

    #[test]
    fn join_inner() {
        let c = ctx();
        let a = c.parallelize(vec![(1, "x"), (2, "y"), (3, "z")], 2);
        let b = c.parallelize(vec![(2, 20.0), (3, 30.0), (4, 40.0)], 2);
        let mut j = a.join(&b).collect().unwrap();
        j.sort_by_key(|e| e.0);
        assert_eq!(j, vec![(2, ("y", 20.0)), (3, ("z", 30.0))]);
    }

    #[test]
    fn cache_hits_and_invalidation_recovery() {
        let c = ctx();
        let d = c
            .parallelize((0..100).collect::<Vec<i32>>(), 4)
            .map(|x| x + 1)
            .cache();
        d.materialize().unwrap();
        assert!(d.is_cached(2));
        let before = c.stats().0;
        let _ = d.partition(2).unwrap(); // cache hit: no new task
        assert_eq!(c.stats().0, before);
        assert!(c.stats().1 >= 1);

        // simulate executor loss
        d.invalidate_partition(2);
        assert!(!d.is_cached(2));
        let v = d.partition(2).unwrap(); // recomputed through lineage
        assert_eq!(v[0], 51);
        assert!(d.is_cached(2));
        assert_eq!(c.stats().2, 1, "one recovery recorded");
        // data identical after recovery
        assert_eq!(d.collect().unwrap(), (1..=100).collect::<Vec<_>>());
    }

    #[test]
    fn lineage_recovery_through_deep_chain() {
        let c = ctx();
        let base = c.parallelize((0..20).collect::<Vec<i64>>(), 2).cache();
        let derived = base.map(|x| x * 2).filter(|x| *x % 4 == 0).cache();
        derived.materialize().unwrap();
        base.invalidate_partition(0);
        derived.invalidate_partition(0);
        // both recover transparently
        let out = derived.collect().unwrap();
        assert_eq!(out, (0..20).map(|x| x * 2).filter(|x| x % 4 == 0).collect::<Vec<_>>());
        assert!(c.stats().2 >= 2);
    }

    #[test]
    fn checkpoint_truncates_lineage_and_is_idempotent() {
        let c = ctx();
        let d = c
            .parallelize((0..100).collect::<Vec<i32>>(), 4)
            .map(|x| x + 1)
            .cache();
        let cluster = SimCluster::ec2(4);
        assert!(!d.is_checkpointed());
        d.checkpoint(&cluster).unwrap();
        assert!(d.is_checkpointed());
        assert_eq!(cluster.rounds(), 1, "checkpoint runs as one round");
        assert!(cluster.total_disk_seconds() > 0.0, "HDFS roundtrip charged");

        // idempotent: no extra round, no extra charge
        let disk = cluster.total_disk_seconds();
        d.checkpoint(&cluster).unwrap();
        assert_eq!(cluster.rounds(), 1);
        assert_eq!(cluster.total_disk_seconds(), disk);

        // lose a partition AND poison its lineage: recovery must come
        // from the checkpoint, never replaying the (now failing) compute
        d.invalidate_partition(2);
        c.failures.fail_times(d.id(), 2, 1000);
        let v = d.partition(2).unwrap();
        assert_eq!(v.as_ref(), &(51..=75).collect::<Vec<i32>>());
        assert!(c.checkpoint_hits() >= 1);
        assert_eq!(c.stats().2, 1, "checkpoint read still counts as recovery");
        assert!(d.is_cached(2), "recovered partition re-cached");
    }

    #[test]
    fn retry_exhaustion_is_typed_fault_recovery() {
        let c = ctx();
        let d = c.parallelize(vec![1, 2, 3], 1).map(|x| *x);
        c.failures.fail_times(d.id(), 0, 100);
        let err = d.collect().unwrap_err();
        assert!(err.is_fault_recovery(), "got: {err}");
        // the last underlying error is preserved in the message
        assert!(err.to_string().contains("injected task failure"));
    }

    #[test]
    fn retry_timeout_budget_is_enforced() {
        use super::super::RetryPolicy;
        use std::time::Duration;
        let c = ctx();
        c.set_retry_policy(RetryPolicy {
            max_attempts: 1000,
            backoff_base: Duration::from_millis(10),
            timeout: Duration::from_millis(25),
        });
        let d = c.parallelize(vec![1], 1).map(|x| *x);
        c.failures.fail_times(d.id(), 0, 1_000_000);
        let err = d.collect().unwrap_err();
        assert!(err.is_fault_recovery(), "got: {err}");
        assert!(err.to_string().contains("timed out"), "got: {err}");
    }

    #[test]
    fn retry_refuses_futile_final_sleep() {
        use super::super::RetryPolicy;
        use std::time::Duration;
        let c = ctx();
        // the first backoff (1s) already exceeds the whole 50ms budget; the
        // old behaviour slept the clamped remainder before erroring, the
        // fixed one reports exhaustion immediately
        c.set_retry_policy(RetryPolicy {
            max_attempts: 10,
            backoff_base: Duration::from_secs(1),
            timeout: Duration::from_millis(50),
        });
        let d = c.parallelize(vec![1], 1).map(|x| *x);
        c.failures.fail_times(d.id(), 0, 1_000_000);
        let sw = std::time::Instant::now();
        let err = d.collect().unwrap_err();
        assert!(err.is_fault_recovery(), "got: {err}");
        assert!(err.to_string().contains("timed out"), "got: {err}");
        assert!(
            sw.elapsed() < Duration::from_millis(500),
            "slept through a futile backoff: {:?}",
            sw.elapsed()
        );
    }

    #[test]
    fn bind_cluster_invalidates_partitions_of_dead_machine() {
        let c = ctx();
        let d = c.parallelize((0..80).collect::<Vec<i64>>(), 8).cache();
        d.materialize().unwrap();
        let cluster = SimCluster::ec2(4);
        d.bind_cluster(&cluster);
        cluster.kill_machine(1, None);
        // partitions 1 and 5 live on machine 1 (p % 4); both drop
        assert!(!d.is_cached(1) && !d.is_cached(5));
        assert!(d.is_cached(0) && d.is_cached(2));
        assert_eq!(c.failures.losses(), 2);
        // next action recovers both through lineage, bitwise-identical
        assert_eq!(d.collect().unwrap(), (0..80).collect::<Vec<_>>());
        assert_eq!(c.stats().2, 2, "both partitions recovered");
    }

    #[test]
    fn parallel_actions_match_serial() {
        let serial = ctx();
        let par = EngineContext::new().with_executor(4);
        let mk = |c: &Arc<EngineContext>| {
            c.parallelize((0..1000).collect::<Vec<i64>>(), 8)
                .map(|x| x * 3 + 1)
                .filter(|x| x % 2 == 0)
        };
        let a = mk(&serial);
        let b = mk(&par);
        assert_eq!(a.collect().unwrap(), b.collect().unwrap());
        assert_eq!(a.count().unwrap(), b.count().unwrap());
        assert_eq!(
            a.reduce(|x, y| x + y).unwrap(),
            b.reduce(|x, y| x + y).unwrap()
        );
    }
}
