//! The distributed dataflow engine (Spark surrogate, DESIGN.md §3).
//!
//! The paper implements MLI on Spark [11] for (a) iterative in-memory
//! computation and (b) lineage-based fault tolerance. This module rebuilds
//! the subset MLI needs, in-process:
//!
//! * [`Dataset<T>`] — an RDD: an immutable, partitioned collection with a
//!   recorded *lineage* (a compute closure reaching back to its parents).
//!   Transformations are lazy; actions (`collect`, `reduce`, `count`)
//!   force computation.
//! * **Caching** — `cache()` pins computed partitions in memory.
//! * **Fault tolerance** — `invalidate_partition` simulates losing a
//!   cached partition (executor death); the next access transparently
//!   recomputes it through the lineage chain, exactly Spark's recovery
//!   story. Task-level failure injection with bounded retries lives in
//!   [`failure`].
//! * **Shuffles** — `reduce_by_key` / `group_by_key` / `join` hash-
//!   partition intermediate state ([`shuffle`]).
//! * **Broadcast** — [`EngineContext::broadcast`] mirrors
//!   `sc.broadcast` (Fig. A9 uses it for ALS factor shipping).
//!
//! The engine is deliberately *pure dataflow*: simulated-time charging is
//! done by the algorithm layer (which knows message sizes and topologies),
//! keeping this layer independently testable.

pub mod dataset;
pub mod failure;
pub mod shuffle;

pub use dataset::Dataset;
pub use failure::FailurePlan;

use std::cell::RefCell;
use std::rc::Rc;

/// Shared engine state: id allocator, failure plan, task metrics.
pub struct EngineContext {
    next_id: RefCell<usize>,
    pub failures: Rc<FailurePlan>,
    /// Tasks executed (partition computations), for overhead benches.
    pub tasks_run: RefCell<u64>,
    /// Cache hits (partition served from memory).
    pub cache_hits: RefCell<u64>,
    /// Partition recomputations triggered by invalidation (recoveries).
    pub recoveries: RefCell<u64>,
}

impl EngineContext {
    pub fn new() -> Rc<EngineContext> {
        Rc::new(EngineContext {
            next_id: RefCell::new(0),
            failures: Rc::new(FailurePlan::default()),
            tasks_run: RefCell::new(0),
            cache_hits: RefCell::new(0),
            recoveries: RefCell::new(0),
        })
    }

    pub(crate) fn fresh_id(&self) -> usize {
        let mut id = self.next_id.borrow_mut();
        *id += 1;
        *id
    }

    /// Create a dataset from local data, split into `partitions` chunks
    /// (Spark's `sc.parallelize`).
    pub fn parallelize<T: Clone + 'static>(
        self: &Rc<Self>,
        data: Vec<T>,
        partitions: usize,
    ) -> Dataset<T> {
        Dataset::from_vec(self.clone(), data, partitions)
    }

    /// Broadcast a value to all (simulated) machines. Cheap Rc clone
    /// in-process; the *cost* is charged by the caller via
    /// `SimCluster::charge_broadcast` (algorithms know the byte size).
    pub fn broadcast<T>(&self, value: T) -> Broadcast<T> {
        Broadcast { value: Rc::new(value) }
    }

    pub fn stats(&self) -> (u64, u64, u64) {
        (
            *self.tasks_run.borrow(),
            *self.cache_hits.borrow(),
            *self.recoveries.borrow(),
        )
    }
}

/// A broadcast variable (Fig. A9: `ctx.broadcast(V)`).
#[derive(Clone)]
pub struct Broadcast<T> {
    value: Rc<T>,
}

impl<T> Broadcast<T> {
    pub fn value(&self) -> &T {
        &self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelize_and_broadcast() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize((0..10).collect::<Vec<i64>>(), 3);
        assert_eq!(d.num_partitions(), 3);
        assert_eq!(d.collect().unwrap(), (0..10).collect::<Vec<_>>());
        let b = ctx.broadcast(vec![1, 2, 3]);
        assert_eq!(b.value().len(), 3);
        let b2 = b.clone();
        assert_eq!(b2.value()[0], 1);
    }

    #[test]
    fn context_stats_track_tasks() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize(vec![1, 2, 3, 4], 2).map(|x| x * 2);
        let _ = d.collect().unwrap();
        let (tasks, _, _) = ctx.stats();
        assert!(tasks >= 2); // at least one task per partition
    }
}
