//! The distributed dataflow engine (Spark surrogate, DESIGN.md §3).
//!
//! The paper implements MLI on Spark [11] for (a) iterative in-memory
//! computation and (b) lineage-based fault tolerance. This module rebuilds
//! the subset MLI needs, in-process:
//!
//! * [`Dataset<T>`] — an RDD: an immutable, partitioned collection with a
//!   recorded *lineage* (a compute closure reaching back to its parents).
//!   Transformations are lazy; actions (`collect`, `reduce`, `count`)
//!   force computation.
//! * **Caching** — `cache()` pins computed partitions in memory.
//! * **Fault tolerance** — `invalidate_partition` simulates losing a
//!   cached partition (executor death); the next access transparently
//!   recomputes it through the lineage chain, exactly Spark's recovery
//!   story. Task-level failure injection with bounded retries lives in
//!   [`failure`].
//! * **Shuffles** — `reduce_by_key` / `group_by_key` / `join` hash-
//!   partition intermediate state ([`shuffle`]).
//! * **Broadcast** — [`EngineContext::broadcast`] mirrors
//!   `sc.broadcast` (Fig. A9 uses it for ALS factor shipping).
//! * **Parallel execution** — attach a work-stealing thread pool with
//!   [`EngineContext::with_executor`] and actions evaluate one task per
//!   partition on it ([`crate::exec`]). Without a pool, actions run
//!   serially on the calling thread. Results are bitwise-identical either
//!   way: every parallel stage merges per-partition results in partition
//!   index order, and task retries/lineage recovery go through the same
//!   `Send + Sync` failure plan.
//!
//! Note the two clocks: the executor shrinks *real* wall-clock time, while
//! *simulated* cluster time (the `SimCluster` ledger the benches report)
//! is charged analytically per round and is unaffected by how many local
//! threads computed the round.
//!
//! The engine is deliberately *pure dataflow*: simulated-time charging is
//! done by the algorithm layer (which knows message sizes and topologies),
//! keeping this layer independently testable.

pub mod dataset;
pub mod failure;
pub mod shuffle;

pub use dataset::Dataset;
pub use failure::FailurePlan;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::exec::{lock_unpoisoned, ThreadPool};
use crate::trace::Tracer;

/// Retry policy for partition compute attempts (Spark task-scheduler
/// surrogate): bounded attempts with exponential backoff and a per-action
/// wall-clock budget. The backoff sleeps are *real* (they model scheduler
/// re-launch delay) but tiny by default so tests stay fast; simulated
/// cluster time never reads them.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Max compute attempts per partition (`spark.task.maxFailures`).
    pub max_attempts: usize,
    /// Sleep before retry `i` (1-based) is `backoff_base * 2^(i-1)`.
    pub backoff_base: Duration,
    /// Total wall-clock budget across all attempts of one partition; once
    /// exceeded, remaining retries are forfeited and the action fails
    /// with [`crate::error::Error::FaultRecovery`].
    pub timeout: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            backoff_base: Duration::from_micros(200),
            timeout: Duration::from_secs(30),
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry `attempt` (1-based): `backoff_base *
    /// 2^(attempt-1)`, exponent capped so the shift can't overflow.
    pub fn backoff_for(&self, attempt: usize) -> Duration {
        self.backoff_base * (1u32 << (attempt.saturating_sub(1)).min(16))
    }

    /// The sleep to take after failed attempt `attempt` (1-based), given
    /// `elapsed` budget already spent. `None` means the retry budget is
    /// exhausted: the attempt limit is reached, the timeout has elapsed,
    /// or the backoff could not complete inside the remaining budget —
    /// sleeping through the rest of the budget only to report exhaustion
    /// afterwards is futile, so exhaustion is reported *before* the
    /// overshooting sleep rather than after it.
    pub fn next_backoff(&self, attempt: usize, elapsed: Duration) -> Option<Duration> {
        if attempt >= self.max_attempts {
            return None;
        }
        let backoff = self.backoff_for(attempt);
        let remaining = self.timeout.checked_sub(elapsed)?;
        if backoff >= remaining {
            return None;
        }
        Some(backoff)
    }
}

/// Shared engine state: id allocator, failure plan, task metrics, and the
/// optional task executor. All counters are atomics so partition tasks on
/// pool workers can record into them directly.
pub struct EngineContext {
    next_id: AtomicUsize,
    pub failures: Arc<FailurePlan>,
    /// Tasks executed (partition computations), for overhead benches.
    pub tasks_run: AtomicU64,
    /// Cache hits (partition served from memory).
    pub cache_hits: AtomicU64,
    /// Partition recomputations triggered by invalidation (recoveries).
    pub recoveries: AtomicU64,
    /// Partitions served from a checkpoint instead of lineage replay.
    pub checkpoint_hits: AtomicU64,
    retry: Mutex<RetryPolicy>,
    executor: Mutex<Option<Arc<ThreadPool>>>,
    tracer: Mutex<Arc<Tracer>>,
}

impl EngineContext {
    pub fn new() -> Arc<EngineContext> {
        Arc::new(EngineContext {
            next_id: AtomicUsize::new(0),
            failures: Arc::new(FailurePlan::default()),
            tasks_run: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            checkpoint_hits: AtomicU64::new(0),
            retry: Mutex::new(RetryPolicy::default()),
            executor: Mutex::new(None),
            tracer: Mutex::new(Tracer::disabled()),
        })
    }

    /// Swap the retry policy (attempts / backoff / timeout budget).
    pub fn set_retry_policy(&self, p: RetryPolicy) {
        *lock_unpoisoned(&self.retry) = p;
    }

    pub fn retry_policy(&self) -> RetryPolicy {
        *lock_unpoisoned(&self.retry)
    }

    pub fn checkpoint_hits(&self) -> u64 {
        self.checkpoint_hits.load(Ordering::Relaxed)
    }

    /// Attach a work-stealing executor with `threads` workers; subsequent
    /// actions evaluate partitions in parallel. Returns the context for
    /// chaining: `EngineContext::new().with_executor(4)`. The context's
    /// tracer (if any) is propagated to the new pool.
    pub fn with_executor(self: &Arc<Self>, threads: usize) -> Arc<Self> {
        let pool = ThreadPool::new(threads);
        pool.set_tracer(self.tracer());
        *lock_unpoisoned(&self.executor) = Some(pool);
        self.clone()
    }

    /// Attach a tracer: actions record per-eval/per-action spans, and an
    /// attached pool records per-task spans. Chains like `with_executor`.
    pub fn with_tracer(self: &Arc<Self>, tracer: Arc<Tracer>) -> Arc<Self> {
        self.set_tracer(tracer);
        self.clone()
    }

    /// Swap the tracer, propagating it to the attached pool (if any).
    pub fn set_tracer(&self, tracer: Arc<Tracer>) {
        if let Some(pool) = self.executor() {
            pool.set_tracer(tracer.clone());
        }
        *lock_unpoisoned(&self.tracer) = tracer;
    }

    pub fn tracer(&self) -> Arc<Tracer> {
        lock_unpoisoned(&self.tracer).clone()
    }

    /// Share an existing pool (e.g. the `SimCluster`'s) instead of
    /// spawning a new one.
    pub fn set_executor(&self, pool: Option<Arc<ThreadPool>>) {
        *lock_unpoisoned(&self.executor) = pool;
    }

    /// The attached executor, if any.
    pub fn executor(&self) -> Option<Arc<ThreadPool>> {
        lock_unpoisoned(&self.executor).clone()
    }

    pub(crate) fn fresh_id(&self) -> usize {
        self.next_id.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Create a dataset from local data, split into `partitions` chunks
    /// (Spark's `sc.parallelize`).
    pub fn parallelize<T: Clone + Send + Sync + 'static>(
        self: &Arc<Self>,
        data: Vec<T>,
        partitions: usize,
    ) -> Dataset<T> {
        Dataset::from_vec(self.clone(), data, partitions)
    }

    /// Broadcast a value to all (simulated) machines. Cheap Arc clone
    /// in-process; the *cost* is charged by the caller via
    /// `SimCluster::charge_broadcast` (algorithms know the byte size).
    pub fn broadcast<T>(&self, value: T) -> Broadcast<T> {
        Broadcast {
            value: Arc::new(value),
        }
    }

    pub fn stats(&self) -> (u64, u64, u64) {
        (
            self.tasks_run.load(Ordering::Relaxed),
            self.cache_hits.load(Ordering::Relaxed),
            self.recoveries.load(Ordering::Relaxed),
        )
    }
}

/// A broadcast variable (Fig. A9: `ctx.broadcast(V)`). Clone is O(1) and
/// the payload is shared across worker threads.
pub struct Broadcast<T> {
    value: Arc<T>,
}

impl<T> Clone for Broadcast<T> {
    fn clone(&self) -> Self {
        Broadcast {
            value: self.value.clone(),
        }
    }
}

impl<T> Broadcast<T> {
    pub fn value(&self) -> &T {
        &self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelize_and_broadcast() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize((0..10).collect::<Vec<i64>>(), 3);
        assert_eq!(d.num_partitions(), 3);
        assert_eq!(d.collect().unwrap(), (0..10).collect::<Vec<_>>());
        let b = ctx.broadcast(vec![1, 2, 3]);
        assert_eq!(b.value().len(), 3);
        let b2 = b.clone();
        assert_eq!(b2.value()[0], 1);
    }

    #[test]
    fn context_stats_track_tasks() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize(vec![1, 2, 3, 4], 2).map(|x| x * 2);
        let _ = d.collect().unwrap();
        let (tasks, _, _) = ctx.stats();
        assert!(tasks >= 2); // at least one task per partition
    }

    #[test]
    fn retry_policy_defaults_and_swap() {
        let ctx = EngineContext::new();
        let p = ctx.retry_policy();
        assert_eq!(p.max_attempts, 4);
        ctx.set_retry_policy(RetryPolicy {
            max_attempts: 2,
            ..Default::default()
        });
        assert_eq!(ctx.retry_policy().max_attempts, 2);
        assert_eq!(ctx.checkpoint_hits(), 0);
    }

    #[test]
    fn backoff_never_overshoots_the_budget() {
        let p = RetryPolicy {
            max_attempts: 10,
            backoff_base: Duration::from_millis(10),
            timeout: Duration::from_millis(25),
        };
        assert_eq!(p.backoff_for(1), Duration::from_millis(10));
        assert_eq!(p.backoff_for(2), Duration::from_millis(20));
        // plenty of budget left: sleep the exponential backoff
        assert_eq!(p.next_backoff(1, Duration::ZERO), Some(Duration::from_millis(10)));
        // 20ms backoff vs 15ms remaining: refused, not clamped-and-slept
        assert_eq!(p.next_backoff(2, Duration::from_millis(10)), None);
        // budget already spent
        assert_eq!(p.next_backoff(1, Duration::from_millis(25)), None);
        assert_eq!(p.next_backoff(1, Duration::from_secs(9)), None);
        // attempt limit
        assert_eq!(p.next_backoff(10, Duration::ZERO), None);
        // huge attempt index saturates the exponent instead of overflowing
        assert!(p.backoff_for(1000) >= p.backoff_for(17));
    }

    #[test]
    fn executor_attach_and_share() {
        let ctx = EngineContext::new().with_executor(2);
        let pool = ctx.executor().expect("pool attached");
        assert_eq!(pool.threads(), 2);
        let other = EngineContext::new();
        other.set_executor(Some(pool.clone()));
        assert!(other.executor().is_some());
        other.set_executor(None);
        assert!(other.executor().is_none());
    }
}
