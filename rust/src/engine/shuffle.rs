//! Shuffle machinery: hash partitioning + shuffle-side combine for the
//! wide dependencies (`reduce_by_key`, `group_by_key`, `join`).
//!
//! All intermediate state uses *insertion-ordered* maps ([`OrderedMap`])
//! instead of `std::collections::HashMap`, whose per-instance random seed
//! would make output order (and, for non-commutative combine functions,
//! even values) vary run to run. With insertion ordering, shuffle output
//! is a pure function of the input stream order — identical across runs
//! and across executor thread counts, which is the engine's determinism
//! contract (see `crate::exec`).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

use super::dataset::Dataset;
use crate::cluster::SimCluster;
use crate::error::Result;

/// Deterministic bucket for a key.
pub fn bucket_of<K: Hash>(key: &K, parts: usize) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() % parts as u64) as usize
}

/// A hash map that remembers first-insertion order: `entries` is the
/// canonical (ordered) storage, `idx` the key -> position index.
pub(crate) struct OrderedMap<K, V> {
    // mli-lint: allow(D001) lookup-only index; iteration always uses `entries`
    idx: HashMap<K, usize>,
    entries: Vec<(K, V)>,
}

impl<K: Clone + Hash + Eq, V: Clone> OrderedMap<K, V> {
    pub(crate) fn new() -> OrderedMap<K, V> {
        OrderedMap {
            // mli-lint: allow(D001) lookup-only index (see field docs)
            idx: HashMap::new(),
            entries: Vec::new(),
        }
    }

    /// Insert, or combine with the existing value via `f(old, new)`.
    pub(crate) fn upsert(&mut self, k: K, v: V, f: &impl Fn(V, V) -> V) {
        match self.idx.get(&k) {
            Some(&i) => {
                let old = self.entries[i].1.clone();
                self.entries[i].1 = f(old, v);
            }
            None => {
                self.idx.insert(k.clone(), self.entries.len());
                self.entries.push((k, v));
            }
        }
    }

    /// Entries in first-insertion order.
    pub(crate) fn into_entries(self) -> Vec<(K, V)> {
        self.entries
    }
}

/// Map-side combine + hash shuffle + reduce-side merge. Returns one bucket
/// of combined (K, V) pairs per output partition.
///
/// Combines *within each source partition first* (Spark's map-side
/// combine), so shuffle volume is O(distinct keys) not O(records) — the
/// difference the paper leans on when it calls Mahout's SGD
/// "communication intensive".
///
/// Deterministic: source partitions are drained in index order, keys keep
/// first-seen order, and values combine in stream order.
pub fn shuffle_reduce<K, V>(
    parent: &Dataset<(K, V)>,
    parts: usize,
    f: &impl Fn(V, V) -> V,
) -> Result<Vec<Vec<(K, V)>>>
where
    K: Clone + Hash + Eq + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    shuffle_reduce_on(parent, parts, f, None)
}

/// [`shuffle_reduce`] with its bucket transfers routed through a
/// simulated cluster's network fault layer: the shuffle runs as one
/// cluster round, and each (source partition -> bucket) message goes
/// through `SimCluster::net_transfer` with placement from
/// `assign_machine` — so it is charged, retried against drop windows,
/// degraded, or failed (`Error::NetFault`) by any active link faults.
/// The merged *values* never travel through the fault layer: output is
/// bitwise-identical to the plain shuffle whenever every message lands.
pub fn shuffle_reduce_on<K, V>(
    parent: &Dataset<(K, V)>,
    parts: usize,
    f: &impl Fn(V, V) -> V,
    cluster: Option<&SimCluster>,
) -> Result<Vec<Vec<(K, V)>>>
where
    K: Clone + Hash + Eq + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    // materialize parents (parallel when the context has an executor and
    // this runs on the driver thread; inline-serial inside a pool task)
    let src = parent.partitions()?;
    if let Some(c) = cluster {
        c.begin_round();
    }
    let result = (|| {
        let mut buckets: Vec<OrderedMap<K, V>> =
            (0..parts).map(|_| OrderedMap::new()).collect();
        for (sp, part) in src.iter().enumerate() {
            // map-side combine
            let mut local: OrderedMap<K, V> = OrderedMap::new();
            for (k, v) in part.iter() {
                local.upsert(k.clone(), v.clone(), f);
            }
            let entries = local.into_entries();
            if let Some(c) = cluster {
                charge_bucket_transfers(c, sp, parts, entries.iter().map(|(k, _)| k))?;
            }
            // shuffle into reduce-side buckets
            for (k, v) in entries {
                let b = bucket_of(&k, parts);
                buckets[b].upsert(k, v, f);
            }
        }
        Ok(buckets.into_iter().map(|m| m.into_entries()).collect())
    })();
    if let Some(c) = cluster {
        c.end_round();
    }
    result
}

/// Hash shuffle with grouping (no combine function).
pub fn shuffle_group<K, V>(
    parent: &Dataset<(K, V)>,
    parts: usize,
) -> Result<Vec<Vec<(K, Vec<V>)>>>
where
    K: Clone + Hash + Eq + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    shuffle_group_on(parent, parts, None)
}

/// [`shuffle_group`] through a simulated cluster's network fault layer;
/// see [`shuffle_reduce_on`] for the transfer semantics. Grouping ships
/// every record (no map-side combine), so its messages are proportionally
/// larger.
pub fn shuffle_group_on<K, V>(
    parent: &Dataset<(K, V)>,
    parts: usize,
    cluster: Option<&SimCluster>,
) -> Result<Vec<Vec<(K, Vec<V>)>>>
where
    K: Clone + Hash + Eq + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    let src = parent.partitions()?;
    if let Some(c) = cluster {
        c.begin_round();
    }
    let result = (|| {
        let mut buckets: Vec<OrderedMap<K, Vec<V>>> =
            (0..parts).map(|_| OrderedMap::new()).collect();
        for (sp, part) in src.iter().enumerate() {
            if let Some(c) = cluster {
                charge_bucket_transfers(c, sp, parts, part.iter().map(|(k, _)| k))?;
            }
            for (k, v) in part.iter() {
                buckets[bucket_of(k, parts)].upsert(k.clone(), vec![v.clone()], &|mut a, b| {
                    a.extend(b);
                    a
                });
            }
        }
        Ok(buckets.into_iter().map(|m| m.into_entries()).collect())
    })();
    if let Some(c) = cluster {
        c.end_round();
    }
    result
}

/// Charge one source partition's per-bucket shuffle messages through the
/// cluster's fault-aware transfer path. Buckets are visited in index
/// order and sizes estimated from the record count, so the charge
/// sequence (and hence every per-message fault roll) is deterministic.
fn charge_bucket_transfers<'a, K: Hash + 'a>(
    cluster: &SimCluster,
    src_partition: usize,
    parts: usize,
    keys: impl Iterator<Item = &'a K>,
) -> Result<()> {
    let mut counts = vec![0u64; parts];
    for k in keys {
        counts[bucket_of(k, parts)] += 1;
    }
    let record_bytes = std::mem::size_of::<K>().max(8) as u64 * 2;
    let src_m = cluster.assign_machine(src_partition)?;
    for (b, n) in counts.iter().enumerate() {
        if *n == 0 {
            continue;
        }
        let dst_m = cluster.assign_machine(b)?;
        cluster.net_transfer(src_m, dst_m, n * record_bytes)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;

    #[test]
    fn bucket_deterministic_and_in_range() {
        for parts in [1, 3, 16] {
            for k in 0..100 {
                let b = bucket_of(&k, parts);
                assert!(b < parts);
                assert_eq!(b, bucket_of(&k, parts));
            }
        }
    }

    #[test]
    fn keys_land_in_one_bucket_only() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize(
            (0..50).map(|i| (i % 7, 1u64)).collect::<Vec<_>>(),
            5,
        );
        let buckets = shuffle_reduce(&d, 5, &|a, b| a + b).unwrap();
        // each key appears in exactly one bucket with the full count
        let mut seen = HashMap::new();
        for (b, bucket) in buckets.iter().enumerate() {
            for (k, v) in bucket {
                assert!(seen.insert(*k, (b, *v)).is_none(), "key {k} duplicated");
            }
        }
        assert_eq!(seen.len(), 7);
        for (k, (_, v)) in seen {
            let expect = (0..50).filter(|i| i % 7 == k).count() as u64;
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn group_collects_all_values() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize(vec![("a", 1), ("a", 2), ("b", 3)], 2);
        let buckets = shuffle_group(&d, 2).unwrap();
        let all: Vec<(&str, Vec<i32>)> = buckets.into_iter().flatten().collect();
        let a = all.iter().find(|(k, _)| *k == "a").unwrap();
        assert_eq!(a.1.len(), 2);
    }

    #[test]
    fn shuffle_output_order_is_deterministic() {
        // two identical runs produce byte-identical output order (no
        // HashMap RandomState leakage)
        let run = || {
            let ctx = EngineContext::new();
            let d = ctx.parallelize(
                (0..200).map(|i| (i % 17, i as u64)).collect::<Vec<_>>(),
                4,
            );
            shuffle_reduce(&d, 4, &|a, b| a + b).unwrap()
        };
        assert_eq!(run(), run());
    }
}
