//! Shuffle machinery: hash partitioning + shuffle-side combine for the
//! wide dependencies (`reduce_by_key`, `group_by_key`, `join`).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

use super::dataset::Dataset;
use crate::error::Result;

/// Deterministic bucket for a key.
pub fn bucket_of<K: Hash>(key: &K, parts: usize) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() % parts as u64) as usize
}

/// Map-side combine + hash shuffle + reduce-side merge. Returns one bucket
/// of combined (K, V) pairs per output partition.
///
/// Combines *within each source partition first* (Spark's map-side
/// combine), so shuffle volume is O(distinct keys) not O(records) — the
/// difference the paper leans on when it calls Mahout's SGD
/// "communication intensive".
pub fn shuffle_reduce<K, V>(
    parent: &Dataset<(K, V)>,
    parts: usize,
    f: &impl Fn(V, V) -> V,
) -> Result<Vec<Vec<(K, V)>>>
where
    K: Clone + Hash + Eq + 'static,
    V: Clone + 'static,
{
    let mut buckets: Vec<HashMap<K, V>> = (0..parts).map(|_| HashMap::new()).collect();
    for p in 0..parent.num_partitions() {
        // map-side combine
        let mut local: HashMap<K, V> = HashMap::new();
        for (k, v) in parent.partition(p)?.iter() {
            match local.remove(k) {
                None => {
                    local.insert(k.clone(), v.clone());
                }
                Some(prev) => {
                    local.insert(k.clone(), f(prev, v.clone()));
                }
            }
        }
        // shuffle into reduce-side buckets
        for (k, v) in local {
            let b = bucket_of(&k, parts);
            match buckets[b].remove(&k) {
                None => {
                    buckets[b].insert(k, v);
                }
                Some(prev) => {
                    buckets[b].insert(k, f(prev, v));
                }
            }
        }
    }
    Ok(buckets
        .into_iter()
        .map(|m| m.into_iter().collect())
        .collect())
}

/// Hash shuffle with grouping (no combine function).
pub fn shuffle_group<K, V>(
    parent: &Dataset<(K, V)>,
    parts: usize,
) -> Result<Vec<Vec<(K, Vec<V>)>>>
where
    K: Clone + Hash + Eq + 'static,
    V: Clone + 'static,
{
    let mut buckets: Vec<HashMap<K, Vec<V>>> = (0..parts).map(|_| HashMap::new()).collect();
    for p in 0..parent.num_partitions() {
        for (k, v) in parent.partition(p)?.iter() {
            buckets[bucket_of(k, parts)]
                .entry(k.clone())
                .or_default()
                .push(v.clone());
        }
    }
    Ok(buckets
        .into_iter()
        .map(|m| m.into_iter().collect())
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;

    #[test]
    fn bucket_deterministic_and_in_range() {
        for parts in [1, 3, 16] {
            for k in 0..100 {
                let b = bucket_of(&k, parts);
                assert!(b < parts);
                assert_eq!(b, bucket_of(&k, parts));
            }
        }
    }

    #[test]
    fn keys_land_in_one_bucket_only() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize(
            (0..50).map(|i| (i % 7, 1u64)).collect::<Vec<_>>(),
            5,
        );
        let buckets = shuffle_reduce(&d, 5, &|a, b| a + b).unwrap();
        // each key appears in exactly one bucket with the full count
        let mut seen = HashMap::new();
        for (b, bucket) in buckets.iter().enumerate() {
            for (k, v) in bucket {
                assert!(seen.insert(*k, (b, *v)).is_none(), "key {k} duplicated");
            }
        }
        assert_eq!(seen.len(), 7);
        for (k, (_, v)) in seen {
            let expect = (0..50).filter(|i| i % 7 == k).count() as u64;
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn group_collects_all_values() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize(vec![("a", 1), ("a", 2), ("b", 3)], 2);
        let buckets = shuffle_group(&d, 2).unwrap();
        let all: Vec<(&str, Vec<i32>)> = buckets.into_iter().flatten().collect();
        let a = all.iter().find(|(k, _)| *k == "a").unwrap();
        assert_eq!(a.1.len(), 2);
    }
}
