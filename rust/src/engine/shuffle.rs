//! Shuffle machinery: hash partitioning + shuffle-side combine for the
//! wide dependencies (`reduce_by_key`, `group_by_key`, `join`).
//!
//! All intermediate state uses *insertion-ordered* maps ([`OrderedMap`])
//! instead of `std::collections::HashMap`, whose per-instance random seed
//! would make output order (and, for non-commutative combine functions,
//! even values) vary run to run. With insertion ordering, shuffle output
//! is a pure function of the input stream order — identical across runs
//! and across executor thread counts, which is the engine's determinism
//! contract (see `crate::exec`).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

use super::dataset::Dataset;
use crate::error::Result;

/// Deterministic bucket for a key.
pub fn bucket_of<K: Hash>(key: &K, parts: usize) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() % parts as u64) as usize
}

/// A hash map that remembers first-insertion order: `entries` is the
/// canonical (ordered) storage, `idx` the key -> position index.
pub(crate) struct OrderedMap<K, V> {
    // mli-lint: allow(D001) lookup-only index; iteration always uses `entries`
    idx: HashMap<K, usize>,
    entries: Vec<(K, V)>,
}

impl<K: Clone + Hash + Eq, V: Clone> OrderedMap<K, V> {
    pub(crate) fn new() -> OrderedMap<K, V> {
        OrderedMap {
            // mli-lint: allow(D001) lookup-only index (see field docs)
            idx: HashMap::new(),
            entries: Vec::new(),
        }
    }

    /// Insert, or combine with the existing value via `f(old, new)`.
    pub(crate) fn upsert(&mut self, k: K, v: V, f: &impl Fn(V, V) -> V) {
        match self.idx.get(&k) {
            Some(&i) => {
                let old = self.entries[i].1.clone();
                self.entries[i].1 = f(old, v);
            }
            None => {
                self.idx.insert(k.clone(), self.entries.len());
                self.entries.push((k, v));
            }
        }
    }

    /// Entries in first-insertion order.
    pub(crate) fn into_entries(self) -> Vec<(K, V)> {
        self.entries
    }
}

/// Map-side combine + hash shuffle + reduce-side merge. Returns one bucket
/// of combined (K, V) pairs per output partition.
///
/// Combines *within each source partition first* (Spark's map-side
/// combine), so shuffle volume is O(distinct keys) not O(records) — the
/// difference the paper leans on when it calls Mahout's SGD
/// "communication intensive".
///
/// Deterministic: source partitions are drained in index order, keys keep
/// first-seen order, and values combine in stream order.
pub fn shuffle_reduce<K, V>(
    parent: &Dataset<(K, V)>,
    parts: usize,
    f: &impl Fn(V, V) -> V,
) -> Result<Vec<Vec<(K, V)>>>
where
    K: Clone + Hash + Eq + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    // materialize parents (parallel when the context has an executor and
    // this runs on the driver thread; inline-serial inside a pool task)
    let src = parent.partitions()?;
    let mut buckets: Vec<OrderedMap<K, V>> = (0..parts).map(|_| OrderedMap::new()).collect();
    for part in &src {
        // map-side combine
        let mut local: OrderedMap<K, V> = OrderedMap::new();
        for (k, v) in part.iter() {
            local.upsert(k.clone(), v.clone(), f);
        }
        // shuffle into reduce-side buckets
        for (k, v) in local.into_entries() {
            let b = bucket_of(&k, parts);
            buckets[b].upsert(k, v, f);
        }
    }
    Ok(buckets.into_iter().map(|m| m.into_entries()).collect())
}

/// Hash shuffle with grouping (no combine function).
pub fn shuffle_group<K, V>(
    parent: &Dataset<(K, V)>,
    parts: usize,
) -> Result<Vec<Vec<(K, Vec<V>)>>>
where
    K: Clone + Hash + Eq + Send + Sync + 'static,
    V: Clone + Send + Sync + 'static,
{
    let src = parent.partitions()?;
    let mut buckets: Vec<OrderedMap<K, Vec<V>>> =
        (0..parts).map(|_| OrderedMap::new()).collect();
    for part in &src {
        for (k, v) in part.iter() {
            buckets[bucket_of(k, parts)].upsert(k.clone(), vec![v.clone()], &|mut a, b| {
                a.extend(b);
                a
            });
        }
    }
    Ok(buckets.into_iter().map(|m| m.into_entries()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;

    #[test]
    fn bucket_deterministic_and_in_range() {
        for parts in [1, 3, 16] {
            for k in 0..100 {
                let b = bucket_of(&k, parts);
                assert!(b < parts);
                assert_eq!(b, bucket_of(&k, parts));
            }
        }
    }

    #[test]
    fn keys_land_in_one_bucket_only() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize(
            (0..50).map(|i| (i % 7, 1u64)).collect::<Vec<_>>(),
            5,
        );
        let buckets = shuffle_reduce(&d, 5, &|a, b| a + b).unwrap();
        // each key appears in exactly one bucket with the full count
        let mut seen = HashMap::new();
        for (b, bucket) in buckets.iter().enumerate() {
            for (k, v) in bucket {
                assert!(seen.insert(*k, (b, *v)).is_none(), "key {k} duplicated");
            }
        }
        assert_eq!(seen.len(), 7);
        for (k, (_, v)) in seen {
            let expect = (0..50).filter(|i| i % 7 == k).count() as u64;
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn group_collects_all_values() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize(vec![("a", 1), ("a", 2), ("b", 3)], 2);
        let buckets = shuffle_group(&d, 2).unwrap();
        let all: Vec<(&str, Vec<i32>)> = buckets.into_iter().flatten().collect();
        let a = all.iter().find(|(k, _)| *k == "a").unwrap();
        assert_eq!(a.1.len(), 2);
    }

    #[test]
    fn shuffle_output_order_is_deterministic() {
        // two identical runs produce byte-identical output order (no
        // HashMap RandomState leakage)
        let run = || {
            let ctx = EngineContext::new();
            let d = ctx.parallelize(
                (0..200).map(|i| (i % 17, i as u64)).collect::<Vec<_>>(),
                4,
            );
            shuffle_reduce(&d, 4, &|a, b| a + b).unwrap()
        };
        assert_eq!(run(), run());
    }
}
