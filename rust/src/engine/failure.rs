//! Failure injection: deterministic task-failure and partition-loss plans
//! for testing the engine's Spark-style recovery (the paper's §IV
//! motivation for building on Spark: "automatic recovery from node
//! failure is a necessity").

use std::collections::{HashMap, HashSet};
use std::sync::Mutex;

/// Deterministic failure plan shared by all datasets of a context.
///
/// Two failure modes:
/// * **task failures** — `fail_times(dataset, partition, n)` makes the
///   next `n` compute attempts of that partition fail; the scheduler
///   retries up to Spark's default 4 attempts.
/// * **partition loss** — recorded by `Dataset::invalidate_partition` via
///   `mark_lost`, used to count lineage recoveries.
///
/// `Send + Sync` (mutex-guarded) so retry accounting stays correct when
/// partition tasks race on the `exec` thread pool: budget decrements are
/// atomic per attempt, and a (dataset, partition) budget is only ever
/// consumed by the one task computing that partition.
#[derive(Default)]
pub struct FailurePlan {
    fail_budget: Mutex<HashMap<(usize, usize), usize>>,
    lost: Mutex<HashSet<(usize, usize)>>,
}

impl FailurePlan {
    /// Make the next `n` compute attempts of (dataset, partition) fail.
    pub fn fail_times(&self, dataset: usize, partition: usize, n: usize) {
        self.fail_budget
            .lock()
            .unwrap()
            .insert((dataset, partition), n);
    }

    /// Called by the scheduler before each attempt; consumes one failure
    /// from the budget if present.
    pub fn should_fail(&self, dataset: usize, partition: usize) -> bool {
        let mut b = self.fail_budget.lock().unwrap();
        match b.get_mut(&(dataset, partition)) {
            Some(n) if *n > 0 => {
                *n -= 1;
                true
            }
            _ => false,
        }
    }

    pub(crate) fn mark_lost(&self, dataset: usize, partition: usize) {
        self.lost.lock().unwrap().insert((dataset, partition));
    }

    pub(crate) fn was_lost(&self, dataset: usize, partition: usize) -> bool {
        self.lost.lock().unwrap().contains(&(dataset, partition))
    }

    /// Total partitions ever marked lost (for reporting).
    pub fn losses(&self) -> usize {
        self.lost.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;

    #[test]
    fn budget_consumed() {
        let p = FailurePlan::default();
        p.fail_times(1, 0, 2);
        assert!(p.should_fail(1, 0));
        assert!(p.should_fail(1, 0));
        assert!(!p.should_fail(1, 0));
        assert!(!p.should_fail(9, 9));
    }

    #[test]
    fn transient_task_failure_retried_to_success() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize((0..10).collect::<Vec<i32>>(), 2).map(|x| x * 3);
        // fail the first 2 attempts of partition 1; retry budget is 4
        ctx.failures.fail_times(d.id(), 1, 2);
        let out = d.collect().unwrap();
        assert_eq!(out, (0..10).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn permanent_failure_exhausts_retries() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize(vec![1, 2, 3], 1).map(|x| *x);
        ctx.failures.fail_times(d.id(), 0, 100);
        let err = d.collect().unwrap_err();
        assert!(err.to_string().contains("injected task failure"));
    }

    #[test]
    fn loss_tracking() {
        let p = FailurePlan::default();
        p.mark_lost(3, 1);
        assert!(p.was_lost(3, 1));
        assert!(!p.was_lost(3, 0));
        assert_eq!(p.losses(), 1);
    }
}
