//! Failure injection: deterministic task-failure and partition-loss plans
//! for testing the engine's Spark-style recovery (the paper's §IV
//! motivation for building on Spark: "automatic recovery from node
//! failure is a necessity").

use crate::exec::lock_unpoisoned;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

/// Deterministic failure plan shared by all datasets of a context.
///
/// Two failure modes:
/// * **task failures** — `fail_times(dataset, partition, n)` makes the
///   next `n` compute attempts of that partition fail; the scheduler
///   retries up to Spark's default 4 attempts.
/// * **partition loss** — recorded by `Dataset::invalidate_partition` via
///   `mark_lost`, used to count lineage recoveries.
///
/// `Send + Sync` (mutex-guarded) so retry accounting stays correct when
/// partition tasks race on the `exec` thread pool: budget decrements are
/// atomic per attempt, and a (dataset, partition) budget is only ever
/// consumed by the one task computing that partition.
// Ordered collections so any future iteration (reporting, draining) is
// deterministic by construction — (dataset, partition) keys are Ord.
#[derive(Default)]
pub struct FailurePlan {
    fail_budget: Mutex<BTreeMap<(usize, usize), usize>>,
    lost: Mutex<BTreeSet<(usize, usize)>>,
}

impl FailurePlan {
    /// Make the next `n` compute attempts of (dataset, partition) fail.
    /// `n == 0` clears the entry: a zero budget can never fire, so leaving
    /// it in the map would only accumulate dead keys.
    pub fn fail_times(&self, dataset: usize, partition: usize, n: usize) {
        let mut b = lock_unpoisoned(&self.fail_budget);
        if n == 0 {
            b.remove(&(dataset, partition));
        } else {
            b.insert((dataset, partition), n);
        }
    }

    /// Called by the scheduler before each attempt; consumes one failure
    /// from the budget if present.
    pub fn should_fail(&self, dataset: usize, partition: usize) -> bool {
        let mut b = lock_unpoisoned(&self.fail_budget);
        match b.get_mut(&(dataset, partition)) {
            Some(n) if *n > 0 => {
                *n -= 1;
                true
            }
            _ => false,
        }
    }

    /// (dataset, partition) keys with failure budget still to burn.
    pub fn pending_failures(&self) -> usize {
        lock_unpoisoned(&self.fail_budget)
            .values()
            .filter(|&&n| n > 0)
            .count()
    }

    pub(crate) fn mark_lost(&self, dataset: usize, partition: usize) {
        lock_unpoisoned(&self.lost).insert((dataset, partition));
    }

    pub(crate) fn was_lost(&self, dataset: usize, partition: usize) -> bool {
        lock_unpoisoned(&self.lost).contains(&(dataset, partition))
    }

    /// Total partitions ever marked lost (for reporting).
    pub fn losses(&self) -> usize {
        lock_unpoisoned(&self.lost).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn budget_consumed() {
        let p = FailurePlan::default();
        p.fail_times(1, 0, 2);
        assert!(p.should_fail(1, 0));
        assert!(p.should_fail(1, 0));
        assert!(!p.should_fail(1, 0));
        assert!(!p.should_fail(9, 9));
    }

    #[test]
    fn zero_budget_removes_entry() {
        let p = FailurePlan::default();
        p.fail_times(1, 0, 0);
        assert_eq!(p.pending_failures(), 0);
        assert!(!p.should_fail(1, 0));
        // and resetting an existing budget to 0 clears it too
        p.fail_times(1, 0, 3);
        assert_eq!(p.pending_failures(), 1);
        p.fail_times(1, 0, 0);
        assert_eq!(p.pending_failures(), 0);
        assert!(!p.should_fail(1, 0));
    }

    #[test]
    fn transient_task_failure_retried_to_success() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize((0..10).collect::<Vec<i32>>(), 2).map(|x| x * 3);
        // fail the first 2 attempts of partition 1; retry budget is 4
        ctx.failures.fail_times(d.id(), 1, 2);
        let out = d.collect().unwrap();
        assert_eq!(out, (0..10).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn permanent_failure_exhausts_retries() {
        let ctx = EngineContext::new();
        let d = ctx.parallelize(vec![1, 2, 3], 1).map(|x| *x);
        ctx.failures.fail_times(d.id(), 0, 100);
        let err = d.collect().unwrap_err();
        assert!(err.to_string().contains("injected task failure"));
    }

    #[test]
    fn budget_exact_under_concurrent_attempts() {
        // Stress the single-mutex decrement: 8 threads hammer
        // `should_fail` on one (dataset, partition) key with a budget of
        // 64. Exactly 64 calls may observe a failure — a double consume
        // or lost decrement would shift the count.
        let p = Arc::new(FailurePlan::default());
        p.fail_times(1, 0, 64);
        let fired = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let p = p.clone();
            let fired = fired.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    if p.should_fail(1, 0) {
                        fired.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(fired.load(Ordering::SeqCst), 64);
        assert!(!p.should_fail(1, 0), "budget must be fully consumed");
    }

    #[test]
    fn retry_budget_boundary_is_exactly_four_attempts() {
        // Boundary of Spark's spark.task.maxFailures = 4: 3 injected
        // failures -> the 4th attempt succeeds; 4 injected failures ->
        // the budget is exhausted and the action errors.
        let ctx = EngineContext::new();
        let d = ctx.parallelize((0..8).collect::<Vec<i32>>(), 1).map(|x| x + 1);
        ctx.failures.fail_times(d.id(), 0, 3);
        assert!(d.collect().is_ok(), "3 failures must retry to success");
        let d2 = ctx.parallelize((0..8).collect::<Vec<i32>>(), 1).map(|x| x + 1);
        ctx.failures.fail_times(d2.id(), 0, 4);
        assert!(d2.collect().is_err(), "4 failures must exhaust the budget");
    }

    #[test]
    fn retry_budget_not_double_consumed_under_parallel_evaluation() {
        // 8 partitions with 3 injected failures each, evaluated on a
        // 4-thread pool: every partition must succeed on its 4th attempt,
        // and the task counter must land on exactly 8 * (4 attempts on
        // the derived dataset + 1 base-partition compute) = 40 — any
        // double consume or off-by-one under concurrency would shift it.
        let ctx = EngineContext::new().with_executor(4);
        let d = ctx
            .parallelize((0..64).collect::<Vec<i64>>(), 8)
            .map(|x| x * 2);
        for part in 0..8 {
            ctx.failures.fail_times(d.id(), part, 3);
        }
        let out = d.collect().unwrap();
        assert_eq!(out, (0..64).map(|x| x * 2).collect::<Vec<_>>());
        assert_eq!(ctx.stats().0, 40);
    }

    #[test]
    fn loss_tracking() {
        let p = FailurePlan::default();
        p.mark_lost(3, 1);
        assert!(p.was_lost(3, 1));
        assert!(!p.was_lost(3, 0));
        assert_eq!(p.losses(), 1);
    }
}
