//! MLVector — the vector type of the MLI API (Fig. A4 uses `MLVector` for
//! weights, gradients, and table rows cast to feature vectors).

use std::ops::{Add, Index, IndexMut, Mul, Sub};

use crate::error::{Error, Result};

/// Dense f64 vector with MATLAB-ish arithmetic.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MLVector {
    data: Vec<f64>,
}

impl MLVector {
    pub fn new(data: Vec<f64>) -> MLVector {
        MLVector { data }
    }

    pub fn zeros(n: usize) -> MLVector {
        MLVector { data: vec![0.0; n] }
    }

    pub fn ones(n: usize) -> MLVector {
        MLVector { data: vec![1.0; n] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn from_f32(xs: &[f32]) -> MLVector {
        MLVector::new(xs.iter().map(|&x| x as f64).collect())
    }

    /// Sub-vector `[lo, hi)` (Fig. A4: `vec.slice(1, vec.length)`).
    pub fn slice(&self, lo: usize, hi: usize) -> MLVector {
        MLVector::new(self.data[lo..hi].to_vec())
    }

    fn check_len(&self, other: &MLVector, op: &str) -> Result<()> {
        if self.len() != other.len() {
            return Err(Error::Shape(format!(
                "{op}: length mismatch {} vs {}",
                self.len(),
                other.len()
            )));
        }
        Ok(())
    }

    /// Dot product (`x dot w` in Fig. A4).
    pub fn dot(&self, other: &MLVector) -> Result<f64> {
        self.check_len(other, "dot")?;
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a * b)
            .sum())
    }

    /// `self + other` (Fig. A4 `_ plus _` in the reduce).
    pub fn plus(&self, other: &MLVector) -> Result<MLVector> {
        self.check_len(other, "plus")?;
        Ok(MLVector::new(
            self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        ))
    }

    pub fn minus(&self, other: &MLVector) -> Result<MLVector> {
        self.check_len(other, "minus")?;
        Ok(MLVector::new(
            self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        ))
    }

    /// Scalar multiply (`x times (...)` in Fig. A4).
    pub fn times(&self, s: f64) -> MLVector {
        MLVector::new(self.data.iter().map(|a| a * s).collect())
    }

    /// In-place axpy: `self += alpha * other`. The SGD hot path —
    /// avoids the two allocations of `plus(times(..))`.
    pub fn axpy(&mut self, alpha: f64, other: &MLVector) -> Result<()> {
        self.check_len(other, "axpy")?;
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f64) {
        for a in &mut self.data {
            *a *= s;
        }
    }

    pub fn norm2(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.sum() / self.len() as f64
        }
    }

    pub fn iter(&self) -> std::slice::Iter<'_, f64> {
        self.data.iter()
    }
}

impl Index<usize> for MLVector {
    type Output = f64;
    fn index(&self, i: usize) -> &f64 {
        &self.data[i]
    }
}

impl IndexMut<usize> for MLVector {
    fn index_mut(&mut self, i: usize) -> &mut f64 {
        &mut self.data[i]
    }
}

impl Add for &MLVector {
    type Output = MLVector;
    fn add(self, rhs: &MLVector) -> MLVector {
        self.plus(rhs).expect("vector add: length mismatch")
    }
}

impl Sub for &MLVector {
    type Output = MLVector;
    fn sub(self, rhs: &MLVector) -> MLVector {
        self.minus(rhs).expect("vector sub: length mismatch")
    }
}

impl Mul<f64> for &MLVector {
    type Output = MLVector;
    fn mul(self, s: f64) -> MLVector {
        self.times(s)
    }
}

impl From<Vec<f64>> for MLVector {
    fn from(v: Vec<f64>) -> MLVector {
        MLVector::new(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = MLVector::new(vec![1., 2., 3.]);
        let b = MLVector::new(vec![4., 5., 6.]);
        assert_eq!(a.dot(&b).unwrap(), 32.0);
        assert_eq!(a.plus(&b).unwrap().as_slice(), &[5., 7., 9.]);
        assert_eq!(b.minus(&a).unwrap().as_slice(), &[3., 3., 3.]);
        assert_eq!(a.times(2.0).as_slice(), &[2., 4., 6.]);
        assert_eq!((&a + &b).as_slice(), &[5., 7., 9.]);
        assert_eq!((&b - &a).as_slice(), &[3., 3., 3.]);
        assert_eq!((&a * 3.0).as_slice(), &[3., 6., 9.]);
    }

    #[test]
    fn length_mismatch_errors() {
        let a = MLVector::zeros(2);
        let b = MLVector::zeros(3);
        assert!(a.dot(&b).is_err());
        assert!(a.plus(&b).is_err());
        assert!(a.minus(&b).is_err());
        let mut c = a.clone();
        assert!(c.axpy(1.0, &b).is_err());
    }

    #[test]
    fn axpy_matches_plus_times() {
        let mut a = MLVector::new(vec![1., 2.]);
        let g = MLVector::new(vec![10., 20.]);
        let want = a.plus(&g.times(-0.5)).unwrap();
        a.axpy(-0.5, &g).unwrap();
        assert_eq!(a, want);
    }

    #[test]
    fn slice_and_norms() {
        let v = MLVector::new(vec![3., 4., 5.]);
        assert_eq!(v.slice(0, 2).as_slice(), &[3., 4.]);
        assert!((v.slice(0, 2).norm2() - 5.0).abs() < 1e-12);
        assert_eq!(v.sum(), 12.0);
        assert_eq!(v.mean(), 4.0);
        assert_eq!(MLVector::zeros(0).mean(), 0.0);
    }

    #[test]
    fn f32_roundtrip() {
        let v = MLVector::new(vec![1.5, -2.25]);
        assert_eq!(MLVector::from_f32(&v.to_f32()), v);
    }
}
