//! Row-major dense f64 matrix — the workhorse storage behind
//! [`super::LocalMatrix`].

use crate::error::{Error, Result};
use crate::util::rng::Rng;

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DenseMatrix {
    pub fn new(rows: usize, cols: usize, data: Vec<f64>) -> Result<DenseMatrix> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "dense: data len {} != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(DenseMatrix { rows, cols, data })
    }

    pub fn zeros(rows: usize, cols: usize) -> DenseMatrix {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Uniform [0,1) entries (Fig. A9 `LocalMatrix.rand(m, k)`).
    pub fn rand(rows: usize, cols: usize, rng: &mut Rng) -> DenseMatrix {
        DenseMatrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.f64()).collect(),
        }
    }

    /// Standard-normal entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> DenseMatrix {
        DenseMatrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal()).collect(),
        }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Result<DenseMatrix> {
        let r = rows.len();
        let c = rows.first().map(|v| v.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for (i, row) in rows.iter().enumerate() {
            if row.len() != c {
                return Err(Error::Shape(format!(
                    "from_rows: row {i} has {} cols, expected {c}",
                    row.len()
                )));
            }
            data.extend_from_slice(row);
        }
        DenseMatrix::new(r, c, data)
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness on big matrices
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Matrix multiply with ikj loop order (row-major friendly).
    pub fn matmul(&self, other: &DenseMatrix) -> Result<DenseMatrix> {
        if self.cols != other.rows {
            return Err(Error::Shape(format!(
                "matmul: {}x{} * {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = DenseMatrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let arow = self.row(i);
            let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for (k, &aik) in arow.iter().enumerate() {
                if aik == 0.0 {
                    continue;
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += aik * b;
                }
            }
        }
        Ok(out)
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Result<Vec<f64>> {
        if v.len() != self.cols {
            return Err(Error::Shape(format!(
                "matvec: {}x{} * {}",
                self.rows,
                self.cols,
                v.len()
            )));
        }
        Ok((0..self.rows)
            .map(|r| self.row(r).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect())
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> DenseMatrix {
        DenseMatrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Elementwise combine (checked).
    pub fn zip(&self, other: &DenseMatrix, f: impl Fn(f64, f64) -> f64) -> Result<DenseMatrix> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(Error::Shape(format!(
                "zip: {}x{} vs {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        Ok(DenseMatrix {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, &x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = DenseMatrix::new(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let b = DenseMatrix::new(2, 2, vec![5., 6., 7., 8.]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data, vec![19., 22., 43., 50.]);
        assert!(a.matmul(&DenseMatrix::zeros(3, 3)).is_err());
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = DenseMatrix::rand(4, 6, &mut rng);
        let i6 = DenseMatrix::eye(6);
        assert_eq!(a.matmul(&i6).unwrap(), a);
    }

    #[test]
    fn transpose_blocked_correct() {
        let mut rng = Rng::new(1);
        let a = DenseMatrix::rand(70, 45, &mut rng);
        let t = a.transpose();
        for r in 0..70 {
            for c in 0..45 {
                assert_eq!(a.get(r, c), t.get(c, r));
            }
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(2);
        let a = DenseMatrix::rand(5, 3, &mut rng);
        let v = vec![1.0, -2.0, 0.5];
        let got = a.matvec(&v).unwrap();
        let vm = DenseMatrix::new(3, 1, v.clone()).unwrap();
        let want = a.matmul(&vm).unwrap();
        for r in 0..5 {
            assert!((got[r] - want.get(r, 0)).abs() < 1e-12);
        }
        assert!(a.matvec(&[1.0]).is_err());
    }

    #[test]
    fn map_zip() {
        let a = DenseMatrix::new(1, 3, vec![1., -2., 3.]).unwrap();
        assert_eq!(a.map(f64::abs).data, vec![1., 2., 3.]);
        let b = DenseMatrix::new(1, 3, vec![1., 1., 1.]).unwrap();
        assert_eq!(a.zip(&b, |x, y| x + y).unwrap().data, vec![2., -1., 4.]);
        assert!(a.zip(&DenseMatrix::zeros(2, 2), |x, _| x).is_err());
        assert_eq!(a.max_abs(), 3.0);
    }

    #[test]
    fn from_rows_validates() {
        assert!(DenseMatrix::from_rows(vec![vec![1., 2.], vec![3.]]).is_err());
        let m = DenseMatrix::from_rows(vec![vec![1., 2.], vec![3., 4.]]).unwrap();
        assert_eq!(m.get(1, 0), 3.0);
    }
}
