//! Operator overloads for [`LocalMatrix`] — the Fig. A3 "Arithmetic"
//! family: elementwise matrix±matrix, matrix±scalar, matrix*/scalar,
//! elementwise matrix*matrix and matrix/matrix (MATLAB `.*`, `./`).
//!
//! Panicking operators mirror MATLAB ergonomics for example code; the
//! checked equivalents (`try_add`, ...) are what library code uses.

use std::ops::{Add, Div, Mul, Neg, Sub};

use super::{DenseMatrix, LocalMatrix};
use crate::error::Result;

impl LocalMatrix {
    fn zip_dense(&self, other: &LocalMatrix, f: impl Fn(f64, f64) -> f64) -> Result<LocalMatrix> {
        let a = self.to_dense();
        let b = other.to_dense();
        Ok(LocalMatrix::Dense(a.zip(&b, f)?))
    }

    /// Elementwise add (checked).
    pub fn try_add(&self, other: &LocalMatrix) -> Result<LocalMatrix> {
        self.zip_dense(other, |a, b| a + b)
    }

    /// Elementwise subtract (checked).
    pub fn try_sub(&self, other: &LocalMatrix) -> Result<LocalMatrix> {
        self.zip_dense(other, |a, b| a - b)
    }

    /// Elementwise multiply — MATLAB `.*` (checked).
    pub fn try_mul_elem(&self, other: &LocalMatrix) -> Result<LocalMatrix> {
        self.zip_dense(other, |a, b| a * b)
    }

    /// Elementwise divide — MATLAB `./` (checked).
    pub fn try_div_elem(&self, other: &LocalMatrix) -> Result<LocalMatrix> {
        self.zip_dense(other, |a, b| a / b)
    }

    /// Scalar ops (matA + 5, matA - 5, matA * 5, matA / 5).
    pub fn add_scalar(&self, s: f64) -> LocalMatrix {
        LocalMatrix::Dense(self.to_dense().map(|x| x + s))
    }

    pub fn sub_scalar(&self, s: f64) -> LocalMatrix {
        LocalMatrix::Dense(self.to_dense().map(|x| x - s))
    }

    pub fn mul_scalar(&self, s: f64) -> LocalMatrix {
        match self {
            // scaling preserves sparsity — stay CSR
            LocalMatrix::Sparse(m) => {
                let mut m = m.clone();
                for v in &mut m.values {
                    *v *= s;
                }
                LocalMatrix::Sparse(m)
            }
            LocalMatrix::Dense(m) => LocalMatrix::Dense(m.map(|x| x * s)),
        }
    }

    pub fn div_scalar(&self, s: f64) -> LocalMatrix {
        self.mul_scalar(1.0 / s)
    }

    /// Elementwise map (densifies).
    pub fn map(&self, f: impl Fn(f64) -> f64) -> LocalMatrix {
        LocalMatrix::Dense(self.to_dense().map(f))
    }
}

impl Add for &LocalMatrix {
    type Output = LocalMatrix;
    fn add(self, rhs: &LocalMatrix) -> LocalMatrix {
        self.try_add(rhs).expect("matrix add: shape mismatch")
    }
}

impl Sub for &LocalMatrix {
    type Output = LocalMatrix;
    fn sub(self, rhs: &LocalMatrix) -> LocalMatrix {
        self.try_sub(rhs).expect("matrix sub: shape mismatch")
    }
}

impl Mul<f64> for &LocalMatrix {
    type Output = LocalMatrix;
    fn mul(self, s: f64) -> LocalMatrix {
        self.mul_scalar(s)
    }
}

impl Div<f64> for &LocalMatrix {
    type Output = LocalMatrix;
    fn div(self, s: f64) -> LocalMatrix {
        self.div_scalar(s)
    }
}

impl Add<f64> for &LocalMatrix {
    type Output = LocalMatrix;
    fn add(self, s: f64) -> LocalMatrix {
        self.add_scalar(s)
    }
}

impl Sub<f64> for &LocalMatrix {
    type Output = LocalMatrix;
    fn sub(self, s: f64) -> LocalMatrix {
        self.sub_scalar(s)
    }
}

impl Neg for &LocalMatrix {
    type Output = LocalMatrix;
    fn neg(self) -> LocalMatrix {
        self.mul_scalar(-1.0)
    }
}

impl From<DenseMatrix> for LocalMatrix {
    fn from(m: DenseMatrix) -> LocalMatrix {
        LocalMatrix::Dense(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, d: Vec<f64>) -> LocalMatrix {
        LocalMatrix::dense(rows, cols, d).unwrap()
    }

    #[test]
    fn elementwise_ops() {
        let a = m(2, 2, vec![1., 2., 3., 4.]);
        let b = m(2, 2, vec![10., 20., 30., 40.]);
        assert_eq!((&a + &b), m(2, 2, vec![11., 22., 33., 44.]));
        assert_eq!((&b - &a), m(2, 2, vec![9., 18., 27., 36.]));
        assert_eq!(a.try_mul_elem(&b).unwrap(), m(2, 2, vec![10., 40., 90., 160.]));
        assert_eq!(b.try_div_elem(&a).unwrap(), m(2, 2, vec![10., 10., 10., 10.]));
        assert!(a.try_add(&LocalMatrix::zeros(3, 3)).is_err());
    }

    #[test]
    fn scalar_ops() {
        let a = m(1, 3, vec![1., 2., 3.]);
        assert_eq!((&a + 1.0), m(1, 3, vec![2., 3., 4.]));
        assert_eq!((&a - 1.0), m(1, 3, vec![0., 1., 2.]));
        assert_eq!((&a * 2.0), m(1, 3, vec![2., 4., 6.]));
        assert_eq!((&a / 2.0), m(1, 3, vec![0.5, 1., 1.5]));
        assert_eq!((-&a), m(1, 3, vec![-1., -2., -3.]));
    }

    #[test]
    fn sparse_scale_stays_sparse() {
        let d = m(2, 2, vec![0., 5., 0., 0.]);
        let s = LocalMatrix::Sparse(d.to_sparse());
        let scaled = s.mul_scalar(2.0);
        assert!(scaled.is_sparse());
        assert_eq!(scaled.get(0, 1), 10.0);
    }

    #[test]
    fn map_applies() {
        let a = m(1, 2, vec![-1., 4.]);
        assert_eq!(a.map(f64::abs), m(1, 2, vec![1., 4.]));
    }
}
