//! Dense linear algebra for LocalMatrix (Fig. A3 "Linear Algebra" family):
//! LU solve, inverse, Cholesky, QR, one-sided-Jacobi SVD, symmetric Jacobi
//! eigendecomposition, and numerical rank. No LAPACK in this sandbox —
//! everything is implemented here (and cross-checked by property tests in
//! `rust/tests/proptests.rs`).

use super::dense::DenseMatrix;
use crate::error::{Error, Result};

/// LU decomposition with partial pivoting. Returns (LU-packed, perm, sign).
pub fn lu(a: &DenseMatrix) -> Result<(DenseMatrix, Vec<usize>, f64)> {
    if a.rows != a.cols {
        return Err(Error::Shape(format!("lu: non-square {}x{}", a.rows, a.cols)));
    }
    let n = a.rows;
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut sign = 1.0;
    for k in 0..n {
        // pivot: max |a[i][k]| for i >= k
        let mut p = k;
        let mut pmax = lu.get(k, k).abs();
        for i in k + 1..n {
            let v = lu.get(i, k).abs();
            if v > pmax {
                pmax = v;
                p = i;
            }
        }
        if pmax < 1e-300 {
            return Err(Error::Numerical(format!("lu: singular at pivot {k}")));
        }
        if p != k {
            for c in 0..n {
                let t = lu.get(k, c);
                lu.set(k, c, lu.get(p, c));
                lu.set(p, c, t);
            }
            perm.swap(k, p);
            sign = -sign;
        }
        let pivot = lu.get(k, k);
        for i in k + 1..n {
            let m = lu.get(i, k) / pivot;
            lu.set(i, k, m);
            if m != 0.0 {
                for c in k + 1..n {
                    let v = lu.get(i, c) - m * lu.get(k, c);
                    lu.set(i, c, v);
                }
            }
        }
    }
    Ok((lu, perm, sign))
}

/// Solve A X = B via LU with partial pivoting. B may have many columns.
pub fn solve(a: &DenseMatrix, b: &DenseMatrix) -> Result<DenseMatrix> {
    if a.rows != b.rows {
        return Err(Error::Shape(format!(
            "solve: A is {}x{}, B has {} rows",
            a.rows, a.cols, b.rows
        )));
    }
    let (lu_m, perm, _) = lu(a)?;
    let n = a.rows;
    let m = b.cols;
    let mut x = DenseMatrix::zeros(n, m);
    // apply permutation to B
    for (i, &pi) in perm.iter().enumerate() {
        for c in 0..m {
            x.set(i, c, b.get(pi, c));
        }
    }
    // forward substitution (L has unit diagonal)
    for i in 0..n {
        for k in 0..i {
            let l = lu_m.get(i, k);
            if l != 0.0 {
                for c in 0..m {
                    let v = x.get(i, c) - l * x.get(k, c);
                    x.set(i, c, v);
                }
            }
        }
    }
    // back substitution
    for i in (0..n).rev() {
        for k in i + 1..n {
            let u = lu_m.get(i, k);
            if u != 0.0 {
                for c in 0..m {
                    let v = x.get(i, c) - u * x.get(k, c);
                    x.set(i, c, v);
                }
            }
        }
        let d = lu_m.get(i, i);
        for c in 0..m {
            x.set(i, c, x.get(i, c) / d);
        }
    }
    Ok(x)
}

/// Matrix inverse via LU solve against the identity.
pub fn inverse(a: &DenseMatrix) -> Result<DenseMatrix> {
    solve(a, &DenseMatrix::eye(a.rows))
}

/// Determinant via LU.
pub fn det(a: &DenseMatrix) -> Result<f64> {
    match lu(a) {
        Ok((lu_m, _, sign)) => {
            let mut d = sign;
            for i in 0..a.rows {
                d *= lu_m.get(i, i);
            }
            Ok(d)
        }
        Err(Error::Numerical(_)) => Ok(0.0), // singular => det 0
        Err(e) => Err(e),
    }
}

/// Cholesky factorization A = L L^T for SPD A (lower triangular L).
pub fn cholesky(a: &DenseMatrix) -> Result<DenseMatrix> {
    if a.rows != a.cols {
        return Err(Error::Shape("cholesky: non-square".into()));
    }
    let n = a.rows;
    let mut l = DenseMatrix::zeros(n, n);
    for j in 0..n {
        let mut s = a.get(j, j);
        for p in 0..j {
            s -= l.get(j, p) * l.get(j, p);
        }
        if s <= 0.0 {
            return Err(Error::Numerical(format!(
                "cholesky: matrix not positive definite at column {j}"
            )));
        }
        let d = s.sqrt();
        l.set(j, j, d);
        for i in j + 1..n {
            let mut s = a.get(i, j);
            for p in 0..j {
                s -= l.get(i, p) * l.get(j, p);
            }
            l.set(i, j, s / d);
        }
    }
    Ok(l)
}

/// Solve SPD system via Cholesky (the ALS normal-equation path when run
/// CPU-side; the XLA artifact uses the same algorithm, see
/// python/compile/model.py::spd_solve).
pub fn spd_solve(a: &DenseMatrix, b: &[f64]) -> Result<Vec<f64>> {
    let l = cholesky(a)?;
    let n = a.rows;
    if b.len() != n {
        return Err(Error::Shape("spd_solve: rhs length".into()));
    }
    // forward: L z = b
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for p in 0..i {
            s -= l.get(i, p) * z[p];
        }
        z[i] = s / l.get(i, i);
    }
    // backward: L^T x = z
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for p in i + 1..n {
            s -= l.get(p, i) * x[p];
        }
        x[i] = s / l.get(i, i);
    }
    Ok(x)
}

/// Householder QR: returns (Q, R) with Q m x n orthonormal columns
/// (thin QR), R n x n upper triangular, for m >= n.
pub fn qr(a: &DenseMatrix) -> Result<(DenseMatrix, DenseMatrix)> {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        return Err(Error::Shape("qr: requires rows >= cols".into()));
    }
    let mut r = a.clone();
    // accumulate Q as product of Householder reflectors applied to I
    let mut qt = DenseMatrix::eye(m); // Q^T, m x m
    for k in 0..n {
        // Householder vector for column k
        let mut norm = 0.0;
        for i in k..m {
            norm += r.get(i, k) * r.get(i, k);
        }
        let norm = norm.sqrt();
        if norm < 1e-300 {
            continue;
        }
        let alpha = if r.get(k, k) >= 0.0 { -norm } else { norm };
        let mut v = vec![0.0; m];
        for i in k..m {
            v[i] = r.get(i, k);
        }
        v[k] -= alpha;
        let vnorm2: f64 = v[k..].iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            continue;
        }
        // apply H = I - 2 v v^T / (v^T v) to R (cols k..) and Q^T (all cols)
        for c in k..n {
            let dot: f64 = (k..m).map(|i| v[i] * r.get(i, c)).sum();
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                let val = r.get(i, c) - f * v[i];
                r.set(i, c, val);
            }
        }
        for c in 0..m {
            let dot: f64 = (k..m).map(|i| v[i] * qt.get(i, c)).sum();
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                let val = qt.get(i, c) - f * v[i];
                qt.set(i, c, val);
            }
        }
    }
    // thin Q: first n rows of Q^T transposed
    let mut q = DenseMatrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            q.set(i, j, qt.get(j, i));
        }
    }
    let mut r_thin = DenseMatrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_thin.set(i, j, r.get(i, j));
        }
    }
    Ok((q, r_thin))
}

/// One-sided Jacobi SVD: A = U diag(S) V^T for m >= n (tall); wide inputs
/// are transposed internally. Returns (U m x n, S n, V^T n x n) with
/// singular values sorted descending.
pub fn svd(a: &DenseMatrix) -> Result<(DenseMatrix, Vec<f64>, DenseMatrix)> {
    if a.rows < a.cols {
        // A^T = U' S V'^T  =>  A = V' S U'^T
        let (u2, s, vt2) = svd(&a.transpose())?;
        // A = (V'^T)^T s u2^T ; U = vt2^T, V^T = u2^T
        return Ok((vt2.transpose(), s, u2.transpose()));
    }
    let (m, n) = (a.rows, a.cols);
    // work on columns of U = A (copied), accumulate V
    let mut u = a.clone();
    let mut v = DenseMatrix::eye(n);
    let max_sweeps = 60;
    let eps = 1e-12;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in p + 1..n {
                // compute [app apq; apq aqq] of U^T U
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let up = u.get(i, p);
                    let uq = u.get(i, q);
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u.get(i, p);
                    let uq = u.get(i, q);
                    u.set(i, p, c * up - s * uq);
                    u.set(i, q, s * up + c * uq);
                }
                for i in 0..n {
                    let vp = v.get(i, p);
                    let vq = v.get(i, q);
                    v.set(i, p, c * vp - s * vq);
                    v.set(i, q, s * vp + c * vq);
                }
            }
        }
        if off < 1e-15 {
            break;
        }
    }
    // singular values = column norms of U; normalize columns
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm: f64 = (0..m).map(|i| u.get(i, j).powi(2)).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.total_cmp(&a.0));
    let mut u_out = DenseMatrix::zeros(m, n);
    let mut vt_out = DenseMatrix::zeros(n, n);
    let mut s_out = Vec::with_capacity(n);
    for (newj, &(norm, oldj)) in sv.iter().enumerate() {
        s_out.push(norm);
        if norm > 1e-300 {
            for i in 0..m {
                u_out.set(i, newj, u.get(i, oldj) / norm);
            }
        }
        for i in 0..n {
            vt_out.set(newj, i, v.get(i, oldj));
        }
    }
    Ok((u_out, s_out, vt_out))
}

/// Symmetric eigendecomposition via classical Jacobi. Returns
/// (eigenvalues desc, eigenvectors as columns).
pub fn eigen_sym(a: &DenseMatrix) -> Result<(Vec<f64>, DenseMatrix)> {
    if a.rows != a.cols {
        return Err(Error::Shape("eigen: non-square".into()));
    }
    let n = a.rows;
    // symmetry check (tolerant)
    for i in 0..n {
        for j in i + 1..n {
            if (a.get(i, j) - a.get(j, i)).abs() > 1e-8 * a.max_abs().max(1.0) {
                return Err(Error::Numerical("eigen: matrix not symmetric".into()));
            }
        }
    }
    let mut d = a.clone();
    let mut v = DenseMatrix::eye(n);
    for _sweep in 0..100 {
        let mut off = 0.0;
        for p in 0..n {
            for q in p + 1..n {
                off += d.get(p, q).abs();
            }
        }
        if off < 1e-13 {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = d.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = d.get(p, p);
                let aqq = d.get(q, q);
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // rotate rows/cols p, q of d
                for i in 0..n {
                    let dip = d.get(i, p);
                    let diq = d.get(i, q);
                    d.set(i, p, c * dip - s * diq);
                    d.set(i, q, s * dip + c * diq);
                }
                for i in 0..n {
                    let dpi = d.get(p, i);
                    let dqi = d.get(q, i);
                    d.set(p, i, c * dpi - s * dqi);
                    d.set(q, i, s * dpi + c * dqi);
                }
                for i in 0..n {
                    let vip = v.get(i, p);
                    let viq = v.get(i, q);
                    v.set(i, p, c * vip - s * viq);
                    v.set(i, q, s * vip + c * viq);
                }
            }
        }
    }
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (d.get(i, i), i)).collect();
    pairs.sort_by(|a, b| b.0.total_cmp(&a.0));
    let vals: Vec<f64> = pairs.iter().map(|&(v, _)| v).collect();
    let mut vecs = DenseMatrix::zeros(n, n);
    for (newj, &(_, oldj)) in pairs.iter().enumerate() {
        for i in 0..n {
            vecs.set(i, newj, v.get(i, oldj));
        }
    }
    Ok((vals, vecs))
}

/// Numerical rank: singular values above MATLAB's default tolerance
/// `max(m,n) * eps * s_max`.
pub fn rank(a: &DenseMatrix) -> Result<usize> {
    let (_, s, _) = svd(a)?;
    let smax = s.first().copied().unwrap_or(0.0);
    if smax == 0.0 {
        return Ok(0);
    }
    let tol = a.rows.max(a.cols) as f64 * f64::EPSILON * smax;
    Ok(s.iter().filter(|&&x| x > tol).count())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn assert_close(a: &DenseMatrix, b: &DenseMatrix, tol: f64) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for i in 0..a.data.len() {
            assert!(
                (a.data[i] - b.data[i]).abs() < tol,
                "entry {i}: {} vs {}",
                a.data[i],
                b.data[i]
            );
        }
    }

    #[test]
    fn solve_known_system() {
        let a = DenseMatrix::new(2, 2, vec![2., 1., 1., 3.]).unwrap();
        let b = DenseMatrix::new(2, 1, vec![5., 10.]).unwrap();
        let x = solve(&a, &b).unwrap();
        assert!((x.get(0, 0) - 1.0).abs() < 1e-12);
        assert!((x.get(1, 0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn solve_random_roundtrip() {
        let mut rng = Rng::new(0);
        for n in [1, 2, 5, 12] {
            let a = DenseMatrix::randn(n, n, &mut rng);
            let x = DenseMatrix::randn(n, 3, &mut rng);
            let b = a.matmul(&x).unwrap();
            let x2 = solve(&a, &b).unwrap();
            assert_close(&x, &x2, 1e-7);
        }
    }

    #[test]
    fn singular_detected() {
        let a = DenseMatrix::new(2, 2, vec![1., 2., 2., 4.]).unwrap();
        let b = DenseMatrix::new(2, 1, vec![1., 2.]).unwrap();
        assert!(solve(&a, &b).is_err());
        assert_eq!(det(&a).unwrap(), 0.0);
    }

    #[test]
    fn inverse_identity() {
        let mut rng = Rng::new(1);
        let a = DenseMatrix::randn(6, 6, &mut rng);
        let ainv = inverse(&a).unwrap();
        let prod = a.matmul(&ainv).unwrap();
        assert_close(&prod, &DenseMatrix::eye(6), 1e-8);
    }

    #[test]
    fn det_of_triangular() {
        let a = DenseMatrix::new(3, 3, vec![2., 5., 7., 0., 3., 9., 0., 0., 4.]).unwrap();
        assert!((det(&a).unwrap() - 24.0).abs() < 1e-9);
    }

    #[test]
    fn cholesky_and_spd_solve() {
        let mut rng = Rng::new(2);
        let g = DenseMatrix::randn(8, 5, &mut rng);
        let a = g.transpose().matmul(&g).unwrap(); // SPD (5x5)
        let a = a.zip(&DenseMatrix::eye(5), |x, e| x + 0.1 * e).unwrap();
        let l = cholesky(&a).unwrap();
        let llt = l.matmul(&l.transpose()).unwrap();
        assert_close(&llt, &a, 1e-9);

        let b: Vec<f64> = (0..5).map(|i| i as f64).collect();
        let x = spd_solve(&a, &b).unwrap();
        let ax = a.matvec(&x).unwrap();
        for i in 0..5 {
            assert!((ax[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = DenseMatrix::new(2, 2, vec![1., 2., 2., 1.]).unwrap(); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn qr_orthonormal_and_reconstructs() {
        let mut rng = Rng::new(3);
        let a = DenseMatrix::randn(8, 4, &mut rng);
        let (q, r) = qr(&a).unwrap();
        // Q^T Q = I
        let qtq = q.transpose().matmul(&q).unwrap();
        assert_close(&qtq, &DenseMatrix::eye(4), 1e-9);
        // QR = A
        assert_close(&q.matmul(&r).unwrap(), &a, 1e-9);
        // R upper triangular
        for i in 0..4 {
            for j in 0..i {
                assert!(r.get(i, j).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn svd_reconstructs_tall_and_wide() {
        let mut rng = Rng::new(4);
        for (m, n) in [(6, 3), (3, 6), (5, 5)] {
            let a = DenseMatrix::randn(m, n, &mut rng);
            let (u, s, vt) = svd(&a).unwrap();
            let k = m.min(n);
            assert_eq!(s.len(), k);
            // descending
            for i in 1..k {
                assert!(s[i] <= s[i - 1] + 1e-12);
            }
            // U diag(S) V^T == A
            let mut us = u.clone();
            for j in 0..k {
                for i in 0..us.rows {
                    let v = us.get(i, j) * s[j];
                    us.set(i, j, v);
                }
            }
            let rec = us.matmul(&vt).unwrap();
            assert_close(&rec, &a, 1e-8);
            // singular values match sqrt eigenvalues of A^T A (frobenius check)
            let frob2: f64 = a.data.iter().map(|x| x * x).sum();
            let s2: f64 = s.iter().map(|x| x * x).sum();
            assert!((frob2 - s2).abs() < 1e-8 * frob2.max(1.0));
        }
    }

    #[test]
    fn eigen_sym_reconstructs() {
        let mut rng = Rng::new(5);
        let g = DenseMatrix::randn(6, 6, &mut rng);
        let a = g
            .transpose()
            .matmul(&g)
            .unwrap()
            .map(|x| x / 6.0);
        let (vals, vecs) = eigen_sym(&a).unwrap();
        // A v_i = lambda_i v_i
        for j in 0..6 {
            let vj: Vec<f64> = (0..6).map(|i| vecs.get(i, j)).collect();
            let av = a.matvec(&vj).unwrap();
            for i in 0..6 {
                assert!((av[i] - vals[j] * vj[i]).abs() < 1e-8);
            }
        }
        // PSD: all eigenvalues >= 0
        assert!(vals.iter().all(|&l| l > -1e-10));
        assert!(eigen_sym(&DenseMatrix::new(2, 2, vec![1., 5., 0., 1.]).unwrap()).is_err());
    }

    #[test]
    fn rank_detects_deficiency() {
        let mut rng = Rng::new(6);
        let b1 = DenseMatrix::randn(5, 2, &mut rng);
        let b2 = DenseMatrix::randn(2, 5, &mut rng);
        let a = b1.matmul(&b2).unwrap(); // rank 2
        assert_eq!(rank(&a).unwrap(), 2);
        assert_eq!(rank(&DenseMatrix::eye(4)).unwrap(), 4);
        assert_eq!(rank(&DenseMatrix::zeros(3, 3)).unwrap(), 0);
    }
}
