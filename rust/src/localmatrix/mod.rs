//! LocalMatrix — MATLAB-style linear algebra on *partitions* of data
//! (paper §III-B, API in Fig. A3).
//!
//! Deliberately local: all operations run on one partition's data; global
//! combination happens through explicit MLTable reduces, so developers can
//! reason about communication (the paper's "shared nothing" principle).
//!
//! Two storage formats, unified behind [`LocalMatrix`]:
//! * [`DenseMatrix`] — row-major `f64` (MATLAB-like semantics),
//! * [`CsrMatrix`] — compressed sparse rows, used by ALS for ratings
//!   (paper §IV-B: "support for CSR-compressed sparse representations").
//!
//! Linear algebra (solve / inverse / svd / eigen / rank / cholesky / qr)
//! lives in [`linalg`] and operates on dense matrices; `LocalMatrix`
//! forwards after densifying sparse inputs (documented trade-off: the
//! paper's LocalMatrix does the same — factor solves are dense at rank k).

pub mod dense;
pub mod linalg;
pub mod ops;
pub mod sparse;
pub mod vector;

pub use dense::DenseMatrix;
pub use sparse::CsrMatrix;
pub use vector::MLVector;

use crate::error::{Error, Result};

/// A partition-local matrix: dense or CSR-sparse.
#[derive(Debug, Clone, PartialEq)]
pub enum LocalMatrix {
    Dense(DenseMatrix),
    Sparse(CsrMatrix),
}

impl LocalMatrix {
    // -- constructors --------------------------------------------------

    pub fn dense(rows: usize, cols: usize, data: Vec<f64>) -> Result<LocalMatrix> {
        Ok(LocalMatrix::Dense(DenseMatrix::new(rows, cols, data)?))
    }

    pub fn zeros(rows: usize, cols: usize) -> LocalMatrix {
        LocalMatrix::Dense(DenseMatrix::zeros(rows, cols))
    }

    pub fn eye(n: usize) -> LocalMatrix {
        LocalMatrix::Dense(DenseMatrix::eye(n))
    }

    pub fn rand(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng) -> LocalMatrix {
        LocalMatrix::Dense(DenseMatrix::rand(rows, cols, rng))
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Result<LocalMatrix> {
        Ok(LocalMatrix::Dense(DenseMatrix::from_rows(rows)?))
    }

    // -- shape (Fig. A3 "Shape" family) ------------------------------

    pub fn num_rows(&self) -> usize {
        match self {
            LocalMatrix::Dense(m) => m.rows,
            LocalMatrix::Sparse(m) => m.rows,
        }
    }

    pub fn num_cols(&self) -> usize {
        match self {
            LocalMatrix::Dense(m) => m.cols,
            LocalMatrix::Sparse(m) => m.cols,
        }
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.num_rows(), self.num_cols())
    }

    pub fn is_sparse(&self) -> bool {
        matches!(self, LocalMatrix::Sparse(_))
    }

    /// Number of stored non-zeros (dense counts actual non-zero values).
    pub fn nnz(&self) -> usize {
        match self {
            LocalMatrix::Dense(m) => m.data.iter().filter(|&&x| x != 0.0).count(),
            LocalMatrix::Sparse(m) => m.nnz(),
        }
    }

    // -- element access (Fig. A3 "Indexing") -------------------------

    pub fn get(&self, r: usize, c: usize) -> f64 {
        match self {
            LocalMatrix::Dense(m) => m.get(r, c),
            LocalMatrix::Sparse(m) => m.get(r, c),
        }
    }

    pub fn set(&mut self, r: usize, c: usize, v: f64) -> Result<()> {
        match self {
            LocalMatrix::Dense(m) => {
                m.set(r, c, v);
                Ok(())
            }
            LocalMatrix::Sparse(_) => Err(Error::Shape(
                "in-place update on CSR matrix unsupported; densify first".into(),
            )),
        }
    }

    /// Row as a vector.
    pub fn row(&self, r: usize) -> MLVector {
        match self {
            LocalMatrix::Dense(m) => MLVector::new(m.row(r).to_vec()),
            LocalMatrix::Sparse(m) => {
                let mut out = vec![0.0; m.cols];
                for (c, v) in m.row_iter(r) {
                    out[c] = v;
                }
                MLVector::new(out)
            }
        }
    }

    pub fn col(&self, c: usize) -> MLVector {
        let mut out = Vec::with_capacity(self.num_rows());
        for r in 0..self.num_rows() {
            out.push(self.get(r, c));
        }
        MLVector::new(out)
    }

    /// Sub-matrix by row and column index sequences (Fig. A3
    /// `mat(Seq(2,4), 1)` style indexing).
    pub fn select(&self, rows: &[usize], cols: &[usize]) -> Result<LocalMatrix> {
        let mut data = Vec::with_capacity(rows.len() * cols.len());
        for &r in rows {
            if r >= self.num_rows() {
                return Err(Error::Shape(format!("row {r} out of bounds")));
            }
            for &c in cols {
                if c >= self.num_cols() {
                    return Err(Error::Shape(format!("col {c} out of bounds")));
                }
                data.push(self.get(r, c));
            }
        }
        LocalMatrix::dense(rows.len(), cols.len(), data)
    }

    /// Select whole rows (Fig. A9 `Y.getRows(...)`).
    pub fn get_rows(&self, rows: &[usize]) -> Result<LocalMatrix> {
        let cols: Vec<usize> = (0..self.num_cols()).collect();
        self.select(rows, &cols)
    }

    /// Indices of non-zero entries of a row (Fig. A3 "Reverse Indexing",
    /// used heavily by ALS: `tuple.nonZeroIndices`).
    pub fn non_zero_indices(&self, row: usize) -> Vec<usize> {
        match self {
            LocalMatrix::Dense(m) => m
                .row(row)
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(i, _)| i)
                .collect(),
            LocalMatrix::Sparse(m) => m.row_iter(row).map(|(c, _)| c).collect(),
        }
    }

    // -- conversion -----------------------------------------------------

    pub fn to_dense(&self) -> DenseMatrix {
        match self {
            LocalMatrix::Dense(m) => m.clone(),
            LocalMatrix::Sparse(m) => m.to_dense(),
        }
    }

    pub fn to_sparse(&self) -> CsrMatrix {
        match self {
            LocalMatrix::Dense(m) => CsrMatrix::from_dense(m),
            LocalMatrix::Sparse(m) => m.clone(),
        }
    }

    /// Flatten row-major to f32 (the XLA boundary format).
    pub fn to_f32(&self) -> Vec<f32> {
        let d = self.to_dense();
        d.data.iter().map(|&x| x as f32).collect()
    }

    /// Rows as MLVectors (Fig. A4 `data.toMLVectors`).
    pub fn to_vectors(&self) -> Vec<MLVector> {
        (0..self.num_rows()).map(|r| self.row(r)).collect()
    }

    // -- composition (Fig. A3 "Composition") -------------------------

    /// Stack vertically (`matA on matB`).
    pub fn on(&self, other: &LocalMatrix) -> Result<LocalMatrix> {
        if self.num_cols() != other.num_cols() {
            return Err(Error::Shape(format!(
                "on: col mismatch {} vs {}",
                self.num_cols(),
                other.num_cols()
            )));
        }
        let mut d = self.to_dense();
        let o = other.to_dense();
        d.data.extend_from_slice(&o.data);
        d.rows += o.rows;
        Ok(LocalMatrix::Dense(d))
    }

    /// Concatenate horizontally (`matA then matB`).
    pub fn then(&self, other: &LocalMatrix) -> Result<LocalMatrix> {
        if self.num_rows() != other.num_rows() {
            return Err(Error::Shape(format!(
                "then: row mismatch {} vs {}",
                self.num_rows(),
                other.num_rows()
            )));
        }
        let a = self.to_dense();
        let b = other.to_dense();
        let mut data = Vec::with_capacity(a.data.len() + b.data.len());
        for r in 0..a.rows {
            data.extend_from_slice(a.row(r));
            data.extend_from_slice(b.row(r));
        }
        LocalMatrix::dense(a.rows, a.cols + b.cols, data)
    }

    // -- linear algebra (Fig. A3 "Linear Algebra") --------------------

    pub fn transpose(&self) -> LocalMatrix {
        match self {
            LocalMatrix::Dense(m) => LocalMatrix::Dense(m.transpose()),
            LocalMatrix::Sparse(m) => LocalMatrix::Sparse(m.transpose()),
        }
    }

    /// Matrix multiply (`matA times matB`). Sparse*dense uses CSR row
    /// iteration; everything else densifies.
    pub fn times(&self, other: &LocalMatrix) -> Result<LocalMatrix> {
        if self.num_cols() != other.num_rows() {
            return Err(Error::Shape(format!(
                "times: {}x{} * {}x{}",
                self.num_rows(),
                self.num_cols(),
                other.num_rows(),
                other.num_cols()
            )));
        }
        match (self, other) {
            (LocalMatrix::Sparse(a), LocalMatrix::Dense(b)) => {
                Ok(LocalMatrix::Dense(a.matmul_dense(b)))
            }
            _ => {
                let a = self.to_dense();
                let b = other.to_dense();
                Ok(LocalMatrix::Dense(a.matmul(&b)?))
            }
        }
    }

    /// Elementwise (Frobenius) dot product (`matA dot matB`).
    pub fn dot(&self, other: &LocalMatrix) -> Result<f64> {
        if self.dims() != other.dims() {
            return Err(Error::Shape("dot: dims differ".into()));
        }
        let a = self.to_dense();
        let b = other.to_dense();
        Ok(a.data.iter().zip(&b.data).map(|(x, y)| x * y).sum())
    }

    /// Solve `self * x = rhs` (Fig. A3 `matA.solve(v)`), LU w/ pivoting.
    pub fn solve(&self, rhs: &LocalMatrix) -> Result<LocalMatrix> {
        let a = self.to_dense();
        let b = rhs.to_dense();
        Ok(LocalMatrix::Dense(linalg::solve(&a, &b)?))
    }

    pub fn inverse(&self) -> Result<LocalMatrix> {
        let a = self.to_dense();
        Ok(LocalMatrix::Dense(linalg::inverse(&a)?))
    }

    /// Singular value decomposition (one-sided Jacobi): (U, S, V^T).
    pub fn svd(&self) -> Result<(LocalMatrix, MLVector, LocalMatrix)> {
        let (u, s, vt) = linalg::svd(&self.to_dense())?;
        Ok((
            LocalMatrix::Dense(u),
            MLVector::new(s),
            LocalMatrix::Dense(vt),
        ))
    }

    /// Symmetric eigendecomposition (Jacobi): (values, vectors-as-cols).
    pub fn eigen(&self) -> Result<(MLVector, LocalMatrix)> {
        let (vals, vecs) = linalg::eigen_sym(&self.to_dense())?;
        Ok((MLVector::new(vals), LocalMatrix::Dense(vecs)))
    }

    /// Numerical rank via SVD with MATLAB's default tolerance.
    pub fn rank(&self) -> Result<usize> {
        linalg::rank(&self.to_dense())
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        match self {
            LocalMatrix::Dense(m) => m.data.iter().map(|x| x * x).sum::<f64>().sqrt(),
            LocalMatrix::Sparse(m) => m.values.iter().map(|x| x * x).sum::<f64>().sqrt(),
        }
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f64 {
        match self {
            LocalMatrix::Dense(m) => m.data.iter().sum(),
            LocalMatrix::Sparse(m) => m.values.iter().sum(),
        }
    }

    /// Memory footprint in bytes (used by the cluster OOM model).
    pub fn byte_size(&self) -> usize {
        match self {
            LocalMatrix::Dense(m) => m.data.len() * 8,
            LocalMatrix::Sparse(m) => m.values.len() * 8 + m.indices.len() * 8 + m.indptr.len() * 8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn construction_and_shape() {
        let m = LocalMatrix::dense(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m.dims(), (2, 3));
        assert_eq!(m.get(1, 2), 6.0);
        assert!(LocalMatrix::dense(2, 3, vec![1.0]).is_err());
        assert_eq!(LocalMatrix::eye(3).get(2, 2), 1.0);
        assert_eq!(LocalMatrix::zeros(2, 2).sum(), 0.0);
    }

    #[test]
    fn composition_on_then() {
        let a = LocalMatrix::dense(1, 2, vec![1., 2.]).unwrap();
        let b = LocalMatrix::dense(1, 2, vec![3., 4.]).unwrap();
        let v = a.on(&b).unwrap();
        assert_eq!(v.dims(), (2, 2));
        assert_eq!(v.get(1, 0), 3.0);
        let h = a.then(&b).unwrap();
        assert_eq!(h.dims(), (1, 4));
        assert_eq!(h.get(0, 3), 4.0);
        assert!(a.on(&LocalMatrix::zeros(1, 3)).is_err());
        assert!(a.then(&LocalMatrix::zeros(2, 2)).is_err());
    }

    #[test]
    fn select_and_nonzero() {
        let m = LocalMatrix::dense(3, 3, vec![1., 0., 2., 0., 0., 0., 3., 0., 4.]).unwrap();
        assert_eq!(m.non_zero_indices(0), vec![0, 2]);
        assert_eq!(m.non_zero_indices(1), Vec::<usize>::new());
        let s = m.select(&[0, 2], &[0, 2]).unwrap();
        assert_eq!(s.dims(), (2, 2));
        assert_eq!(s.get(1, 1), 4.0);
        assert!(m.select(&[5], &[0]).is_err());
    }

    #[test]
    fn times_and_solve_roundtrip() {
        let mut rng = Rng::new(0);
        let a = LocalMatrix::rand(4, 4, &mut rng);
        let x = LocalMatrix::rand(4, 2, &mut rng);
        let b = a.times(&x).unwrap();
        let x2 = a.solve(&b).unwrap();
        for r in 0..4 {
            for c in 0..2 {
                assert!((x.get(r, c) - x2.get(r, c)).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn sparse_dense_roundtrip() {
        let d = LocalMatrix::dense(2, 3, vec![0., 1., 0., 2., 0., 3.]).unwrap();
        let s = LocalMatrix::Sparse(d.to_sparse());
        assert_eq!(s.nnz(), 3);
        assert_eq!(s.get(1, 2), 3.0);
        assert_eq!(s.to_dense(), d.to_dense());
        assert_eq!(s.row(1).as_slice(), &[2., 0., 3.]);
        assert_eq!(s.non_zero_indices(1), vec![0, 2]);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = LocalMatrix::rand(3, 5, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().dims(), (5, 3));
    }

    #[test]
    fn frob_and_dot() {
        let m = LocalMatrix::dense(2, 2, vec![3., 0., 4., 0.]).unwrap();
        assert!((m.frob_norm() - 5.0).abs() < 1e-12);
        assert!((m.dot(&m).unwrap() - 25.0).abs() < 1e-12);
    }
}
