//! CSR (compressed sparse row) matrix — the storage the paper's ALS uses
//! for ratings ("support for CSR-compressed sparse representations of
//! matrices", §IV-B).

use super::dense::DenseMatrix;
use crate::error::{Error, Result};

/// Compressed sparse row matrix.
///
/// `indptr.len() == rows + 1`; row r's entries live at
/// `indices[indptr[r]..indptr[r+1]]` / `values[...]`, with column indices
/// strictly increasing within a row.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<usize>,
    pub values: Vec<f64>,
}

impl CsrMatrix {
    /// Build from (row, col, value) triplets; duplicates summed.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        mut triplets: Vec<(usize, usize, f64)>,
    ) -> Result<CsrMatrix> {
        for &(r, c, _) in &triplets {
            if r >= rows || c >= cols {
                return Err(Error::Shape(format!(
                    "triplet ({r},{c}) out of bounds for {rows}x{cols}"
                )));
            }
        }
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices: Vec<usize> = Vec::with_capacity(triplets.len());
        let mut values: Vec<f64> = Vec::with_capacity(triplets.len());
        let mut last: Option<(usize, usize)> = None;
        for (r, c, v) in triplets {
            if last == Some((r, c)) {
                // duplicate (r, c): sum contributions
                // mli-lint: allow(E001) last == Some((r, c)) implies a prior push
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(c);
                values.push(v);
                last = Some((r, c));
            }
            indptr[r + 1] = indices.len();
        }
        // forward-fill indptr for empty rows
        for r in 1..=rows {
            indptr[r] = indptr[r].max(indptr[r - 1]);
        }
        Ok(CsrMatrix { rows, cols, indptr, indices, values })
    }

    pub fn from_dense(m: &DenseMatrix) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        for r in 0..m.rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    indices.push(c);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CsrMatrix { rows: m.rows, cols: m.cols, indptr, indices, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn row_nnz(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    /// Iterate a row's (col, value) pairs.
    pub fn row_iter(&self, r: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        self.indices[lo..hi]
            .iter()
            .copied()
            .zip(self.values[lo..hi].iter().copied())
    }

    /// Point lookup via binary search within the row.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let lo = self.indptr[r];
        let hi = self.indptr[r + 1];
        match self.indices[lo..hi].binary_search(&c) {
            Ok(i) => self.values[lo + i],
            Err(_) => 0.0,
        }
    }

    pub fn to_dense(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for (c, v) in self.row_iter(r) {
                out.set(r, c, v);
            }
        }
        out
    }

    /// CSR transpose (counting sort over columns) — O(nnz + rows + cols).
    /// The paper's ALS distributes both M and M^T (§IV-B); this is how the
    /// transposed copy is built.
    pub fn transpose(&self) -> CsrMatrix {
        let mut counts = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            counts[c + 1] += 1;
        }
        for i in 1..=self.cols {
            counts[i] += counts[i - 1];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0usize; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        for r in 0..self.rows {
            for (c, v) in self.row_iter(r) {
                let dst = cursor[c];
                indices[dst] = r;
                values[dst] = v;
                cursor[c] += 1;
            }
        }
        CsrMatrix {
            rows: self.cols,
            cols: self.rows,
            indptr,
            indices,
            values,
        }
    }

    /// Sparse x dense multiply.
    pub fn matmul_dense(&self, b: &DenseMatrix) -> DenseMatrix {
        assert_eq!(self.cols, b.rows, "spmm shape mismatch");
        let mut out = DenseMatrix::zeros(self.rows, b.cols);
        for r in 0..self.rows {
            let orow = out.row_mut(r);
            for (c, v) in self.row_iter(r) {
                let brow = b.row(c);
                for (o, &bb) in orow.iter_mut().zip(brow) {
                    *o += v * bb;
                }
            }
        }
        out
    }

    /// Sparse matvec.
    pub fn matvec(&self, v: &[f64]) -> Result<Vec<f64>> {
        if v.len() != self.cols {
            return Err(Error::Shape(format!(
                "spmv: {}x{} * {}",
                self.rows,
                self.cols,
                v.len()
            )));
        }
        Ok((0..self.rows)
            .map(|r| self.row_iter(r).map(|(c, x)| x * v[c]).sum())
            .collect())
    }

    /// Row slice as a new CSR (rows [lo, hi)) — used to partition ratings
    /// across simulated machines.
    pub fn row_slice(&self, lo: usize, hi: usize) -> CsrMatrix {
        assert!(lo <= hi && hi <= self.rows);
        let base = self.indptr[lo];
        let indptr: Vec<usize> = self.indptr[lo..=hi].iter().map(|&p| p - base).collect();
        CsrMatrix {
            rows: hi - lo,
            cols: self.cols,
            indptr,
            indices: self.indices[self.indptr[lo]..self.indptr[hi]].to_vec(),
            values: self.values[self.indptr[lo]..self.indptr[hi]].to_vec(),
        }
    }

    /// Horizontal tiling: repeat this matrix `times` across columns — the
    /// paper's Netflix scale-up ("repeatedly tiling the Netflix dataset",
    /// §IV-B) preserving sparsity structure.
    pub fn tile_cols(&self, times: usize) -> CsrMatrix {
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(self.nnz() * times);
        let mut values = Vec::with_capacity(self.nnz() * times);
        for r in 0..self.rows {
            for t in 0..times {
                for (c, v) in self.row_iter(r) {
                    indices.push(c + t * self.cols);
                    values.push(v);
                }
            }
            indptr[r + 1] = indices.len();
        }
        CsrMatrix {
            rows: self.rows,
            cols: self.cols * times,
            indptr,
            indices,
            values,
        }
    }

    /// Vertical tiling: repeat across rows.
    pub fn tile_rows(&self, times: usize) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(self.rows * times + 1);
        indptr.push(0);
        let mut indices = Vec::with_capacity(self.nnz() * times);
        let mut values = Vec::with_capacity(self.nnz() * times);
        for _ in 0..times {
            for r in 0..self.rows {
                for (c, v) in self.row_iter(r) {
                    indices.push(c);
                    values.push(v);
                }
                indptr.push(indices.len());
            }
        }
        CsrMatrix {
            rows: self.rows * times,
            cols: self.cols,
            indptr,
            indices,
            values,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        // [[1 0 2], [0 0 0], [3 4 0]]
        CsrMatrix::from_triplets(3, 3, vec![(0, 0, 1.), (0, 2, 2.), (2, 0, 3.), (2, 1, 4.)])
            .unwrap()
    }

    #[test]
    fn triplets_and_lookup() {
        let m = sample();
        assert_eq!(m.nnz(), 4);
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(0, 1), 0.0);
        assert_eq!(m.get(2, 1), 4.0);
        assert_eq!(m.row_nnz(1), 0);
        assert_eq!(m.row_iter(2).collect::<Vec<_>>(), vec![(0, 3.0), (1, 4.0)]);
    }

    #[test]
    fn triplets_out_of_bounds() {
        assert!(CsrMatrix::from_triplets(2, 2, vec![(2, 0, 1.)]).is_err());
        assert!(CsrMatrix::from_triplets(2, 2, vec![(0, 5, 1.)]).is_err());
    }

    #[test]
    fn unsorted_triplets() {
        let m =
            CsrMatrix::from_triplets(2, 3, vec![(1, 2, 5.), (0, 1, 1.), (1, 0, 2.)]).unwrap();
        assert_eq!(m.get(0, 1), 1.0);
        assert_eq!(m.get(1, 0), 2.0);
        assert_eq!(m.get(1, 2), 5.0);
    }

    #[test]
    fn duplicate_triplets_summed() {
        let m = CsrMatrix::from_triplets(2, 2, vec![(0, 0, 1.), (0, 0, 2.5), (1, 1, 1.)])
            .unwrap();
        assert_eq!(m.get(0, 0), 3.5);
        assert_eq!(m.nnz(), 2);
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let d = m.to_dense();
        assert_eq!(CsrMatrix::from_dense(&d), m);
    }

    #[test]
    fn transpose_correct() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        let d = m.to_dense();
        let td = t.to_dense();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(d.get(r, c), td.get(c, r));
            }
        }
        // double transpose = identity
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn spmm_matches_dense() {
        let m = sample();
        let b = DenseMatrix::new(3, 2, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let got = m.matmul_dense(&b);
        let want = m.to_dense().matmul(&b).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn spmv() {
        let m = sample();
        let got = m.matvec(&[1.0, 1.0, 1.0]).unwrap();
        assert_eq!(got, vec![3.0, 0.0, 7.0]);
        assert!(m.matvec(&[1.0]).is_err());
    }

    #[test]
    fn row_slice() {
        let m = sample();
        let s = m.row_slice(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.get(0, 0), 0.0);
        assert_eq!(s.get(1, 1), 4.0);
        assert_eq!(s.nnz(), 2);
    }

    #[test]
    fn tiling_preserves_sparsity_pattern() {
        let m = sample();
        let t = m.tile_cols(3);
        assert_eq!(t.cols, 9);
        assert_eq!(t.nnz(), 12);
        assert_eq!(t.get(0, 3), 1.0); // second tile
        assert_eq!(t.get(2, 7), 4.0);
        let v = m.tile_rows(2);
        assert_eq!(v.rows, 6);
        assert_eq!(v.get(5, 1), 4.0);
        // per-row density identical to original
        assert_eq!(v.row_nnz(3), m.row_nnz(0));
    }
}
