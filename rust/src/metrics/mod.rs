//! Metrics: counters, timer series, and table reporters used by the
//! training loops and the bench harness.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::Mutex;

use crate::util;
use crate::util::lock_unpoisoned;

/// A named collection of counters and timing series. Mutex-guarded
/// (`Send + Sync`) so `exec` pool workers and the driver can record into
/// one registry.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    series: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, v: u64) {
        *lock_unpoisoned(&self.counters)
            .entry(name.to_string())
            .or_insert(0) += v;
    }

    pub fn counter(&self, name: &str) -> u64 {
        lock_unpoisoned(&self.counters)
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Append a sample (seconds, losses, whatever) to a named series.
    pub fn observe(&self, name: &str, v: f64) {
        lock_unpoisoned(&self.series)
            .entry(name.to_string())
            .or_default()
            .push(v);
    }

    pub fn series(&self, name: &str) -> Vec<f64> {
        lock_unpoisoned(&self.series)
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    pub fn summary(&self, name: &str) -> (usize, f64, f64, f64) {
        let s = self.series(name);
        (s.len(), util::mean(&s), util::median(&s), util::stddev(&s))
    }

    /// Render everything as an aligned text report.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = lock_unpoisoned(&self.counters);
        if !counters.is_empty() {
            out.push_str("counters:\n");
            for (k, v) in counters.iter() {
                let _ = writeln!(out, "  {k:<40} {v}");
            }
        }
        let series = lock_unpoisoned(&self.series);
        if !series.is_empty() {
            out.push_str("series (n / mean / median / stddev):\n");
            for (k, s) in series.iter() {
                let _ = writeln!(
                    out,
                    "  {k:<40} {:>6} / {:.6} / {:.6} / {:.6}",
                    s.len(),
                    util::mean(s),
                    util::median(s),
                    util::stddev(s)
                );
            }
        }
        out
    }
}

/// A simple aligned-column table for bench output (markdown-ish, matches
/// what EXPERIMENTS.md embeds).
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form footnotes rendered after the table body (markdown only;
    /// CSV output is unaffected so plotting scripts keep parsing).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "table row width");
        self.rows.push(cells);
    }

    /// Append a footnote line (e.g. failure/recovery accounting).
    pub fn note(&mut self, line: impl Into<String>) {
        self.notes.push(line.into());
    }

    /// Render as a markdown table.
    pub fn to_markdown(&self) -> String {
        
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("### {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(line, " {c:<w$} |", w = w);
            }
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}-|", "-".repeat(w + 2 - 1));
        }
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        for n in &self.notes {
            out.push('\n');
            let _ = writeln!(out, "_{n}_");
        }
        out
    }

    /// Render as CSV (for plotting scripts).
    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    /// Write both renderings under `results/` with the given stem.
    pub fn save(&self, stem: &str) -> std::io::Result<()> {
        std::fs::create_dir_all("results")?;
        std::fs::write(format!("results/{stem}.md"), self.to_markdown())?;
        std::fs::write(format!("results/{stem}.csv"), self.to_csv())?;
        Ok(())
    }
}

/// Format seconds for tables: "DNF(oom)" for None.
pub fn fmt_time(t: Option<f64>) -> String {
    match t {
        Some(s) => format!("{s:.2}"),
        None => "DNF(oom)".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_series() {
        let m = Metrics::new();
        m.incr("tasks");
        m.add("tasks", 4);
        assert_eq!(m.counter("tasks"), 5);
        assert_eq!(m.counter("missing"), 0);
        m.observe("round_s", 1.0);
        m.observe("round_s", 3.0);
        let (n, mean, median, _) = m.summary("round_s");
        assert_eq!(n, 2);
        assert_eq!(mean, 2.0);
        assert_eq!(median, 2.0);
        let r = m.report();
        assert!(r.contains("tasks") && r.contains("round_s"));
    }

    #[test]
    fn table_render() {
        let mut t = Table::new("Fig 2a", &["System", "LoC"]);
        t.row(vec!["MLI".into(), "55".into()]);
        t.row(vec!["Vowpal Wabbit".into(), "721".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| MLI"));
        assert!(md.contains("Fig 2a"));
        let csv = t.to_csv();
        assert!(csv.starts_with("System,LoC\n"));
        assert!(csv.contains("MLI,55"));
    }

    #[test]
    #[should_panic(expected = "table row width")]
    fn table_rejects_ragged() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_time_dnf() {
        assert_eq!(fmt_time(Some(1.234)), "1.23");
        assert_eq!(fmt_time(None), "DNF(oom)");
    }
}
