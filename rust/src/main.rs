//! `mli` — launcher CLI for the MLI reproduction.
//!
//! Subcommands (see `mli help`): train, serve-info, bench, loc, selftest.

use mli::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let code = match mli::run_cli(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}
