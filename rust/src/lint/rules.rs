//! The lint rules and the per-file analysis context they share.
//!
//! Every rule is a pure function over the token stream plus precomputed
//! regions (test code, `use` declarations, `Result`-returning function
//! bodies). Rules never look inside comments or string literals — the
//! lexer already dropped them — so a rule firing always points at real
//! code. See `docs/lint.md` for the rule inventory and rationale.

use super::lexer::{TokKind, Token};

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Repo-relative path with forward slashes (e.g. `rust/src/exec/pool.rs`).
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule id, e.g. "D001".
    pub rule: &'static str,
    pub message: String,
    pub suggestion: String,
}

/// All rule ids, in report order.
pub const ALL_RULES: [&str; 5] = ["D001", "D002", "C001", "C002", "E001"];

/// Short per-rule description (for `--list-rules` and the JSON header).
pub fn rule_summary(rule: &str) -> &'static str {
    match rule {
        "D001" => "unordered HashMap/HashSet in a determinism-sensitive module",
        "D002" => "wall-clock read inside a simulated-time module",
        "C001" => "raw .lock().unwrap()/.expect() instead of lock_unpoisoned",
        "C002" => "lock guard held across a ThreadPool submit/run call",
        "E001" => "unwrap()/expect() inside a Result-returning library function",
        _ => "unknown rule",
    }
}

/// Precomputed per-file analysis context.
pub struct FileCtx<'a> {
    /// Repo-relative path, forward slashes.
    pub rel: &'a str,
    pub tokens: &'a [Token],
    /// File lives under `rust/tests/` or `rust/benches/`.
    pub is_test_file: bool,
    /// Inclusive line ranges covered by `#[cfg(test)]` / `#[test]` items.
    pub test_lines: Vec<(usize, usize)>,
    /// Token-index ranges (start..=end) of `use` declarations.
    pub use_spans: Vec<(usize, usize)>,
    /// (body_start, body_end, returns_result) token-index ranges per fn.
    pub fn_spans: Vec<(usize, usize, bool)>,
}

impl<'a> FileCtx<'a> {
    pub fn new(rel: &'a str, tokens: &'a [Token]) -> FileCtx<'a> {
        let is_test_file = rel.starts_with("rust/tests/") || rel.starts_with("rust/benches/");
        FileCtx {
            rel,
            tokens,
            is_test_file,
            test_lines: find_test_regions(tokens),
            use_spans: find_use_spans(tokens),
            fn_spans: find_fn_spans(tokens),
        }
    }

    /// True when `line` is test code (test file, or inside a
    /// `#[cfg(test)]` / `#[test]` region).
    pub fn in_test_code(&self, line: usize) -> bool {
        self.is_test_file || self.test_lines.iter().any(|&(a, b)| a <= line && line <= b)
    }

    fn in_use_decl(&self, idx: usize) -> bool {
        self.use_spans.iter().any(|&(a, b)| a <= idx && idx <= b)
    }

    /// Does the *innermost* fn enclosing token `idx` return `Result`?
    fn in_result_fn(&self, idx: usize) -> bool {
        self.fn_spans
            .iter()
            .filter(|&&(a, b, _)| a <= idx && idx <= b)
            .max_by_key(|&&(a, _, _)| a)
            .map(|&(_, _, r)| r)
            .unwrap_or(false)
    }
}

/// Find line ranges of test items: an outer attribute containing the
/// ident `test` (but not `not`, so `#[cfg(not(test))]` stays live code)
/// marks the following item (to its matching `}` or terminating `;`).
fn find_test_regions(tokens: &[Token]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i + 1 < tokens.len() {
        if tokens[i].is(TokKind::Punct, "#") && tokens[i + 1].is(TokKind::Punct, "[") {
            // scan the attribute body to its matching `]`
            let mut j = i + 2;
            let mut depth = 1usize;
            let mut saw_test = false;
            let mut saw_not = false;
            while j < tokens.len() && depth > 0 {
                match (&tokens[j].kind, tokens[j].text.as_str()) {
                    (TokKind::Punct, "[") => depth += 1,
                    (TokKind::Punct, "]") => depth -= 1,
                    (TokKind::Ident, "test") => saw_test = true,
                    (TokKind::Ident, "not") => saw_not = true,
                    _ => {}
                }
                j += 1;
            }
            if saw_test && !saw_not {
                // the region runs from the attribute to the end of the
                // next item: first `{`..matching `}`, or a `;` if one
                // comes first (e.g. `mod tests;`)
                let start_line = tokens[i].line;
                let mut k = j;
                let mut end_line = start_line;
                while k < tokens.len() {
                    if tokens[k].is(TokKind::Punct, ";") {
                        end_line = tokens[k].line;
                        break;
                    }
                    if tokens[k].is(TokKind::Punct, "{") {
                        let mut d = 1usize;
                        let mut m = k + 1;
                        while m < tokens.len() && d > 0 {
                            match tokens[m].text.as_str() {
                                "{" => d += 1,
                                "}" => d -= 1,
                                _ => {}
                            }
                            m += 1;
                        }
                        end_line = tokens[m.saturating_sub(1)].line;
                        break;
                    }
                    k += 1;
                }
                out.push((start_line, end_line));
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out
}

/// Token-index spans of `use` declarations (from `use` to its `;`).
fn find_use_spans(tokens: &[Token]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < tokens.len() {
        if tokens[i].is(TokKind::Ident, "use") {
            let start = i;
            while i < tokens.len() && !tokens[i].is(TokKind::Punct, ";") {
                i += 1;
            }
            out.push((start, i));
        }
        i += 1;
    }
    out
}

/// For every `fn`, the token span of its body and whether its declared
/// return type mentions `Result`.
fn find_fn_spans(tokens: &[Token]) -> Vec<(usize, usize, bool)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < tokens.len() {
        if tokens[i].is(TokKind::Ident, "fn") {
            // scan the signature: past the parameter list, then inspect
            // the return type (if any) until the body `{` or a `;`
            // (trait method without body)
            let mut j = i + 1;
            // find the opening paren of the parameter list
            while j < tokens.len()
                && !tokens[j].is(TokKind::Punct, "(")
                && !tokens[j].is(TokKind::Punct, "{")
                && !tokens[j].is(TokKind::Punct, ";")
            {
                j += 1;
            }
            if j >= tokens.len() || !tokens[j].is(TokKind::Punct, "(") {
                i += 1;
                continue;
            }
            // matching close paren
            let mut d = 1usize;
            j += 1;
            while j < tokens.len() && d > 0 {
                match tokens[j].text.as_str() {
                    "(" => d += 1,
                    ")" => d -= 1,
                    _ => {}
                }
                j += 1;
            }
            // return type region: tokens until `{` or `;`
            let mut returns_result = false;
            let mut k = j;
            while k < tokens.len()
                && !tokens[k].is(TokKind::Punct, "{")
                && !tokens[k].is(TokKind::Punct, ";")
            {
                if tokens[k].is(TokKind::Ident, "Result") {
                    returns_result = true;
                }
                k += 1;
            }
            if k < tokens.len() && tokens[k].is(TokKind::Punct, "{") {
                // body span via brace matching
                let body_start = k;
                let mut bd = 1usize;
                let mut m = k + 1;
                while m < tokens.len() && bd > 0 {
                    match tokens[m].text.as_str() {
                        "{" => bd += 1,
                        "}" => bd -= 1,
                        _ => {}
                    }
                    m += 1;
                }
                out.push((body_start, m.saturating_sub(1), returns_result));
                i = body_start + 1; // recurse into the body for nested fns
                continue;
            }
            i = k;
        }
        i += 1;
    }
    out
}

// ---- rules ---------------------------------------------------------------

/// D001: unordered `HashMap`/`HashSet` in determinism-sensitive modules.
/// Iterating either feeds RandomState order into merges/exports, breaking
/// the bitwise-determinism contract. `use` declarations and test code are
/// exempt; lookup-only maps get an `allow` with the reason documented.
pub fn d001(ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
    const SENSITIVE: [&str; 6] = [
        "rust/src/engine/",
        "rust/src/optim/",
        "rust/src/algorithms/",
        "rust/src/trace/",
        "rust/src/metrics/",
        "rust/src/cluster/netfault",
    ];
    if !SENSITIVE.iter().any(|p| ctx.rel.starts_with(p)) {
        return;
    }
    for (i, t) in ctx.tokens.iter().enumerate() {
        if t.kind != TokKind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
            continue;
        }
        if ctx.in_use_decl(i) || ctx.in_test_code(t.line) {
            continue;
        }
        out.push(Diagnostic {
            file: ctx.rel.to_string(),
            line: t.line,
            rule: "D001",
            message: format!(
                "unordered `{}` in a determinism-sensitive module (merge/export \
                 paths must not depend on RandomState iteration order)",
                t.text
            ),
            suggestion: "use BTreeMap/BTreeSet or the engine's OrderedMap, or sort \
                         before iterating; for a lookup-only map add \
                         `// mli-lint: allow(D001) <reason>`"
                .to_string(),
        });
    }
}

/// D002: wall-clock reads (`Instant::now`, `SystemTime::now`,
/// `Stopwatch::start`) inside the simulated-time modules. The `SimCluster`
/// ledger is analytic — leaking real time into it silently breaks
/// simulated-vs-wall attribution. Legitimately-wall-clock sites (retry
/// budgets, real task timing charged by design) carry `allow` annotations.
pub fn d002(ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
    const SENSITIVE: [&str; 2] = ["rust/src/cluster/", "rust/src/engine/"];
    if !SENSITIVE.iter().any(|p| ctx.rel.starts_with(p)) {
        return;
    }
    let toks = ctx.tokens;
    for i in 0..toks.len().saturating_sub(2) {
        let (a, b, c) = (&toks[i], &toks[i + 1], &toks[i + 2]);
        if a.kind != TokKind::Ident || !b.is(TokKind::Punct, "::") || c.kind != TokKind::Ident {
            continue;
        }
        let hit = match (a.text.as_str(), c.text.as_str()) {
            ("Instant", "now") | ("SystemTime", "now") => true,
            ("Stopwatch", "start") => true,
            _ => false,
        };
        if !hit || ctx.in_test_code(a.line) {
            continue;
        }
        out.push(Diagnostic {
            file: ctx.rel.to_string(),
            line: a.line,
            rule: "D002",
            message: format!(
                "wall-clock read `{}::{}` inside a simulated-time module",
                a.text, c.text
            ),
            suggestion: "charge simulated time through the SimCluster ledger instead; \
                         if this site is wall-clock by design (retry budget, measured \
                         task cost) add `// mli-lint: allow(D002) <reason>`"
                .to_string(),
        });
    }
}

/// C001: `.lock().unwrap()` / `.lock().expect(..)`. A panicking pool task
/// poisons any mutex it held; unwrapping the poison error aborts unrelated
/// threads. `util::lock_unpoisoned` (or `lockdep::TrackedMutex`) recovers
/// instead — see the failure contract in `exec`.
pub fn c001(ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
    let toks = ctx.tokens;
    for i in 0..toks.len().saturating_sub(5) {
        if toks[i].is(TokKind::Punct, ".")
            && toks[i + 1].is(TokKind::Ident, "lock")
            && toks[i + 2].is(TokKind::Punct, "(")
            && toks[i + 3].is(TokKind::Punct, ")")
            && toks[i + 4].is(TokKind::Punct, ".")
            && toks[i + 5].kind == TokKind::Ident
            && (toks[i + 5].text == "unwrap" || toks[i + 5].text == "expect")
        {
            out.push(Diagnostic {
                file: ctx.rel.to_string(),
                line: toks[i + 5].line,
                rule: "C001",
                message: format!(
                    "raw `.lock().{}()` — unwrapping a poisoned mutex aborts \
                     threads that did nothing wrong",
                    toks[i + 5].text
                ),
                suggestion: "use `crate::util::lock_unpoisoned(&mutex)` (poison \
                             recovery) or `util::lockdep::TrackedMutex` (recovery + \
                             debug lock-order checking)"
                    .to_string(),
            });
        }
    }
}

/// C002: a mutex guard bound by `let` is still live when a `ThreadPool`
/// submit/run-style call occurs in the same scope. Blocking a stage on a
/// held lock invites the classic guard-across-await deadlock shape (a
/// worker task needing the same lock can never finish). Lexical
/// approximation: a guard dies at its scope's `}` or an explicit
/// `drop(guard)`.
pub fn c002(ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
    if ctx.is_test_file {
        return;
    }
    const POOL_CALLS: [&str; 5] = ["run", "try_run", "try_run_speculative", "submit", "spawn"];
    let toks = ctx.tokens;
    // live guards: (name, depth_bound_at, activation_token_index)
    let mut guards: Vec<(String, usize, usize)> = Vec::new();
    let mut depth = 0usize;
    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];
        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "{") => depth += 1,
            (TokKind::Punct, "}") => {
                depth = depth.saturating_sub(1);
                guards.retain(|&(_, d, _)| d <= depth);
            }
            (TokKind::Ident, "let") => {
                // does this statement bind a lock guard?
                // binder: `let [mut] <ident> = ...;` (tuple/struct patterns
                // are not tracked)
                let mut j = i + 1;
                if j < toks.len() && toks[j].is(TokKind::Ident, "mut") {
                    j += 1;
                }
                if j < toks.len() && toks[j].kind == TokKind::Ident {
                    let name = toks[j].text.clone();
                    // statement end: `;` back at this depth
                    let mut d = 0i64;
                    let mut k = j + 1;
                    let mut end = None;
                    let mut locks = false;
                    while k < toks.len() {
                        match toks[k].text.as_str() {
                            "{" | "(" | "[" => d += 1,
                            "}" | ")" | "]" => d -= 1,
                            ";" if d == 0 => {
                                end = Some(k);
                                break;
                            }
                            _ => {}
                        }
                        // only a depth-0 lock call makes the binding a
                        // guard; a lock inside a nested closure (e.g.
                        // `let job = Box::new(move || { ..lock().. })`)
                        // is acquired later, not held by this binding
                        if d == 0
                            && (toks[k].is(TokKind::Ident, "lock_unpoisoned")
                                || (toks[k].is(TokKind::Punct, ".")
                                    && k + 3 < toks.len()
                                    && toks[k + 1].is(TokKind::Ident, "lock")
                                    && toks[k + 2].is(TokKind::Punct, "(")
                                    && toks[k + 3].is(TokKind::Punct, ")")))
                        {
                            locks = true;
                        }
                        if d < 0 {
                            break; // malformed / end of enclosing block
                        }
                        k += 1;
                    }
                    if locks {
                        if let Some(end) = end {
                            guards.push((name, depth, end));
                        }
                    }
                }
            }
            (TokKind::Ident, "drop") => {
                // `drop(<guard>)` releases it early
                if i + 3 < toks.len()
                    && toks[i + 1].is(TokKind::Punct, "(")
                    && toks[i + 2].kind == TokKind::Ident
                    && toks[i + 3].is(TokKind::Punct, ")")
                {
                    let name = &toks[i + 2].text;
                    guards.retain(|(g, _, _)| g != name);
                }
            }
            (TokKind::Punct, ".") => {
                if i + 2 < toks.len()
                    && toks[i + 1].kind == TokKind::Ident
                    && POOL_CALLS.contains(&toks[i + 1].text.as_str())
                    && toks[i + 2].is(TokKind::Punct, "(")
                {
                    let line = toks[i + 1].line;
                    let live: Vec<&str> = guards
                        .iter()
                        .filter(|&&(_, _, act)| act < i)
                        .map(|(g, _, _)| g.as_str())
                        .collect();
                    if !live.is_empty() && !ctx.in_test_code(line) {
                        out.push(Diagnostic {
                            file: ctx.rel.to_string(),
                            line,
                            rule: "C002",
                            message: format!(
                                "pool call `.{}(...)` while lock guard{} [{}] still live",
                                toks[i + 1].text,
                                if live.len() > 1 { "s" } else { "" },
                                live.join(", ")
                            ),
                            suggestion: "drop the guard (or narrow its scope with a \
                                         block) before submitting work to the pool; \
                                         a worker needing the same lock deadlocks the \
                                         stage"
                                .to_string(),
                        });
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
}

/// E001: `.unwrap()` / `.expect(..)` inside a function that returns
/// `Result` — the typed `Error` should propagate with `?` instead of
/// panicking past the caller's error handling. Test code is exempt;
/// `.lock().unwrap()` is C001's finding, not double-reported here.
pub fn e001(ctx: &FileCtx<'_>, out: &mut Vec<Diagnostic>) {
    if ctx.is_test_file {
        return;
    }
    let toks = ctx.tokens;
    for i in 0..toks.len().saturating_sub(2) {
        if !toks[i].is(TokKind::Punct, ".") {
            continue;
        }
        let name = &toks[i + 1];
        if name.kind != TokKind::Ident || (name.text != "unwrap" && name.text != "expect") {
            continue;
        }
        if !toks[i + 2].is(TokKind::Punct, "(") {
            continue;
        }
        // `.lock().unwrap()` is C001's domain
        if i >= 3
            && toks[i - 3].is(TokKind::Ident, "lock")
            && toks[i - 2].is(TokKind::Punct, "(")
            && toks[i - 1].is(TokKind::Punct, ")")
        {
            continue;
        }
        // a call whose result feeds `?` propagates, it doesn't panic —
        // this also covers same-named user methods returning Result
        // (e.g. the JSON parser's own `self.expect(b'{')?`)
        let mut d = 1usize;
        let mut j = i + 3;
        while j < toks.len() && d > 0 {
            match toks[j].text.as_str() {
                "(" => d += 1,
                ")" => d -= 1,
                _ => {}
            }
            j += 1;
        }
        if j < toks.len() && toks[j].is(TokKind::Punct, "?") {
            continue;
        }
        if ctx.in_test_code(name.line) || !ctx.in_result_fn(i) {
            continue;
        }
        out.push(Diagnostic {
            file: ctx.rel.to_string(),
            line: name.line,
            rule: "E001",
            message: format!(
                "`.{}(..)` inside a Result-returning function — a panic here \
                 bypasses the typed Error path",
                name.text
            ),
            suggestion: "propagate with `?` (ok_or_else(..) for Options); if the \
                         invariant genuinely cannot fail, add \
                         `// mli-lint: allow(E001) <reason>`"
                .to_string(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::lex;
    use super::*;

    fn run_rule(
        rel: &str,
        src: &str,
        rule: fn(&FileCtx<'_>, &mut Vec<Diagnostic>),
    ) -> Vec<Diagnostic> {
        let lexed = lex(src);
        let ctx = FileCtx::new(rel, &lexed.tokens);
        let mut out = Vec::new();
        rule(&ctx, &mut out);
        out
    }

    // -- D001 --------------------------------------------------------------

    #[test]
    fn d001_fires_in_sensitive_module() {
        let diags = run_rule(
            "rust/src/engine/foo.rs",
            "fn f() { let m: HashMap<u32, u32> = HashMap::new(); }",
            d001,
        );
        assert_eq!(diags.len(), 2);
        assert_eq!(diags[0].rule, "D001");
        assert_eq!(diags[0].line, 1);
    }

    #[test]
    fn d001_ignores_use_decls_tests_and_other_modules() {
        // use declaration: exempt
        assert!(run_rule(
            "rust/src/engine/foo.rs",
            "use std::collections::HashMap;\n",
            d001
        )
        .is_empty());
        // cfg(test) region: exempt
        assert!(run_rule(
            "rust/src/engine/foo.rs",
            "#[cfg(test)]\nmod tests {\n fn f() { let m = HashMap::new(); }\n}\n",
            d001
        )
        .is_empty());
        // non-sensitive module: exempt
        assert!(run_rule(
            "rust/src/data/foo.rs",
            "fn f() { let m = HashMap::new(); }",
            d001
        )
        .is_empty());
        // comments / strings never fire (lexer strips them)
        assert!(run_rule(
            "rust/src/engine/foo.rs",
            "// HashMap\nfn f() { let s = \"HashMap\"; }",
            d001
        )
        .is_empty());
    }

    // -- D002 --------------------------------------------------------------

    #[test]
    fn d002_fires_on_wall_clock_in_sim_modules() {
        let diags = run_rule(
            "rust/src/cluster/foo.rs",
            "fn f() { let t = Instant::now(); let s = Stopwatch::start(); }",
            d002,
        );
        assert_eq!(diags.len(), 2);
        assert!(diags.iter().all(|d| d.rule == "D002"));
    }

    #[test]
    fn d002_ignores_tests_and_exec() {
        assert!(run_rule(
            "rust/src/exec/foo.rs",
            "fn f() { let t = Instant::now(); }",
            d002
        )
        .is_empty());
        assert!(run_rule(
            "rust/src/cluster/foo.rs",
            "#[test]\nfn t() { let t = Instant::now(); }",
            d002
        )
        .is_empty());
    }

    // -- C001 --------------------------------------------------------------

    #[test]
    fn c001_fires_on_raw_lock_unwrap_even_multiline() {
        let diags = run_rule(
            "rust/src/foo.rs",
            "fn f() { let g = m.lock().unwrap(); }",
            c001,
        );
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "C001");
        // chained across lines (the metrics::add shape)
        let diags = run_rule(
            "rust/src/foo.rs",
            "fn f() {\n let g = m\n .lock()\n .expect(\"poisoned\");\n}",
            c001,
        );
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].line, 4);
    }

    #[test]
    fn c001_negative_cases() {
        // lock_unpoisoned and unwrap_or_else are the sanctioned spellings
        assert!(run_rule(
            "rust/src/foo.rs",
            "fn f() { let g = lock_unpoisoned(&m); }",
            c001
        )
        .is_empty());
        assert!(run_rule(
            "rust/src/foo.rs",
            "fn f() { let g = m.lock().unwrap_or_else(|e| e.into_inner()); }",
            c001
        )
        .is_empty());
    }

    // -- C002 --------------------------------------------------------------

    #[test]
    fn c002_fires_when_guard_live_across_pool_call() {
        let diags = run_rule(
            "rust/src/foo.rs",
            "fn f() { let g = lock_unpoisoned(&m); pool.try_run(4, |i| i); }",
            c002,
        );
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "C002");
        assert!(diags[0].message.contains("g"), "{}", diags[0].message);
    }

    #[test]
    fn c002_respects_drop_and_scope() {
        // dropped before the call: fine
        assert!(run_rule(
            "rust/src/foo.rs",
            "fn f() { let g = lock_unpoisoned(&m); drop(g); pool.run(4, |i| i); }",
            c002
        )
        .is_empty());
        // guard scoped to an inner block: fine
        assert!(run_rule(
            "rust/src/foo.rs",
            "fn f() { { let g = lock_unpoisoned(&m); } pool.run(4, |i| i); }",
            c002
        )
        .is_empty());
        // pool call inside the guard's own initializer: the guard is not
        // held yet
        assert!(run_rule(
            "rust/src/foo.rs",
            "fn f() { let v = s.lock().len(); }",
            c002
        )
        .is_empty());
        // a lock inside a nested closure does not make the binding a
        // guard (the try_run job-box shape)
        assert!(run_rule(
            "rust/src/foo.rs",
            "fn f() { let job = Box::new(move || { *lock_unpoisoned(&m) = 1; }); \
             pool.submit(job); }",
            c002
        )
        .is_empty());
    }

    // -- E001 --------------------------------------------------------------

    #[test]
    fn e001_fires_only_in_result_fns() {
        let diags = run_rule(
            "rust/src/foo.rs",
            "fn f() -> Result<u32> { let v = x.unwrap(); Ok(v) }",
            e001,
        );
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, "E001");
        // non-Result fn: allowed
        assert!(run_rule(
            "rust/src/foo.rs",
            "fn f() -> u32 { x.unwrap() }",
            e001
        )
        .is_empty());
    }

    #[test]
    fn e001_inner_fn_shadows_outer_result() {
        // the innermost fn decides: a non-Result helper inside a Result fn
        // may unwrap
        let src = "fn outer() -> Result<()> {\n fn helper() -> u32 { x.unwrap() }\n Ok(())\n}";
        assert!(run_rule("rust/src/foo.rs", src, e001).is_empty());
        // and the reverse nests correctly too
        let src = "fn outer() {\n fn helper() -> Result<u32> { Ok(x.unwrap()) }\n}";
        assert_eq!(run_rule("rust/src/foo.rs", src, e001).len(), 1);
    }

    #[test]
    fn e001_skips_lock_unwrap_and_tests() {
        // C001's finding, not E001's
        assert!(run_rule(
            "rust/src/foo.rs",
            "fn f() -> Result<()> { let g = m.lock().unwrap(); Ok(()) }",
            e001
        )
        .is_empty());
        assert!(run_rule(
            "rust/src/foo.rs",
            "#[cfg(test)]\nmod tests {\n fn f() -> Result<()> { Ok(x.unwrap()) }\n}",
            e001
        )
        .is_empty());
        // unwrap_or / unwrap_or_default are fine
        assert!(run_rule(
            "rust/src/foo.rs",
            "fn f() -> Result<u32> { Ok(x.unwrap_or(0)) }",
            e001
        )
        .is_empty());
    }

    #[test]
    fn e001_allows_question_mark_propagation() {
        // a same-named user method whose Result feeds `?` propagates —
        // the JSON parser's own `self.expect(b'{')?` shape
        assert!(run_rule(
            "rust/src/foo.rs",
            "fn f(&mut self) -> Result<()> { self.expect(b'{')?; Ok(()) }",
            e001
        )
        .is_empty());
        // without the `?` it still fires
        assert_eq!(
            run_rule(
                "rust/src/foo.rs",
                "fn f() -> Result<()> { x.expect(\"boom\"); Ok(()) }",
                e001
            )
            .len(),
            1
        );
    }
}
