//! `mli lint` — in-tree determinism & concurrency invariant checker.
//!
//! The generic Rust toolchain can't see this codebase's contracts: that
//! shuffle output must be bitwise-identical across runs (no `HashMap`
//! iteration in merge paths), that `SimCluster` time is analytic (no
//! wall-clock reads in the ledger), that mutexes recover from poisoning
//! (`lock_unpoisoned`, never `.lock().unwrap()`), and that no guard is
//! held across a `ThreadPool` submit. This module enforces those
//! contracts as lint rules over a hand-rolled token stream
//! ([`lexer`]) — no rustc plugin, no external deps, runs in CI as
//! `mli lint --deny`.
//!
//! Sites that violate a rule *by design* carry an inline annotation:
//!
//! ```text
//! // mli-lint: allow(D002) RetryPolicy timeout is a real wall-clock budget
//! ```
//!
//! on the same line as the finding or the line directly above it;
//! `allow-file(RULE)` anywhere in a file suppresses the rule for the
//! whole file. A reason after the closing paren is conventional (and
//! what reviewers diff), though not enforced.
//!
//! Rule inventory, scopes, and known blind spots of the lexical
//! approach: `docs/lint.md`.

pub mod lexer;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::metrics::Table;
use crate::util::json::Json;
use lexer::Lexed;
use rules::{Diagnostic, FileCtx, ALL_RULES};

/// What to scan and which rules to run.
pub struct LintConfig {
    /// Repo root (the directory containing `rust/`), or the `rust/`
    /// directory itself — both are accepted.
    pub root: PathBuf,
    /// Rule ids to run; empty means all.
    pub rules: Vec<String>,
}

impl LintConfig {
    pub fn all(root: impl Into<PathBuf>) -> LintConfig {
        LintConfig { root: root.into(), rules: Vec::new() }
    }

    fn enabled(&self, rule: &str) -> bool {
        self.rules.is_empty() || self.rules.iter().any(|r| r == rule)
    }
}

/// Outcome of a lint run.
pub struct LintReport {
    /// Findings that survived suppression filtering, sorted by
    /// (file, line, rule).
    pub diags: Vec<Diagnostic>,
    /// Files scanned.
    pub files: usize,
    /// Findings suppressed by `mli-lint: allow(..)` annotations.
    pub suppressed: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.diags.is_empty()
    }

    /// Machine-readable report (CI artifact shape; keys sorted, stable).
    pub fn to_json(&self) -> Json {
        let diags = self
            .diags
            .iter()
            .map(|d| {
                Json::obj(vec![
                    ("file", Json::from(d.file.as_str())),
                    ("line", Json::from(d.line)),
                    ("rule", Json::from(d.rule)),
                    ("message", Json::from(d.message.as_str())),
                    ("suggestion", Json::from(d.suggestion.as_str())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("tool", Json::from("mli-lint")),
            ("files_scanned", Json::from(self.files)),
            ("suppressed", Json::from(self.suppressed)),
            ("diagnostics", Json::arr(diags)),
        ])
    }

    /// Human-readable report: one block per finding plus a per-rule
    /// summary table.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diags {
            out.push_str(&format!("{}:{} [{}] {}\n", d.file, d.line, d.rule, d.message));
            out.push_str(&format!("    help: {}\n", d.suggestion));
        }
        let mut t = Table::new("mli lint", &["rule", "what it checks", "findings"]);
        for rule in ALL_RULES {
            let n = self.diags.iter().filter(|d| d.rule == rule).count();
            t.row(vec![
                rule.to_string(),
                rules::rule_summary(rule).to_string(),
                n.to_string(),
            ]);
        }
        t.note(format!(
            "{} files scanned, {} finding{}, {} suppressed by `mli-lint: allow`",
            self.files,
            self.diags.len(),
            if self.diags.len() == 1 { "" } else { "s" },
            self.suppressed
        ));
        out.push_str(&t.to_markdown());
        out
    }
}

/// Lint a single file's source text. `rel` must be the repo-relative
/// path (`rust/src/...`) — rules scope themselves by it.
pub fn lint_source(rel: &str, src: &str, cfg: &LintConfig) -> (Vec<Diagnostic>, usize) {
    let Lexed { tokens, directives } = lexer::lex(src);
    let ctx = FileCtx::new(rel, &tokens);
    let mut found = Vec::new();
    if cfg.enabled("D001") {
        rules::d001(&ctx, &mut found);
    }
    if cfg.enabled("D002") {
        rules::d002(&ctx, &mut found);
    }
    if cfg.enabled("C001") {
        rules::c001(&ctx, &mut found);
    }
    if cfg.enabled("C002") {
        rules::c002(&ctx, &mut found);
    }
    if cfg.enabled("E001") {
        rules::e001(&ctx, &mut found);
    }
    // suppression: `allow(R)` on the finding's line or the line above,
    // `allow-file(R)` anywhere
    let before = found.len();
    found.retain(|d| {
        !directives.iter().any(|dir| {
            dir.rule == d.rule
                && (dir.file_wide || dir.line == d.line || dir.line + 1 == d.line)
        })
    });
    let suppressed = before - found.len();
    (found, suppressed)
}

/// Run the configured rules over `rust/src`, `rust/tests` and
/// `rust/benches` beneath the config root.
pub fn run(cfg: &LintConfig) -> Result<LintReport> {
    // accept either the repo root or the rust/ crate dir
    let base = if cfg.root.join("rust").join("src").is_dir() {
        cfg.root.join("rust")
    } else if cfg.root.join("src").is_dir() {
        cfg.root.clone()
    } else {
        return Err(Error::Config(format!(
            "lint root '{}' contains neither rust/src nor src",
            cfg.root.display()
        )));
    };
    let mut files = Vec::new();
    for sub in ["src", "tests", "benches"] {
        let dir = base.join(sub);
        if dir.is_dir() {
            collect_rs_files(&dir, &mut files)?;
        }
    }
    files.sort(); // deterministic scan order → deterministic report
    let mut diags = Vec::new();
    let mut suppressed = 0usize;
    for path in &files {
        let rel = format!(
            "rust/{}",
            path.strip_prefix(&base)
                .unwrap_or(path)
                .to_string_lossy()
                .replace('\\', "/")
        );
        let src = fs::read_to_string(path)?;
        let (found, supp) = lint_source(&rel, &src, cfg);
        diags.extend(found);
        suppressed += supp;
    }
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(LintReport { diags, files: files.len(), suppressed })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LintConfig {
        LintConfig::all(".")
    }

    #[test]
    fn suppression_same_line_and_line_above() {
        // same line
        let (diags, supp) = lint_source(
            "rust/src/engine/x.rs",
            "fn f() { let m = HashMap::new(); } // mli-lint: allow(D001) lookup-only\n",
            &cfg(),
        );
        assert!(diags.is_empty(), "{diags:?}");
        assert_eq!(supp, 1);
        // line above
        let (diags, supp) = lint_source(
            "rust/src/engine/x.rs",
            "// mli-lint: allow(D001) lookup-only\nfn f() { let m = HashMap::new(); }\n",
            &cfg(),
        );
        assert!(diags.is_empty(), "{diags:?}");
        assert_eq!(supp, 1);
    }

    #[test]
    fn suppression_is_rule_specific_and_local() {
        // wrong rule id: does not suppress
        let (diags, _) = lint_source(
            "rust/src/engine/x.rs",
            "// mli-lint: allow(D002) wrong rule\nfn f() { let m = HashMap::new(); }\n",
            &cfg(),
        );
        assert_eq!(diags.len(), 1);
        // two lines above: too far
        let (diags, _) = lint_source(
            "rust/src/engine/x.rs",
            "// mli-lint: allow(D001) too far\n\nfn f() { let m = HashMap::new(); }\n",
            &cfg(),
        );
        assert_eq!(diags.len(), 1);
    }

    #[test]
    fn file_wide_suppression() {
        let (diags, supp) = lint_source(
            "rust/src/engine/x.rs",
            "// mli-lint: allow-file(D001) legacy module\n\
             fn f() { let m = HashMap::new(); }\n\
             fn g() { let s = HashSet::new(); }\n",
            &cfg(),
        );
        assert!(diags.is_empty(), "{diags:?}");
        assert_eq!(supp, 2);
    }

    #[test]
    fn rule_filter_restricts_to_requested() {
        let src = "fn f() -> Result<()> { let m = HashMap::new(); let g = x.lock().unwrap(); Ok(()) }";
        let all = lint_source("rust/src/engine/x.rs", src, &cfg()).0;
        assert!(all.iter().any(|d| d.rule == "D001"));
        assert!(all.iter().any(|d| d.rule == "C001"));
        let only = LintConfig {
            root: PathBuf::from("."),
            rules: vec!["C001".to_string()],
        };
        let some = lint_source("rust/src/engine/x.rs", src, &only).0;
        assert!(some.iter().all(|d| d.rule == "C001"), "{some:?}");
        assert!(!some.is_empty());
    }

    #[test]
    fn json_report_shape_roundtrips() {
        let (diags, _) = lint_source(
            "rust/src/engine/x.rs",
            "fn f() { let m = HashMap::new(); }",
            &cfg(),
        );
        let report = LintReport { diags, files: 1, suppressed: 0 };
        let j = report.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("tool").unwrap().as_str().unwrap(), "mli-lint");
        let ds = parsed.get("diagnostics").unwrap().as_arr().unwrap();
        assert_eq!(ds.len(), 1);
        assert_eq!(ds[0].get("rule").unwrap().as_str().unwrap(), "D001");
        assert_eq!(
            ds[0].get("file").unwrap().as_str().unwrap(),
            "rust/src/engine/x.rs"
        );
        assert_eq!(ds[0].get("line").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn text_report_names_every_rule() {
        let report = LintReport { diags: Vec::new(), files: 3, suppressed: 2 };
        let text = report.to_text();
        for rule in ALL_RULES {
            assert!(text.contains(rule), "summary table missing {rule}");
        }
        assert!(text.contains("3 files scanned"));
    }
}
