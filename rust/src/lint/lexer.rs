//! A minimal hand-rolled Rust tokenizer for the lint pass.
//!
//! This is not a full Rust lexer — it is exactly enough to make the lint
//! rules decidable on this codebase without external crates: it strips
//! comments (collecting `mli-lint:` directives), strings (including raw
//! and byte strings), char literals (disambiguated from lifetimes), and
//! yields identifiers, numbers and punctuation with 1-based line numbers.
//! Multi-char punctuation is merged only where a rule needs it (`::`,
//! `->`, `=>`); everything else is one token per char.

/// Token classes the rules dispatch on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `let`, `HashMap`, ...).
    Ident,
    /// `'a` — kept distinct so lifetimes never look like char literals.
    Lifetime,
    /// Integer or float literal (suffix included).
    Number,
    /// String / raw string / byte string / char literal (contents dropped:
    /// rules must never match inside literals).
    Literal,
    /// Punctuation: single char, or one of the merged pairs `::` `->` `=>`.
    Punct,
}

/// One lexed token.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    /// 1-based source line of the token's first character.
    pub line: usize,
}

impl Token {
    pub fn is(&self, kind: TokKind, text: &str) -> bool {
        self.kind == kind && self.text == text
    }
}

/// An inline lint directive collected from a `//` comment:
/// `// mli-lint: allow(<RULE>) <reason>` or
/// `// mli-lint: allow-file(<RULE>) <reason>`.
#[derive(Debug, Clone)]
pub struct Directive {
    /// Rule id the directive names, e.g. "D001".
    pub rule: String,
    /// 1-based line the comment appears on.
    pub line: usize,
    /// True for `allow-file` (whole-file suppression).
    pub file_wide: bool,
}

/// Lexer output: the token stream plus any lint directives found in
/// comments along the way.
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub directives: Vec<Directive>,
}

/// Tokenize `src`. Unterminated constructs (string, block comment) simply
/// consume to end-of-file — the linter is tolerant by design.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut tokens = Vec::new();
    let mut directives = Vec::new();

    // Helper closures can't borrow line mutably alongside the main loop,
    // so line accounting is done inline wherever a region is consumed.
    while i < b.len() {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => {
                i += 1;
            }
            '/' if i + 1 < b.len() && b[i + 1] == '/' => {
                // line comment: scan for a lint directive, then skip
                let start = i;
                while i < b.len() && b[i] != '\n' {
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                parse_directive(&text, line, &mut directives);
            }
            '/' if i + 1 < b.len() && b[i + 1] == '*' => {
                // block comment, nested per Rust rules
                let mut depth = 1usize;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            '"' => {
                let tok_line = line;
                i += 1;
                while i < b.len() {
                    match b[i] {
                        '\\' => i += 2,
                        '"' => {
                            i += 1;
                            break;
                        }
                        '\n' => {
                            line += 1;
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
                tokens.push(Token {
                    kind: TokKind::Literal,
                    text: String::from("\"\""),
                    line: tok_line,
                });
            }
            'r' | 'b' if starts_raw_or_byte_string(&b, i) => {
                let tok_line = line;
                // skip the r/b/br prefix
                while i < b.len() && (b[i] == 'r' || b[i] == 'b') {
                    i += 1;
                }
                let mut hashes = 0usize;
                while i < b.len() && b[i] == '#' {
                    hashes += 1;
                    i += 1;
                }
                if i < b.len() && b[i] == '"' {
                    i += 1;
                    if hashes == 0 {
                        // raw string without hashes: plain `"` terminates,
                        // no escapes
                        while i < b.len() && b[i] != '"' {
                            if b[i] == '\n' {
                                line += 1;
                            }
                            i += 1;
                        }
                        i += 1; // closing quote
                    } else {
                        // terminated by `"` + `hashes` consecutive `#`
                        'outer: while i < b.len() {
                            if b[i] == '\n' {
                                line += 1;
                                i += 1;
                                continue;
                            }
                            if b[i] == '"' {
                                let mut k = 0usize;
                                while k < hashes && i + 1 + k < b.len() && b[i + 1 + k] == '#' {
                                    k += 1;
                                }
                                if k == hashes {
                                    i += 1 + hashes;
                                    break 'outer;
                                }
                            }
                            i += 1;
                        }
                    }
                }
                tokens.push(Token {
                    kind: TokKind::Literal,
                    text: String::from("\"\""),
                    line: tok_line,
                });
            }
            '\'' => {
                // lifetime or char literal. `'a` (ident char, no closing
                // quote right after) is a lifetime; everything else is a
                // char literal.
                let tok_line = line;
                let is_lifetime = i + 1 < b.len()
                    && (b[i + 1].is_alphabetic() || b[i + 1] == '_')
                    && !(i + 2 < b.len() && b[i + 2] == '\'');
                if is_lifetime {
                    let start = i;
                    i += 1;
                    while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                        i += 1;
                    }
                    tokens.push(Token {
                        kind: TokKind::Lifetime,
                        text: b[start..i].iter().collect(),
                        line: tok_line,
                    });
                } else {
                    i += 1;
                    if i < b.len() && b[i] == '\\' {
                        i += 2; // escape + escaped char
                        // \u{...}
                        if i < b.len() && b[i - 1] == 'u' && b[i] == '{' {
                            while i < b.len() && b[i] != '}' {
                                i += 1;
                            }
                            i += 1;
                        }
                    } else if i < b.len() {
                        i += 1;
                    }
                    if i < b.len() && b[i] == '\'' {
                        i += 1;
                    }
                    tokens.push(Token {
                        kind: TokKind::Literal,
                        text: String::from("''"),
                        line: tok_line,
                    });
                }
            }
            c if c.is_ascii_digit() => {
                let tok_line = line;
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_alphanumeric()
                        || b[i] == '_'
                        || (b[i] == '.'
                            && i + 1 < b.len()
                            && b[i + 1].is_ascii_digit()
                            && !b[start..i].contains(&'.')))
                {
                    i += 1;
                }
                tokens.push(Token {
                    kind: TokKind::Number,
                    text: b[start..i].iter().collect(),
                    line: tok_line,
                });
            }
            c if c.is_alphabetic() || c == '_' => {
                let tok_line = line;
                let start = i;
                while i < b.len() && (b[i].is_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                tokens.push(Token {
                    kind: TokKind::Ident,
                    text: b[start..i].iter().collect(),
                    line: tok_line,
                });
            }
            _ => {
                let tok_line = line;
                // merge the pairs rules care about
                let two: Option<&str> = if i + 1 < b.len() {
                    match (c, b[i + 1]) {
                        (':', ':') => Some("::"),
                        ('-', '>') => Some("->"),
                        ('=', '>') => Some("=>"),
                        _ => None,
                    }
                } else {
                    None
                };
                if let Some(t) = two {
                    tokens.push(Token {
                        kind: TokKind::Punct,
                        text: t.to_string(),
                        line: tok_line,
                    });
                    i += 2;
                } else {
                    tokens.push(Token {
                        kind: TokKind::Punct,
                        text: c.to_string(),
                        line: tok_line,
                    });
                    i += 1;
                }
            }
        }
    }
    Lexed { tokens, directives }
}

/// Does `r`, `b`, `rb`/`br` at `i` start a raw/byte string (and not an
/// identifier like `result` or `bytes`)?
fn starts_raw_or_byte_string(b: &[char], i: usize) -> bool {
    let mut j = i;
    // at most two prefix letters (r, b, br, rb — rustc only accepts r/b/br,
    // but over-accepting here is harmless)
    let mut letters = 0;
    while j < b.len() && (b[j] == 'r' || b[j] == 'b') && letters < 2 {
        j += 1;
        letters += 1;
    }
    let mut k = j;
    while k < b.len() && b[k] == '#' {
        k += 1;
    }
    // must reach a quote, and `b"..."` (no hash) is a plain byte string;
    // `r` requires either a hash or a quote right after
    if k >= b.len() || b[k] != '"' {
        return false;
    }
    // exclude identifiers ending in r/b followed by... not possible: we
    // are called only when position i itself is 'r'/'b' starting a token,
    // which the main loop guarantees (previous char was not ident-ish)
    true
}

/// Parse `// mli-lint: allow(<RULE>) ...` / `allow-file(<RULE>) ...`.
fn parse_directive(comment: &str, line: usize, out: &mut Vec<Directive>) {
    let Some(pos) = comment.find("mli-lint:") else {
        return;
    };
    let rest = comment[pos + "mli-lint:".len()..].trim_start();
    let file_wide = rest.starts_with("allow-file(");
    let open = if file_wide {
        "allow-file("
    } else if rest.starts_with("allow(") {
        "allow("
    } else {
        return;
    };
    let body = &rest[open.len()..];
    let Some(close) = body.find(')') else {
        return;
    };
    let rule = body[..close].trim().to_string();
    if !rule.is_empty() {
        out.push(Directive {
            rule,
            line,
            file_wide,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_and_strings_are_stripped() {
        let src = r##"
// HashMap in a comment
/* HashMap in /* a nested */ block */
let s = "HashMap in a string";
let r = r#"HashMap raw "quoted" here"#;
let c = 'H';
real_ident();
"##;
        let ids = idents(src);
        assert!(!ids.contains(&"HashMap".to_string()), "{ids:?}");
        assert!(ids.contains(&"real_ident".to_string()));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }";
        let lexed = lex(src);
        let lifetimes: Vec<_> = lexed
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .collect();
        assert_eq!(lifetimes.len(), 3);
        // the code after a lifetime still lexes (a char-literal
        // misparse would swallow `a>(x`)
        assert!(lexed.tokens.iter().any(|t| t.is(TokKind::Ident, "str")));
    }

    #[test]
    fn merged_puncts() {
        let lexed = lex("fn f() -> std::io::Result<()> { match x { _ => 1 } }");
        let puncts: Vec<_> = lexed
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Punct && t.text.len() == 2)
            .map(|t| t.text.clone())
            .collect();
        assert!(puncts.contains(&"->".to_string()));
        assert!(puncts.contains(&"::".to_string()));
        assert!(puncts.contains(&"=>".to_string()));
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let src = "let a = \"two\nlines\";\nmarker();";
        let lexed = lex(src);
        let marker = lexed
            .tokens
            .iter()
            .find(|t| t.is(TokKind::Ident, "marker"))
            .unwrap();
        assert_eq!(marker.line, 3);
    }

    #[test]
    fn directives_parsed() {
        let src = "// mli-lint: allow(D001) lookup-only\nx();\n// mli-lint: allow-file(E001) generated\n";
        let lexed = lex(src);
        assert_eq!(lexed.directives.len(), 2);
        assert_eq!(lexed.directives[0].rule, "D001");
        assert_eq!(lexed.directives[0].line, 1);
        assert!(!lexed.directives[0].file_wide);
        assert!(lexed.directives[1].file_wide);
        assert_eq!(lexed.directives[1].rule, "E001");
    }

    #[test]
    fn byte_and_raw_strings() {
        let src = "let x = b\"HashMap\"; let y = br#\"HashSet\"#; let z = rest;";
        let ids = idents(src);
        assert!(!ids.contains(&"HashMap".to_string()));
        assert!(!ids.contains(&"HashSet".to_string()));
        // `rest` starts with r but is an ident, not a raw string
        assert!(ids.contains(&"rest".to_string()));
    }

    #[test]
    fn numbers_with_suffixes_and_ranges() {
        let lexed = lex("for i in 0..10u64 { let f = 1.5f32; }");
        let nums: Vec<_> = lexed
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Number)
            .map(|t| t.text.clone())
            .collect();
        assert_eq!(nums, vec!["0", "10u64", "1.5f32"]);
    }
}
