//! Column standardization: (x - mean) / std per column — the usual
//! preprocessing before SGD on raw features.

use std::sync::Arc;

use crate::error::Result;
use crate::mltable::{MLNumericTable, MLRow, Schema};

/// Standardize every column to zero mean, unit variance (columns with
/// zero variance pass through centered). `skip_cols` columns at the left
/// (e.g. the label column) are copied unchanged.
pub fn standard_scale(t: &MLNumericTable, skip_cols: usize) -> Result<MLNumericTable> {
    let d = t.num_cols();
    let n = t.num_rows()? as f64;

    // one pass: per-column sum and sum-of-squares
    let (sums, sqs) = t
        .dataset()
        .map_partitions(move |_, rows| {
            let mut s = vec![0.0f64; d];
            let mut q = vec![0.0f64; d];
            for r in rows {
                for j in 0..d {
                    let x = r[j].as_scalar().unwrap_or(0.0);
                    s[j] += x;
                    q[j] += x * x;
                }
            }
            Ok(vec![(s, q)])
        })
        .reduce(|(mut sa, mut qa), (sb, qb)| {
            for (x, y) in sa.iter_mut().zip(&sb) {
                *x += y;
            }
            for (x, y) in qa.iter_mut().zip(&qb) {
                *x += y;
            }
            (sa, qa)
        })?
        .unwrap_or((vec![0.0; d], vec![0.0; d]));

    let mean: Vec<f64> = sums.iter().map(|s| s / n.max(1.0)).collect();
    let std: Vec<f64> = sqs
        .iter()
        .zip(&mean)
        .map(|(q, m)| ((q / n.max(1.0)) - m * m).max(0.0).sqrt())
        .collect();
    let mean = Arc::new(mean);
    let std = Arc::new(std);

    let table = t.table().map(Schema::numeric(d), move |r| {
        let out: Vec<f64> = (0..d)
            .map(|j| {
                let x = r[j].as_scalar().unwrap_or(0.0);
                if j < skip_cols {
                    x
                } else if std[j] > 1e-12 {
                    (x - mean[j]) / std[j]
                } else {
                    x - mean[j]
                }
            })
            .collect();
        MLRow::from_scalars(&out)
    });
    table.to_numeric()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;
    use crate::mltable::{MLRow, MLTable, Schema};

    #[test]
    fn standardizes_columns() {
        let ctx = EngineContext::new();
        let rows = vec![
            MLRow::from_scalars(&[1.0, 10.0]),
            MLRow::from_scalars(&[1.0, 20.0]),
            MLRow::from_scalars(&[0.0, 30.0]),
            MLRow::from_scalars(&[0.0, 40.0]),
        ];
        let t = MLTable::from_rows(&ctx, rows, Schema::numeric(2), 2)
            .unwrap()
            .to_numeric()
            .unwrap();
        let s = standard_scale(&t, 1).unwrap();
        let m = s.collect_matrix().unwrap();
        // col0 skipped (labels preserved)
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(3, 0), 0.0);
        // col1 standardized: mean 0, var 1
        let col: Vec<f64> = (0..4).map(|r| m.get(r, 1)).collect();
        let mean: f64 = col.iter().sum::<f64>() / 4.0;
        let var: f64 = col.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / 4.0;
        assert!(mean.abs() < 1e-12);
        assert!((var - 1.0).abs() < 1e-9);
    }

    #[test]
    fn constant_column_centered_not_divided() {
        let ctx = EngineContext::new();
        let rows = vec![MLRow::from_scalars(&[5.0]), MLRow::from_scalars(&[5.0])];
        let t = MLTable::from_rows(&ctx, rows, Schema::numeric(1), 1)
            .unwrap()
            .to_numeric()
            .unwrap();
        let m = standard_scale(&t, 0).unwrap().collect_matrix().unwrap();
        assert_eq!(m.get(0, 0), 0.0);
        assert!(m.get(1, 0).is_finite());
    }
}
