//! tf-idf feature extractor (Fig. A2: `tfIdf(nGrams(...))`).
//!
//! Input: a numeric table of per-document term counts (the nGrams
//! output). Output: same shape, reweighted as
//! `tf * idf = (count / doc_len) * ln(N / (1 + df))`.

use crate::error::Result;
use crate::mltable::{MLNumericTable, MLRow, Schema};

/// Compute tf-idf over a count table.
pub fn tfidf(counts: &MLNumericTable) -> Result<MLNumericTable> {
    let d = counts.num_cols();
    let n_docs = counts.num_rows()? as f64;

    // document frequencies per term (one engine pass)
    let df = counts
        .dataset()
        .map_partitions(move |_, rows| {
            let mut local = vec![0.0f64; d];
            for r in rows {
                for (j, slot) in local.iter_mut().enumerate() {
                    if r[j].as_scalar().unwrap_or(0.0) > 0.0 {
                        *slot += 1.0;
                    }
                }
            }
            Ok(vec![local])
        })
        .reduce(|mut a, b| {
            for (x, y) in a.iter_mut().zip(&b) {
                *x += y;
            }
            a
        })?
        .unwrap_or_else(|| vec![0.0; d]);

    let idf: std::sync::Arc<Vec<f64>> = std::sync::Arc::new(
        df.iter().map(|&dfj| (n_docs / (1.0 + dfj)).ln().max(0.0)).collect(),
    );

    let table = counts.table().map(Schema::numeric(d), move |r| {
        let mut counts_row = Vec::with_capacity(d);
        let mut doc_len = 0.0;
        for j in 0..d {
            let c = r[j].as_scalar().unwrap_or(0.0);
            doc_len += c;
            counts_row.push(c);
        }
        let denom = doc_len.max(1.0);
        let out: Vec<f64> = counts_row
            .iter()
            .zip(idf.iter())
            .map(|(&c, &w)| (c / denom) * w)
            .collect();
        MLRow::from_scalars(&out)
    });
    table.to_numeric()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;
    use crate::mltable::{MLRow, MLTable, Schema};

    fn counts_table() -> MLNumericTable {
        let ctx = EngineContext::new();
        // 3 docs x 3 terms; term0 in all docs, term1 in one, term2 in none
        let rows = vec![
            MLRow::from_scalars(&[2.0, 0.0, 0.0]),
            MLRow::from_scalars(&[1.0, 3.0, 0.0]),
            MLRow::from_scalars(&[1.0, 0.0, 0.0]),
        ];
        MLTable::from_rows(&ctx, rows, Schema::numeric(3), 2)
            .unwrap()
            .to_numeric()
            .unwrap()
    }

    #[test]
    fn idf_downweights_ubiquitous_terms() {
        let t = tfidf(&counts_table()).unwrap();
        let m = t.collect_matrix().unwrap();
        // term0 appears in every doc: idf = ln(3/4) < 0 clamped to 0
        assert_eq!(m.get(0, 0), 0.0);
        // term1 appears in 1 doc: idf = ln(3/2) > 0; doc1 tf = 3/4
        let expect = (3.0 / 4.0) * (3.0f64 / 2.0).ln();
        assert!((m.get(1, 1) - expect).abs() < 1e-12);
        // absent term stays 0
        assert_eq!(m.get(2, 2), 0.0);
    }

    #[test]
    fn shape_preserved() {
        let t = tfidf(&counts_table()).unwrap();
        assert_eq!(t.num_rows().unwrap(), 3);
        assert_eq!(t.num_cols(), 3);
    }
}
