//! Feature extraction (paper §III-A, Fig. A2): transformations from raw
//! MLTables to featurized MLTables — `nGrams`, `tfIdf`, plus a standard
//! scaler. Each is a function `MLTable -> MLTable` (of a possibly
//! different schema), matching the paper's composition style:
//!
//! ```text
//! let featurized = tfidf(&ngrams(&raw_text, 2, 30000)?)?;
//! ```

pub mod ngrams;
pub mod scaler;
pub mod tfidf;
pub mod tokenize;

pub use ngrams::{ngrams, NGramsOutput};
pub use scaler::standard_scale;
pub use tfidf::tfidf;
pub use tokenize::tokenize;
