//! Tokenization: lowercase, split on non-alphanumeric runs.

/// Split a document into lowercase alphanumeric tokens.
pub fn tokenize(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    for c in text.chars() {
        if c.is_alphanumeric() {
            for lc in c.to_lowercase() {
                cur.push(lc);
            }
        } else if !cur.is_empty() {
            out.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_and_lowercases() {
        assert_eq!(
            tokenize("Hello, World! x2"),
            vec!["hello", "world", "x2"]
        );
        assert_eq!(tokenize(""), Vec::<String>::new());
        assert_eq!(tokenize("  --  "), Vec::<String>::new());
        assert_eq!(tokenize("a"), vec!["a"]);
    }

    #[test]
    fn unicode_safe() {
        assert_eq!(tokenize("Café au lait"), vec!["café", "au", "lait"]);
    }
}
