//! nGrams feature extractor (Fig. A2: `nGrams(rawTextTable, n=2,
//! top=30000)`): builds the corpus-wide top-k n-gram vocabulary, then maps
//! each document to its n-gram count vector.

use std::collections::HashMap;
use std::sync::Arc;

use super::tokenize::tokenize;
use crate::error::{Error, Result};
use crate::mltable::{MLNumericTable, MLRow, MLTable, Schema};

/// Result of n-gram extraction: the featurized table plus the vocabulary
/// (index -> n-gram), needed to interpret the columns downstream.
pub struct NGramsOutput {
    pub table: MLNumericTable,
    pub vocab: Arc<Vec<String>>,
}

/// Extract n-gram counts. `text_col` must be a Str column; the output has
/// `top` Scalar columns (one per vocabulary n-gram, ordered by descending
/// corpus frequency, ties broken lexicographically for determinism).
pub fn ngrams(table: &MLTable, text_col: usize, n: usize, top: usize) -> Result<NGramsOutput> {
    if n == 0 {
        return Err(Error::Config("ngrams: n must be >= 1".into()));
    }
    // pass 1: corpus-wide n-gram document frequencies (driver-side merge
    // of per-partition counts — the reduceByKey pattern).
    let counts = table
        .dataset()
        .map_partitions(move |_, rows| {
            let mut local: HashMap<String, u64> = HashMap::new();
            for r in rows {
                let text = r[text_col]
                    .as_str()
                    .ok_or_else(|| Error::Schema("ngrams: text column is not Str".into()))?;
                for g in doc_ngrams(text, n) {
                    *local.entry(g).or_insert(0) += 1;
                }
            }
            Ok(local.into_iter().collect::<Vec<(String, u64)>>())
        })
        .reduce_by_key(|a, b| a + b)
        .collect()?;

    // top-k vocabulary, deterministic order
    let mut sorted: Vec<(String, u64)> = counts;
    sorted.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    sorted.truncate(top);
    let vocab: Arc<Vec<String>> = Arc::new(sorted.into_iter().map(|(g, _)| g).collect());
    let index: Arc<HashMap<String, usize>> = Arc::new(
        vocab
            .iter()
            .enumerate()
            .map(|(i, g)| (g.clone(), i))
            .collect(),
    );
    let width = vocab.len();

    // pass 2: per-document count vectors
    let idx = index.clone();
    let out = table.map(Schema::numeric(width), move |r| {
        let mut v = vec![0.0f64; width];
        if let Some(text) = r[text_col].as_str() {
            for g in doc_ngrams(text, n) {
                if let Some(&i) = idx.get(&g) {
                    v[i] += 1.0;
                }
            }
        }
        MLRow::from_scalars(&v)
    });
    Ok(NGramsOutput {
        table: out.to_numeric()?,
        vocab,
    })
}

fn doc_ngrams(text: &str, n: usize) -> Vec<String> {
    let toks = tokenize(text);
    if toks.len() < n {
        return Vec::new();
    }
    (0..=toks.len() - n)
        .map(|i| toks[i..i + n].join(" "))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;
    use crate::mltable::text_from_str;

    #[test]
    fn unigrams_count_correctly() {
        let ctx = EngineContext::new();
        let t = text_from_str(&ctx, "a b a\nb b c\n", 2).unwrap();
        let out = ngrams(&t, 0, 1, 10).unwrap();
        // corpus freq: b=3, a=2, c=1 -> vocab [b, a, c]
        assert_eq!(out.vocab.as_slice(), &["b", "a", "c"]);
        let m = out.table.collect_matrix().unwrap();
        assert_eq!(m.rows, 2);
        assert_eq!(m.get(0, 1), 2.0); // doc0 has 2 a's
        assert_eq!(m.get(1, 0), 2.0); // doc1 has 2 b's
        assert_eq!(m.get(0, 2), 0.0);
    }

    #[test]
    fn bigrams_and_top_cutoff() {
        let ctx = EngineContext::new();
        let t = text_from_str(&ctx, "x y x y\nx y z\n", 1).unwrap();
        let out = ngrams(&t, 0, 2, 2).unwrap();
        // bigram freq: "x y"=3, "y x"=1, "y z"=1 -> top2 = ["x y", then tie]
        assert_eq!(out.vocab.len(), 2);
        assert_eq!(out.vocab[0], "x y");
        let m = out.table.collect_matrix().unwrap();
        assert_eq!(m.get(0, 0), 2.0);
    }

    #[test]
    fn n_zero_rejected_and_short_docs_ok() {
        let ctx = EngineContext::new();
        let t = text_from_str(&ctx, "one\n\n", 1).unwrap();
        assert!(ngrams(&t, 0, 0, 5).is_err());
        let out = ngrams(&t, 0, 2, 5).unwrap(); // doc shorter than n
        assert_eq!(out.vocab.len(), 0);
    }
}
