//! Human-readable trace summary.
//!
//! Aggregates spans by normalized name (digit runs collapsed, so
//! `sgd-round-0..N` fold into one line), lists counters, and reports
//! simulated-vs-wall-clock attribution when both clocks were recorded.

use std::collections::BTreeMap;

use super::{normalize, SpanEvent};
use crate::metrics::Table;

struct Agg {
    cat: &'static str,
    count: u64,
    total_ns: u64,
    min_ns: u64,
    max_ns: u64,
}

/// Render the summary tables as a string (printed by `mli trace` and the
/// `--trace-out` paths).
pub fn render(spans: &[SpanEvent], counters: &BTreeMap<String, u64>) -> String {
    let mut out = String::new();

    if spans.is_empty() {
        out.push_str("trace: no spans recorded\n");
    } else {
        let mut aggs: BTreeMap<String, Agg> = BTreeMap::new();
        for s in spans {
            let key = normalize(&s.name);
            let a = aggs.entry(key).or_insert(Agg {
                cat: s.cat,
                count: 0,
                total_ns: 0,
                min_ns: u64::MAX,
                max_ns: 0,
            });
            a.count += 1;
            a.total_ns += s.dur_ns;
            a.min_ns = a.min_ns.min(s.dur_ns);
            a.max_ns = a.max_ns.max(s.dur_ns);
        }
        let mut table = Table::new(
            "trace summary (wall-clock spans)",
            &["span", "cat", "count", "total_ms", "mean_ms", "min_ms", "max_ms"],
        );
        let ms = |ns: u64| format!("{:.3}", ns as f64 / 1e6);
        for (name, a) in &aggs {
            table.row(vec![
                name.clone(),
                a.cat.to_string(),
                a.count.to_string(),
                ms(a.total_ns),
                format!("{:.3}", a.total_ns as f64 / a.count as f64 / 1e6),
                ms(a.min_ns),
                ms(a.max_ns),
            ]);
        }
        out.push_str(&table.to_markdown());
    }

    if !counters.is_empty() {
        let mut table = Table::new("trace counters", &["counter", "value"]);
        for (k, v) in counters {
            table.row(vec![k.clone(), v.to_string()]);
        }
        out.push('\n');
        out.push_str(&table.to_markdown());
    }

    // Simulated-vs-wall attribution: the SimCluster ledger records both
    // clocks per round as counters.
    let sim = counters.get("sim.micros").copied().unwrap_or(0);
    let wall = counters.get("wall.micros").copied().unwrap_or(0);
    if sim > 0 || wall > 0 {
        let ratio = if wall > 0 {
            format!("{:.2}x", sim as f64 / wall as f64)
        } else {
            "n/a".to_string()
        };
        out.push_str(&format!(
            "\nclocks: simulated {:.3}s vs wall {:.3}s ({} sim/wall)\n",
            sim as f64 / 1e6,
            wall as f64 / 1e6,
            ratio
        ));
    }

    // Network fault attribution: the SimCluster's fault-aware send path
    // records drop/retry/duplicate/partition counters when a NetFaultPlan
    // is active; silent when the run was failure-free.
    let net = |k: &str| counters.get(k).copied().unwrap_or(0);
    let (drops, retries, dups, waits) = (
        net("net.drops"),
        net("net.retries"),
        net("net.dups"),
        net("net.partition.waits"),
    );
    if drops + retries + dups + waits > 0 {
        out.push_str(&format!(
            "net faults: {drops} drops, {retries} retries, {dups} dup deliveries, \
             {waits} partition waits ({} messages sent)\n",
            net("net.sends")
        ));
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &str, dur_ns: u64) -> SpanEvent {
        SpanEvent {
            name: name.to_string(),
            cat: "optim",
            tid: 0,
            start_ns: 0,
            dur_ns,
            args: Vec::new(),
        }
    }

    #[test]
    fn aggregates_by_normalized_name() {
        let spans = vec![
            span("sgd-round-0", 1_000_000),
            span("sgd-round-1", 3_000_000),
        ];
        let s = render(&spans, &BTreeMap::new());
        assert!(s.contains("sgd-round-#"), "{s}");
        assert!(s.contains("| 2 "), "count column missing: {s}");
        assert!(s.contains("4.000"), "total_ms missing: {s}");
        assert!(s.contains("2.000"), "mean_ms missing: {s}");
    }

    #[test]
    fn empty_trace_renders_placeholder() {
        let s = render(&[], &BTreeMap::new());
        assert!(s.contains("no spans recorded"));
    }

    #[test]
    fn net_fault_line_appears_only_when_faults_fired() {
        let clean = render(&[], &BTreeMap::new());
        assert!(!clean.contains("net faults:"), "{clean}");
        let mut counters = BTreeMap::new();
        counters.insert("net.sends".to_string(), 40u64);
        counters.insert("net.drops".to_string(), 5u64);
        counters.insert("net.retries".to_string(), 5u64);
        counters.insert("net.dups".to_string(), 2u64);
        counters.insert("net.partition.waits".to_string(), 3u64);
        let s = render(&[], &counters);
        assert!(
            s.contains("net faults: 5 drops, 5 retries, 2 dup deliveries"),
            "{s}"
        );
        assert!(s.contains("3 partition waits (40 messages sent)"), "{s}");
    }

    #[test]
    fn clock_attribution_line() {
        let mut counters = BTreeMap::new();
        counters.insert("sim.micros".to_string(), 3_000_000u64);
        counters.insert("wall.micros".to_string(), 1_500_000u64);
        let s = render(&[], &counters);
        assert!(s.contains("simulated 3.000s"), "{s}");
        assert!(s.contains("wall 1.500s"), "{s}");
        assert!(s.contains("2.00x"), "{s}");
    }
}
