//! `trace`: structured tracing + metrics for the executor and the engine.
//!
//! The paper's performance claims ("highly competitive performance and
//! scalability") need visibility into where time actually goes once the
//! work-stealing executor ([`crate::exec`]) is in the loop. This module is
//! that observability substrate:
//!
//! * **Spans** ([`SpanEvent`]) — wall-clock intervals with a name, a
//!   category, a logical thread id and numeric args. The exec layer emits
//!   per-task spans (with queue-wait attribution) and per-stage spans; the
//!   engine emits per-action/per-eval spans; the optimizers emit per-round
//!   and merge spans; the [`crate::cluster::SimCluster`] ledger emits one
//!   span per simulated round carrying both clocks (simulated seconds in
//!   the args, wall-clock as the span duration).
//! * **Counters** — monotonic totals (per-worker tasks/steals/parks/
//!   injector pops via [`crate::exec::ThreadPool::export_trace`], plus
//!   `sim.micros` / `wall.micros` for simulated-vs-wall attribution).
//! * **Sinks** ([`TraceSink`]) — where events go. [`MemorySink`] is the
//!   in-memory aggregator behind the CLI: it renders a human-readable
//!   summary table ([`MemorySink::summary`]) and exports the Chrome trace
//!   event format ([`MemorySink::write_chrome`], loadable in
//!   `chrome://tracing` or ui.perfetto.dev).
//!
//! A [`Tracer`] is attached per component (`ThreadPool::set_tracer`,
//! `EngineContext::with_tracer`, `SimCluster::with_tracer`) and is
//! disabled by default: the hot-path cost when off is one relaxed atomic
//! load ([`Tracer::start`] returns `None` and all span bookkeeping is
//! skipped).
//!
//! Thread-id convention: tid 0 is the driver thread; pool worker `i`
//! reports as tid `i + 1`.

pub mod chrome;
pub mod summary;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::lock_unpoisoned;

/// One completed wall-clock interval.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    pub name: String,
    /// Category: "exec", "engine", "optim", "sim", ...
    pub cat: &'static str,
    /// Logical thread: 0 = driver, worker i = i + 1.
    pub tid: u32,
    /// Nanoseconds since the tracer's epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Numeric attributes (e.g. `queue_wait_ms`, `sim_s`).
    pub args: Vec<(&'static str, f64)>,
}

/// Destination for trace events. Implementations must be cheap and
/// thread-safe: spans arrive concurrently from pool workers.
pub trait TraceSink: Send + Sync {
    fn record_span(&self, span: SpanEvent);
    fn add_counter(&self, name: &str, delta: u64);
}

/// The per-component trace handle. Cloned freely (wrap in `Arc`); all
/// recording methods are no-ops while disabled.
pub struct Tracer {
    epoch: Instant,
    enabled: AtomicBool,
    sink: Mutex<Option<Arc<dyn TraceSink>>>,
}

impl Tracer {
    /// A disabled tracer: every recording call is a cheap no-op. This is
    /// what components hold by default.
    pub fn disabled() -> Arc<Tracer> {
        Arc::new(Tracer {
            epoch: Instant::now(),
            enabled: AtomicBool::new(false),
            sink: Mutex::new(None),
        })
    }

    /// An enabled tracer recording into a fresh [`MemorySink`].
    pub fn recording() -> (Arc<Tracer>, Arc<MemorySink>) {
        let sink = Arc::new(MemorySink::default());
        let tracer = Tracer {
            epoch: Instant::now(),
            enabled: AtomicBool::new(true),
            sink: Mutex::new(Some(sink.clone() as Arc<dyn TraceSink>)),
        };
        (Arc::new(tracer), sink)
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Swap the sink (None disables the tracer).
    pub fn set_sink(&self, sink: Option<Arc<dyn TraceSink>>) {
        let on = sink.is_some();
        *lock_unpoisoned(&self.sink) = sink;
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Nanoseconds since this tracer's epoch.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Hot-path entry: `Some(now_ns)` when enabled, `None` when disabled.
    /// Callers skip all span bookkeeping on `None`.
    pub fn start(&self) -> Option<u64> {
        if self.is_enabled() {
            Some(self.now_ns())
        } else {
            None
        }
    }

    /// Close a span opened at `start_ns` (from [`Tracer::start`]) ending
    /// now, and record it.
    pub fn span(
        &self,
        name: impl Into<String>,
        cat: &'static str,
        tid: u32,
        start_ns: u64,
        args: &[(&'static str, f64)],
    ) {
        if !self.is_enabled() {
            return;
        }
        let ev = SpanEvent {
            name: name.into(),
            cat,
            tid,
            start_ns,
            dur_ns: self.now_ns().saturating_sub(start_ns),
            args: args.to_vec(),
        };
        let sink = lock_unpoisoned(&self.sink);
        if let Some(s) = sink.as_ref() {
            s.record_span(ev);
        }
    }

    /// Bump a named counter.
    pub fn count(&self, name: &str, delta: u64) {
        if !self.is_enabled() {
            return;
        }
        let sink = lock_unpoisoned(&self.sink);
        if let Some(s) = sink.as_ref() {
            s.add_counter(name, delta);
        }
    }
}

/// In-memory aggregator: collects spans + counters, renders the summary
/// table and the Chrome trace export.
#[derive(Default)]
pub struct MemorySink {
    spans: Mutex<Vec<SpanEvent>>,
    counters: Mutex<BTreeMap<String, u64>>,
}

impl TraceSink for MemorySink {
    fn record_span(&self, span: SpanEvent) {
        lock_unpoisoned(&self.spans).push(span);
    }

    fn add_counter(&self, name: &str, delta: u64) {
        *lock_unpoisoned(&self.counters)
            .entry(name.to_string())
            .or_insert(0) += delta;
    }
}

impl MemorySink {
    pub fn spans(&self) -> Vec<SpanEvent> {
        lock_unpoisoned(&self.spans).clone()
    }

    pub fn counters(&self) -> BTreeMap<String, u64> {
        lock_unpoisoned(&self.counters).clone()
    }

    pub fn span_count(&self) -> usize {
        lock_unpoisoned(&self.spans).len()
    }

    pub fn counter(&self, name: &str) -> u64 {
        lock_unpoisoned(&self.counters)
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Human-readable aggregate tables (spans grouped by normalized name,
    /// counters, simulated-vs-wall attribution).
    pub fn summary(&self) -> String {
        summary::render(&self.spans(), &self.counters())
    }

    /// The Chrome trace-event JSON document.
    pub fn chrome_json(&self) -> crate::util::json::Json {
        chrome::to_json(&self.spans(), &self.counters())
    }

    /// Write the Chrome trace to `path` (open in `chrome://tracing` or
    /// ui.perfetto.dev).
    pub fn write_chrome(&self, path: &str) -> crate::error::Result<()> {
        std::fs::write(path, self.chrome_json().to_string())?;
        Ok(())
    }
}

/// Collapse digit runs so per-iteration span names aggregate in the
/// summary: "sgd-round-7" -> "sgd-round-#", "eval:dataset-12" ->
/// "eval:dataset-#".
pub fn normalize(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    let mut in_digits = false;
    for c in name.chars() {
        if c.is_ascii_digit() {
            if !in_digits {
                out.push('#');
                in_digits = true;
            }
        } else {
            in_digits = false;
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_is_noop() {
        let t = Tracer::disabled();
        assert!(!t.is_enabled());
        assert!(t.start().is_none());
        // recording calls must not panic with no sink
        t.span("x", "exec", 0, 0, &[]);
        t.count("c", 1);
    }

    #[test]
    fn recording_tracer_captures_spans_and_counters() {
        let (t, sink) = Tracer::recording();
        assert!(t.is_enabled());
        let t0 = t.start().expect("enabled");
        t.span("task:work", "exec", 1, t0, &[("queue_wait_ms", 0.5)]);
        t.count("exec.worker0.parks", 3);
        t.count("exec.worker0.parks", 2);
        let spans = sink.spans();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "task:work");
        assert_eq!(spans[0].tid, 1);
        assert_eq!(spans[0].args, vec![("queue_wait_ms", 0.5)]);
        assert_eq!(sink.counter("exec.worker0.parks"), 5);
        assert_eq!(sink.span_count(), 1);
    }

    #[test]
    fn set_sink_toggles_enabled() {
        let t = Tracer::disabled();
        let sink = Arc::new(MemorySink::default());
        t.set_sink(Some(sink.clone() as Arc<dyn TraceSink>));
        assert!(t.is_enabled());
        let t0 = t.start().unwrap();
        t.span("s", "engine", 0, t0, &[]);
        assert_eq!(sink.span_count(), 1);
        t.set_sink(None);
        assert!(!t.is_enabled());
    }

    #[test]
    fn normalize_collapses_digit_runs() {
        assert_eq!(normalize("sgd-round-17"), "sgd-round-#");
        assert_eq!(normalize("eval:dataset-3"), "eval:dataset-#");
        assert_eq!(normalize("plain"), "plain");
        assert_eq!(normalize("a1b22c"), "a#b#c");
    }

    #[test]
    fn summary_mentions_spans_and_counters() {
        let (t, sink) = Tracer::recording();
        for i in 0..3 {
            let t0 = t.start().unwrap();
            t.span(format!("sgd-round-{i}"), "optim", 0, t0, &[]);
        }
        t.count("sim.micros", 2_000_000);
        t.count("wall.micros", 1_000_000);
        let s = sink.summary();
        assert!(s.contains("sgd-round-#"), "{s}");
        assert!(s.contains("sim.micros"), "{s}");
        assert!(s.contains("simulated 2.000s"), "{s}");
    }

    #[test]
    fn chrome_export_is_valid_json_with_events() {
        let (t, sink) = Tracer::recording();
        let t0 = t.start().unwrap();
        t.span("task:epoch", "exec", 2, t0, &[("queue_wait_ms", 1.25)]);
        t.count("exec.worker1.steals", 4);
        let text = sink.chrome_json().to_string();
        let parsed = crate::util::json::Json::parse(&text).expect("valid JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // metadata rows (driver + workers 0..=1) + 1 span + 1 counter
        assert!(events.len() >= 3, "got {} events", events.len());
        let span = events
            .iter()
            .find(|e| {
                e.get("name").and_then(|n| n.as_str().map(str::to_string)).ok()
                    == Some("task:epoch".to_string())
            })
            .expect("span present");
        assert_eq!(span.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(span.get("tid").unwrap().as_usize().unwrap(), 2);
        let counter = events
            .iter()
            .find(|e| e.get("ph").map(|p| p == &crate::util::json::Json::from("C")).unwrap_or(false))
            .expect("counter present");
        assert_eq!(
            counter.get("name").unwrap().as_str().unwrap(),
            "exec.worker1.steals"
        );
    }

    #[test]
    fn write_chrome_creates_file() {
        let (t, sink) = Tracer::recording();
        let t0 = t.start().unwrap();
        t.span("stage:test", "exec", 0, t0, &[]);
        let path = std::env::temp_dir().join("mli_trace_unit.json");
        let path_s = path.to_string_lossy().to_string();
        sink.write_chrome(&path_s).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::util::json::Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(&path);
    }
}
