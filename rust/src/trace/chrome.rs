//! Chrome trace-event exporter.
//!
//! Emits the Trace Event Format consumed by `chrome://tracing` and
//! ui.perfetto.dev: one JSON object with a `traceEvents` array holding
//! "M" thread-name metadata, "X" complete spans (`ts`/`dur` in
//! microseconds) and "C" counter samples. Everything is built on the
//! in-tree [`crate::util::json::Json`] writer — no external deps.

use std::collections::BTreeMap;

use super::SpanEvent;
use crate::util::json::Json;

const PID: usize = 1;

/// Build the full Chrome-trace document from collected spans + counters.
pub fn to_json(spans: &[SpanEvent], counters: &BTreeMap<String, u64>) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(spans.len() + counters.len() + 4);

    // Thread-name metadata for every tid that appears (tid 0 is the
    // driver; worker i reports as tid i + 1).
    let max_tid = spans.iter().map(|s| s.tid).max().unwrap_or(0);
    for tid in 0..=max_tid {
        let label = if tid == 0 {
            "driver".to_string()
        } else {
            format!("worker-{}", tid - 1)
        };
        events.push(Json::obj(vec![
            ("ph", Json::from("M")),
            ("name", Json::from("thread_name")),
            ("pid", Json::from(PID)),
            ("tid", Json::from(tid as usize)),
            ("args", Json::obj(vec![("name", Json::Str(label))])),
        ]));
    }

    let mut end_ts_us = 0.0f64;
    for s in spans {
        let ts = s.start_ns as f64 / 1e3;
        let dur = s.dur_ns as f64 / 1e3;
        end_ts_us = end_ts_us.max(ts + dur);
        let args: BTreeMap<String, Json> = s
            .args
            .iter()
            .map(|(k, v)| (k.to_string(), Json::Num(*v)))
            .collect();
        events.push(Json::obj(vec![
            ("ph", Json::from("X")),
            ("name", Json::Str(s.name.clone())),
            ("cat", Json::from(s.cat)),
            ("pid", Json::from(PID)),
            ("tid", Json::from(s.tid as usize)),
            ("ts", Json::Num(ts)),
            ("dur", Json::Num(dur)),
            ("args", Json::Obj(args)),
        ]));
    }

    // Counters are totals, sampled once at the end of the trace so the
    // counter track shows the final value.
    for (name, value) in counters {
        events.push(Json::obj(vec![
            ("ph", Json::from("C")),
            ("name", Json::Str(name.clone())),
            ("pid", Json::from(PID)),
            ("tid", Json::from(0usize)),
            ("ts", Json::Num(end_ts_us)),
            ("args", Json::obj(vec![("value", Json::from(*value as usize))])),
        ]));
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::from("ms")),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_trace_is_still_valid() {
        let doc = to_json(&[], &BTreeMap::new());
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // just the driver thread-name metadata row
        assert_eq!(events.len(), 1);
        assert_eq!(
            parsed.get("displayTimeUnit").unwrap().as_str().unwrap(),
            "ms"
        );
    }

    #[test]
    fn span_units_are_microseconds() {
        let spans = vec![SpanEvent {
            name: "task:t".into(),
            cat: "exec",
            tid: 1,
            start_ns: 2_000,
            dur_ns: 3_000,
            args: vec![("queue_wait_ms", 0.25)],
        }];
        let doc = to_json(&spans, &BTreeMap::new());
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let span = events
            .iter()
            .find(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
            .unwrap();
        assert_eq!(span.get("ts").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(span.get("dur").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(
            span.get("args")
                .unwrap()
                .get("queue_wait_ms")
                .unwrap()
                .as_f64()
                .unwrap(),
            0.25
        );
    }

    #[test]
    fn counters_become_counter_events() {
        let mut counters = BTreeMap::new();
        counters.insert("exec.worker0.steals".to_string(), 7u64);
        let doc = to_json(&[], &counters);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        let c = events
            .iter()
            .find(|e| e.get("ph").unwrap().as_str().unwrap() == "C")
            .unwrap();
        assert_eq!(
            c.get("name").unwrap().as_str().unwrap(),
            "exec.worker0.steals"
        );
        assert_eq!(
            c.get("args").unwrap().get("value").unwrap().as_usize().unwrap(),
            7
        );
    }
}
