//! # MLI: An API for Distributed Machine Learning
//!
//! Rust + JAX + Pallas reproduction of *MLI: An API for Distributed Machine
//! Learning* (Sparks et al., 2013). The crate provides the paper's API
//! surface — [`mltable::MLTable`], [`localmatrix::LocalMatrix`], and the
//! [`optim::Optimizer`] / [`algorithms::Algorithm`] / [`algorithms::Model`]
//! interfaces — on top of an in-process Spark-surrogate dataflow engine
//! ([`engine`]) scheduled onto a simulated cluster ([`cluster`]) with an
//! analytic network cost model.
//!
//! The numeric hot paths (the paper's `localSGD` and `localALS` inner
//! loops) execute as AOT-compiled XLA programs: JAX/Pallas kernels are
//! lowered to HLO text at build time (`make artifacts`) and loaded/run by
//! [`runtime`] through the PJRT CPU client. Python never runs on the
//! training path.
//!
//! Beneath the engine sits [`exec`], a multi-threaded work-stealing task
//! executor. Attaching a pool (`SimCluster::with_executor` /
//! `EngineContext::with_executor`, or `--threads` on the CLI) makes
//! per-partition stages — dataset actions, SGD/GD epochs, ALS solves,
//! k-means assignment — evaluate concurrently on host threads. Two clocks
//! are in play: the executor shrinks *real* wall-clock time, while the
//! *simulated* cluster time charged by [`cluster::SimCluster`]'s analytic
//! ledger is unaffected by host thread count. Results are bitwise
//! identical for any thread count: workers compute per-partition pieces
//! in parallel, but every merge/fold happens on the calling thread in
//! partition-index order.
//!
//! Layout mirrors DESIGN.md §4; every paper table/figure has a bench in
//! `rust/benches/` (DESIGN.md §5).

pub mod algorithms;
pub mod baselines;
pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod data;
pub mod engine;
pub mod error;
pub mod exec;
pub mod features;
pub mod lint;
pub mod localmatrix;
pub mod metrics;
pub mod mltable;
pub mod optim;
pub mod runtime;
pub mod trace;
pub mod util;
pub mod xla;


pub use error::{Error, Result};

/// Convenience re-exports for application code (`use mli::prelude::*`).
pub mod prelude {
    pub use crate::algorithms::{
        Algorithm, AlsParams, KMeansParams, LinearRegression, LinearSVM,
        LogisticRegression, Model, ALS, KMeans,
    };
    pub use crate::cluster::{
        CommTopology, FaultKind, FaultPlan, NetChaosConfig, NetFaultKind, NetFaultPlan,
        NetStats, PartitionPolicy, SimCluster,
    };
    pub use crate::engine::{EngineContext, RetryPolicy};
    pub use crate::error::{Error, Result};
    pub use crate::exec::{TaskSet, ThreadPool};
    pub use crate::features::{ngrams, standard_scale, tfidf};
    pub use crate::localmatrix::{CsrMatrix, DenseMatrix, LocalMatrix, MLVector};
    pub use crate::mltable::{
        csv_from_file, csv_from_str, text_from_file, text_from_str, MLNumericTable, MLRow,
        MLTable, Schema, Value,
    };
    pub use crate::optim::{GdParams, Reg, SgdParams};
    pub use crate::runtime::{Runtime, Tensor};
    pub use crate::trace::{MemorySink, TraceSink, Tracer};
}

/// Print the trace summary table and, when `out` is given, write the
/// Chrome-trace JSON (open in `chrome://tracing` or ui.perfetto.dev).
fn finish_trace(sink: &trace::MemorySink, out: Option<&str>) -> Result<()> {
    print!("{}", sink.summary());
    if let Some(path) = out {
        sink.write_chrome(path)?;
        println!("chrome trace written to {path}");
    }
    Ok(())
}

/// Options shared by the `mli chaos` workloads.
struct ChaosOpts {
    machines: usize,
    iters: usize,
    seed: u64,
    kill_rate: f64,
    restart_after: usize,
    threads: usize,
    tolerance: f64,
    spec_k: f64,
}

/// Per-run observations from a chaos workload.
struct ChaosRun {
    weights: localmatrix::MLVector,
    final_loss: f64,
    losses: usize,
    recoveries: u64,
    checkpoint_reads: u64,
    kills: u64,
    restarts: u64,
    sim_s: f64,
}

/// `mli chaos --algo logreg`: train twice — a failure-free baseline, then
/// under a seeded random kill schedule with the cached input bound to the
/// cluster and checkpointed to the simulated HDFS — and require the
/// recovered run to match the baseline bitwise on weights and within
/// `tolerance` on final loss.
fn chaos_logreg(o: &ChaosOpts) -> Result<()> {
    use algorithms::logreg::{Backend, LogRegParams};
    use algorithms::{Algorithm, LogisticRegression};
    use std::sync::Arc;

    let n = 2048;
    let d = 32;
    let run = |plan: Option<Arc<cluster::FaultPlan>>| -> Result<ChaosRun> {
        let ctx = engine::EngineContext::new();
        let data = data::dense_gen::generate(&ctx, n, d, o.machines, o.seed)?;
        let table = data.table.cache();
        let mut c = cluster::SimCluster::ec2(o.machines);
        if o.threads > 0 {
            c = c.with_executor(o.threads);
        }
        if o.spec_k > 1.0 {
            c = c.with_speculation(o.spec_k);
        }
        if let Some(p) = plan {
            c = c.with_faults(p);
        }
        // wire machine loss into the cached input and checkpoint it: kills
        // drop the dead machine's resident partitions, and recovery reads
        // the HDFS snapshot instead of replaying lineage
        table.dataset().bind_cluster(&c);
        table.dataset().checkpoint(&c)?;
        let algo = LogisticRegression::new(LogRegParams {
            sgd: optim::SgdParams {
                iters: o.iters,
                track_loss: true,
                ..Default::default()
            },
            backend: Backend::Rust,
        });
        let model = algo.train(&table, &c)?;
        // force a post-train pass over the (possibly damaged) table so
        // recovery actually runs under this kill schedule
        let rows = table.num_rows()?;
        if rows != n {
            return Err(Error::FaultRecovery(format!(
                "chaos logreg: table recovered to {rows} rows, expected {n}"
            )));
        }
        let (kills, restarts) = c.fault_stats();
        Ok(ChaosRun {
            weights: model.weights.clone(),
            final_loss: model.loss_history.last().copied().unwrap_or(f64::NAN),
            losses: ctx.failures.losses(),
            recoveries: ctx.stats().2,
            checkpoint_reads: ctx.checkpoint_hits(),
            kills,
            restarts,
            sim_s: c.total_sim_seconds(),
        })
    };

    let base = run(None)?;
    let plan = Arc::new(cluster::FaultPlan::random(
        o.seed,
        o.machines,
        o.iters + 2,
        o.kill_rate,
        o.restart_after,
    ));
    let scheduled = plan.remaining();
    let faulted = run(Some(plan))?;
    println!(
        "chaos logreg: machines={} iters={} seed={} kill-rate={} ({scheduled} kills scheduled)",
        o.machines, o.iters, o.seed, o.kill_rate
    );
    println!(
        "  faulted run: {} kills, {} restarts, {} partitions lost, {} recoveries, \
         {} checkpoint reads, sim {:.3}s (baseline {:.3}s)",
        faulted.kills,
        faulted.restarts,
        faulted.losses,
        faulted.recoveries,
        faulted.checkpoint_reads,
        faulted.sim_s,
        base.sim_s
    );
    if faulted.weights != base.weights {
        return Err(Error::FaultRecovery(
            "chaos logreg: weights diverged from failure-free baseline".into(),
        ));
    }
    let drift = (faulted.final_loss - base.final_loss).abs();
    if !(drift <= o.tolerance) {
        return Err(Error::FaultRecovery(format!(
            "chaos logreg: final loss drifted by {drift:.6} (tolerance {})",
            o.tolerance
        )));
    }
    println!(
        "  OK: weights bitwise-identical to baseline; loss drift {drift:.2e} <= {}",
        o.tolerance
    );
    Ok(())
}

/// `mli chaos --algo als`: same discipline for ALS on synthetic ratings —
/// machine kills shift placement and sim-time charging, and the final RMSE
/// must stay within `tolerance` of the failure-free baseline.
fn chaos_als(o: &ChaosOpts) -> Result<()> {
    use std::sync::Arc;

    let run = |plan: Option<Arc<cluster::FaultPlan>>| -> Result<(f64, f64, u64, u64)> {
        let data = data::netflix::generate(&data::netflix::NetflixConfig {
            users: 256,
            items: 64,
            seed: o.seed,
            ..Default::default()
        });
        let mut c = cluster::SimCluster::ec2(o.machines);
        if o.threads > 0 {
            c = c.with_executor(o.threads);
        }
        if o.spec_k > 1.0 {
            c = c.with_speculation(o.spec_k);
        }
        if let Some(p) = plan {
            c = c.with_faults(p);
        }
        let model = algorithms::ALS::new(algorithms::AlsParams {
            rank: 8,
            iters: o.iters,
            lambda: 0.01,
            track_rmse: true,
            use_xla: false,
            ..Default::default()
        })
        .train_ratings(&data, &c)?;
        let rmse = model.rmse_history.last().copied().unwrap_or(f64::NAN);
        let (kills, restarts) = c.fault_stats();
        Ok((rmse, c.total_sim_seconds(), kills, restarts))
    };

    let (base_rmse, base_sim, _, _) = run(None)?;
    let plan = Arc::new(cluster::FaultPlan::random(
        o.seed,
        o.machines,
        o.iters + 2,
        o.kill_rate,
        o.restart_after,
    ));
    let scheduled = plan.remaining();
    let (rmse, sim_s, kills, restarts) = run(Some(plan))?;
    println!(
        "chaos als: machines={} iters={} seed={} kill-rate={} ({scheduled} kills scheduled)",
        o.machines, o.iters, o.seed, o.kill_rate
    );
    println!(
        "  faulted run: {kills} kills, {restarts} restarts, rmse {rmse:.6} \
         (baseline {base_rmse:.6}), sim {sim_s:.3}s (baseline {base_sim:.3}s)"
    );
    let drift = (rmse - base_rmse).abs();
    if !(drift <= o.tolerance) {
        return Err(Error::FaultRecovery(format!(
            "chaos als: rmse drifted by {drift:.6} (tolerance {})",
            o.tolerance
        )));
    }
    println!("  OK: rmse within tolerance under failures");
    Ok(())
}

/// Extra knobs for `mli chaos --net`.
struct NetChaosOpts {
    drop_rate: f64,
    dup_rate: f64,
    degrade: f64,
    partition_rounds: usize,
    policy: cluster::PartitionPolicy,
    trace_out: Option<String>,
}

/// `mli chaos --net`: train logreg twice — a failure-free baseline, then
/// under a seeded network fault schedule (lossy links, duplicate
/// deliveries, degraded links, one partition window) — and require the
/// faulted run to produce bitwise-identical weights. Network faults are
/// allowed to move only simulated time and fault counters, never values;
/// the run fails typed if they don't, or if the schedule turned out to be
/// a no-op (no drops/retries/partition activity observed).
fn chaos_net(o: &ChaosOpts, net: &NetChaosOpts) -> Result<()> {
    use algorithms::logreg::{Backend, LogRegParams};
    use algorithms::{Algorithm, LogisticRegression};
    use std::sync::Arc;

    let n = 2048;
    let d = 32;
    let run = |plan: Option<Arc<cluster::NetFaultPlan>>,
               tracer: Option<Arc<trace::Tracer>>|
     -> Result<(localmatrix::MLVector, f64, cluster::NetStats)> {
        let ctx = engine::EngineContext::new();
        let data = data::dense_gen::generate(&ctx, n, d, o.machines, o.seed)?;
        let mut c = cluster::SimCluster::ec2(o.machines).with_partition_policy(net.policy);
        if o.threads > 0 {
            c = c.with_executor(o.threads);
        }
        if let Some(p) = plan {
            c = c.with_netfaults(p);
        }
        if let Some(t) = tracer {
            c.set_tracer(t);
        }
        let algo = LogisticRegression::new(LogRegParams {
            sgd: optim::SgdParams {
                iters: o.iters,
                track_loss: true,
                ..Default::default()
            },
            backend: Backend::Rust,
        });
        let model = algo.train(&data.table, &c)?;
        Ok((model.weights.clone(), c.total_sim_seconds(), c.net_stats()))
    };

    let (base_w, base_sim, _) = run(None, None)?;
    let cfg = cluster::NetChaosConfig {
        drop_prob: net.drop_rate,
        dup_prob: net.dup_rate,
        degrade_windows: net.degrade,
        partition_rounds: net.partition_rounds,
        ..Default::default()
    };
    let plan = cluster::NetFaultPlan::random(o.seed, o.machines, o.iters + 2, &cfg);
    // pin one drop window at round 1 so "nonzero drops" never depends on
    // the seed lottery; like every window it moves time, not values
    if net.drop_rate > 0.0 {
        plan.window(1, 1, cluster::NetFaultKind::Drop { machine: None, prob: net.drop_rate });
    }
    let scheduled = plan.remaining();
    let (tracer, sink) = if net.trace_out.is_some() {
        let (t, s) = trace::Tracer::recording();
        (Some(t), Some(s))
    } else {
        (None, None)
    };
    let (w, sim_s, stats) = run(Some(Arc::new(plan)), tracer)?;
    println!(
        "chaos net: machines={} iters={} seed={} drop-rate={} dup-rate={} \
         partition-rounds={} policy={:?} ({scheduled} windows scheduled)",
        o.machines, o.iters, o.seed, net.drop_rate, net.dup_rate, net.partition_rounds,
        net.policy
    );
    println!(
        "  faulted run: {} sends, {} drops, {} retries, {} dup deliveries, \
         {} partition waits, {} replacements, sim {sim_s:.3}s (baseline {base_sim:.3}s)",
        stats.sends, stats.drops, stats.retries, stats.dups, stats.partition_waits,
        stats.replacements
    );
    if w != base_w {
        return Err(Error::NetFault(
            "chaos net: weights diverged from failure-free baseline".into(),
        ));
    }
    if net.drop_rate > 0.0 && (stats.drops == 0 || stats.retries == 0) {
        return Err(Error::NetFault(format!(
            "chaos net: schedule was a no-op ({} drops, {} retries observed)",
            stats.drops, stats.retries
        )));
    }
    if net.partition_rounds > 0 && stats.partition_waits + stats.replacements == 0 {
        return Err(Error::NetFault(
            "chaos net: partition window produced no waits or replacements".into(),
        ));
    }
    println!(
        "  OK: weights bitwise-identical to baseline; faults moved time only \
         (+{:.3}s sim)",
        sim_s - base_sim
    );
    if let Some(s) = &sink {
        finish_trace(s, net.trace_out.as_deref())?;
    }
    Ok(())
}

/// CLI entry point shared by `rust/src/main.rs` (kept here so integration
/// tests can drive the launcher without spawning a process).
pub fn run_cli(args: util::cli::Args) -> Result<()> {
    use algorithms::logreg::Backend;
    use bench_harness::{
        als_scaling_with, logreg_scaling_with, AlsBenchConfig, LogregBenchConfig, ScalingMode,
    };

    // optional config file + --section.key overrides
    let cfg = match args.get("config") {
        Some(path) => config::Config::from_file(path)?.with_overrides(&args),
        None => config::Config::empty().with_overrides(&args),
    };

    match args.subcommand.as_deref() {
        Some("selftest") => {
            // Smoke-check the AOT runtime: compile + run one small artifact.
            let rt = runtime::Runtime::new(runtime::Runtime::artifact_dir())?;
            let n = 256;
            let d = 64;
            let x = runtime::Tensor::F32(vec![0.0; n * d], vec![n, d]);
            let y = runtime::Tensor::F32(vec![0.0; n], vec![n]);
            let w = runtime::Tensor::F32(vec![0.0; d], vec![d]);
            let lr = runtime::Tensor::Scalar(0.1);
            let out = rt.execute("local_sgd_epoch", "small", &[x, y, w, lr])?;
            println!(
                "selftest OK: local_sgd_epoch(small) -> {} outputs, first len {}",
                out.len(),
                out[0].len()
            );
            Ok(())
        }
        Some("train") => {
            // mli train --algo logreg|als --machines M --iters N [--threads T]
            //           [--trace-out trace.json]
            let machines = args.get_usize("machines", 4)?;
            let iters = args.get_usize("iters", 10)?;
            let use_xla = !args.has_flag("no-xla");
            // --threads T attaches the exec pool (T=0 or bare --threads:
            // fleet-capped default); omitting it keeps evaluation serial
            let threads = if args.has_flag("threads") {
                Some(0)
            } else {
                args.get("threads").map(|_| args.get_usize("threads", 0)).transpose()?
            };
            let trace_out = args.get("trace-out");
            let (tracer, sink) = if trace_out.is_some() {
                let (t, s) = trace::Tracer::recording();
                (Some(t), Some(s))
            } else {
                (None, None)
            };
            let make_cluster = |m: usize| {
                let mut c = cluster::SimCluster::ec2(m);
                if let Some(t) = threads {
                    c = c.with_executor(t);
                }
                if let Some(tr) = &tracer {
                    c.set_tracer(tr.clone());
                }
                c
            };
            match args.get_str("algo", "logreg").as_str() {
                "logreg" => {
                    let ctx = engine::EngineContext::new();
                    let n = args.get_usize("n", 2048)?;
                    let d = args.get_usize("d", 64)?;
                    let data = data::dense_gen::generate(&ctx, n, d, machines, 1)?;
                    let cluster = make_cluster(machines);
                    let algo = algorithms::LogisticRegression::new(
                        algorithms::logreg::LogRegParams {
                            sgd: optim::SgdParams {
                                iters,
                                learning_rate: args.get_f64("lr", 0.02)?,
                                track_loss: true,
                                ..Default::default()
                            },
                            backend: if use_xla { Backend::Xla } else { Backend::Rust },
                        },
                    );
                    use algorithms::Algorithm;
                    let model = algo.train(&data.table, &cluster)?;
                    println!("loss history: {:?}", model.loss_history);
                    println!("sim walltime: {:.3}s", model.sim_seconds);
                    let (tasks, _, recoveries) = ctx.stats();
                    println!(
                        "failures: {} partitions lost, {recoveries} lineage recoveries, \
                         {} checkpoint reads ({tasks} tasks run)",
                        ctx.failures.losses(),
                        ctx.checkpoint_hits()
                    );
                    let (kills, restarts) = cluster.fault_stats();
                    println!("node faults: {kills} kills, {restarts} restarts");
                    let ns = cluster.net_stats();
                    println!(
                        "net faults: {} drops, {} retries, {} dups, {} partition waits \
                         ({} fault-path sends)",
                        ns.drops, ns.retries, ns.dups, ns.partition_waits, ns.sends
                    );
                    if let (Some(s), Some(p)) = (&sink, cluster.pool()) {
                        p.export_trace(s.as_ref());
                    }
                }
                "als" => {
                    let data = data::netflix::generate(&data::netflix::NetflixConfig {
                        users: args.get_usize("users", 512)?,
                        items: args.get_usize("items", 96)?,
                        ..Default::default()
                    });
                    let cluster = make_cluster(machines);
                    let model = algorithms::ALS::new(algorithms::AlsParams {
                        rank: args.get_usize("rank", 10)?,
                        iters,
                        lambda: args.get_f64("lambda", 0.01)?,
                        use_xla,
                        track_rmse: true,
                        ..Default::default()
                    })
                    .train_ratings(&data, &cluster)?;
                    println!("rmse history: {:?}", model.rmse_history);
                    println!("sim walltime: {:.3}s", cluster.total_sim_seconds());
                    let (kills, restarts) = cluster.fault_stats();
                    println!("node faults: {kills} kills, {restarts} restarts");
                    let ns = cluster.net_stats();
                    println!(
                        "net faults: {} drops, {} retries, {} dups, {} partition waits \
                         ({} fault-path sends)",
                        ns.drops, ns.retries, ns.dups, ns.partition_waits, ns.sends
                    );
                    if let (Some(s), Some(p)) = (&sink, cluster.pool()) {
                        p.export_trace(s.as_ref());
                    }
                }
                other => return Err(Error::Config(format!("unknown --algo '{other}'"))),
            }
            if let Some(s) = &sink {
                finish_trace(s, trace_out)?;
            }
            Ok(())
        }
        Some("bench") => {
            // mli bench --figure fig2|figA5|fig3|figA7 [--machines 1,2,4]
            //           [--trace-out trace.json]
            let machines = args.get_usize_list("machines", &[1, 2, 4])?;
            let iters = cfg.get_usize("bench", "iters", 5)?;
            let trace_out = args.get("trace-out");
            let (tracer, sink) = if trace_out.is_some() {
                let (t, s) = trace::Tracer::recording();
                (Some(t), Some(s))
            } else {
                (None, None)
            };
            match args.get_str("figure", "fig2").as_str() {
                "fig2" | "figA5" => {
                    let mode = if args.get_str("figure", "fig2") == "fig2" {
                        ScalingMode::Weak
                    } else {
                        ScalingMode::Strong
                    };
                    let c = LogregBenchConfig {
                        machines,
                        rows: args.get_usize("rows", 512)?,
                        d: args.get_usize("d", 64)?,
                        iters,
                        backend: Backend::Xla,
                        seed: 42,
                        reps: 1,
                        threads: args.get_usize("threads", 0)?,
                    };
                    println!("{}", logreg_scaling_with(&c, mode, tracer.as_ref())?.to_markdown());
                }
                "fig3" | "figA7" => {
                    let mode = if args.get_str("figure", "fig3") == "fig3" {
                        ScalingMode::Weak
                    } else {
                        ScalingMode::Strong
                    };
                    let c = AlsBenchConfig {
                        machines,
                        iters,
                        threads: args.get_usize("threads", 0)?,
                        ..Default::default()
                    };
                    println!("{}", als_scaling_with(&c, mode, tracer.as_ref())?.to_markdown());
                }
                other => return Err(Error::Config(format!("unknown --figure '{other}'"))),
            }
            if let Some(s) = &sink {
                finish_trace(s, trace_out)?;
            }
            Ok(())
        }
        Some("exec-bench") => {
            // mli exec-bench [--threads 1,2,4,8] [--partitions P] [--n N] [--d D]
            //
            // Thread-scaling table for the exec pool: trains the same logreg
            // workload (Rust backend — no AOT artifacts needed) at each host
            // thread count and reports real wall-clock, speedup over 1 thread,
            // and the pool's task/steal counters. Results are checked to be
            // bitwise identical across thread counts; simulated cluster time
            // is thread-independent by construction.
            let thread_counts = args.get_usize_list("threads", &[1, 2, 4, 8])?;
            let parts = args.get_usize("partitions", 8)?;
            let n = args.get_usize("n", 8192)?;
            let d = args.get_usize("d", 64)?;
            let iters = args.get_usize("iters", 10)?;
            let trace_out = args.get("trace-out");
            let (tracer, sink) = if trace_out.is_some() {
                let (t, s) = trace::Tracer::recording();
                (Some(t), Some(s))
            } else {
                (None, None)
            };
            let mut table = metrics::Table::new(
                "exec thread scaling (logreg, Rust backend)",
                &["threads", "wall_ms", "speedup", "tasks", "steals", "sim_s"],
            );
            let mut base_wall: Option<f64> = None;
            let mut base_weights: Option<localmatrix::MLVector> = None;
            for &t in &thread_counts {
                let ctx = engine::EngineContext::new();
                let data = data::dense_gen::generate(&ctx, n, d, parts, 7)?;
                let cluster = cluster::SimCluster::ec2(parts).with_executor(t.max(1));
                if let Some(tr) = &tracer {
                    cluster.set_tracer(tr.clone());
                }
                let algo = algorithms::LogisticRegression::new(
                    algorithms::logreg::LogRegParams {
                        sgd: optim::SgdParams { iters, ..Default::default() },
                        backend: Backend::Rust,
                    },
                );
                use algorithms::Algorithm;
                let start = std::time::Instant::now();
                let model = algo.train(&data.table, &cluster)?;
                let wall_ms = start.elapsed().as_secs_f64() * 1e3;
                match &base_weights {
                    None => base_weights = Some(model.weights.clone()),
                    Some(b) => {
                        if b != &model.weights {
                            return Err(Error::Engine(format!(
                                "exec-bench: weights diverged at {t} threads \
                                 (determinism contract violated)"
                            )));
                        }
                    }
                }
                let (tasks, steals) = cluster
                    .pool()
                    .map(|p| {
                        if let Some(s) = &sink {
                            p.export_trace(s.as_ref());
                        }
                        let s = p.worker_stats();
                        (
                            s.iter().map(|w| w.tasks).sum::<u64>(),
                            s.iter().map(|w| w.steals).sum::<u64>(),
                        )
                    })
                    .unwrap_or((0, 0));
                let base = *base_wall.get_or_insert(wall_ms);
                table.row(vec![
                    t.to_string(),
                    format!("{wall_ms:.1}"),
                    format!("{:.2}x", base / wall_ms),
                    tasks.to_string(),
                    steals.to_string(),
                    format!("{:.3}", cluster.total_sim_seconds()),
                ]);
            }
            println!("{}", table.to_markdown());
            println!("(results bitwise-identical across all thread counts)");
            if let Some(s) = &sink {
                finish_trace(s, trace_out)?;
            }
            Ok(())
        }
        Some("trace") => {
            // mli trace [--threads T] [--partitions P] [--iters N] [--n N]
            //           [--d D] [--out trace.json]
            //
            // Small traced logreg run (Rust backend): prints the span/counter
            // summary and the simulated-vs-wall clock attribution; --out
            // writes the Chrome-trace JSON for chrome://tracing / perfetto.
            let threads = args.get_usize("threads", 2)?;
            let parts = args.get_usize("partitions", 8)?;
            let iters = args.get_usize("iters", 6)?;
            let n = args.get_usize("n", 4096)?;
            let d = args.get_usize("d", 32)?;
            let (tracer, sink) = trace::Tracer::recording();
            let ctx = engine::EngineContext::new();
            let data = data::dense_gen::generate(&ctx, n, d, parts, 7)?;
            let cluster = cluster::SimCluster::ec2(parts).with_executor(threads.max(1));
            cluster.set_tracer(tracer.clone());
            let algo = algorithms::LogisticRegression::new(algorithms::logreg::LogRegParams {
                sgd: optim::SgdParams {
                    iters,
                    track_loss: true,
                    ..Default::default()
                },
                backend: Backend::Rust,
            });
            use algorithms::Algorithm;
            let model = algo.train(&data.table, &cluster)?;
            println!(
                "traced logreg: {n}x{d}, {parts} partitions, {iters} iters, \
                 {threads} threads; final loss {:.6}",
                model.loss_history.last().copied().unwrap_or(f64::NAN)
            );
            if let Some(p) = cluster.pool() {
                p.export_trace(sink.as_ref());
            }
            finish_trace(&sink, args.get("out"))?;
            Ok(())
        }
        Some("chaos") => {
            // mli chaos [--algo logreg|als|both] [--machines 8] [--iters 8]
            //           [--seed 7] [--kill-rate 0.1] [--restart-after 2]
            //           [--threads T] [--tolerance 0.2] [--spec-k K]
            //
            // Seeded random kill schedule: trains each workload twice (a
            // failure-free baseline, then under machine kills) and fails
            // with a typed error unless the recovered run matches the
            // baseline — bitwise weights for logreg, rmse-within-tolerance
            // for ALS. `--restart-after 0` makes every kill permanent.
            let o = ChaosOpts {
                machines: args.get_usize("machines", 8)?,
                iters: args.get_usize("iters", 8)?,
                seed: args.get_usize("seed", 7)? as u64,
                kill_rate: args.get_f64("kill-rate", 0.1)?,
                restart_after: args.get_usize("restart-after", 2)?,
                threads: args.get_usize("threads", 0)?,
                tolerance: args.get_f64("tolerance", 0.2)?,
                spec_k: args.get_f64("spec-k", 0.0)?,
            };
            if args.has_flag("net") {
                // mli chaos --net [--drop-rate 0.25] [--dup-rate 0.2]
                //     [--degrade 0.3] [--partition-rounds 2]
                //     [--partition-policy wait|replace] [--trace-out F]
                //
                // Network fault schedule instead of machine kills: lossy
                // links retry, partitions wait out (or re-place), and the
                // trained weights must stay bitwise-identical to the
                // failure-free baseline.
                let policy = match args.get_str("partition-policy", "wait").as_str() {
                    "wait" => cluster::PartitionPolicy::WaitOut,
                    "replace" => cluster::PartitionPolicy::Replace,
                    other => {
                        return Err(Error::Config(format!(
                            "unknown --partition-policy '{other}' (wait|replace)"
                        )))
                    }
                };
                let net = NetChaosOpts {
                    drop_rate: args.get_f64("drop-rate", 0.25)?,
                    dup_rate: args.get_f64("dup-rate", 0.2)?,
                    degrade: args.get_f64("degrade", 0.3)?,
                    partition_rounds: args.get_usize("partition-rounds", 2)?,
                    policy,
                    trace_out: args.get("trace-out").map(String::from),
                };
                return chaos_net(&o, &net);
            }
            match args.get_str("algo", "logreg").as_str() {
                "logreg" => chaos_logreg(&o),
                "als" => chaos_als(&o),
                "both" => {
                    chaos_logreg(&o)?;
                    chaos_als(&o)
                }
                other => Err(Error::Config(format!("unknown --algo '{other}'"))),
            }
        }
        Some("loc") => {
            println!("{}", bench_harness::loc::fig2a().to_markdown());
            println!("{}", bench_harness::loc::fig3a().to_markdown());
            Ok(())
        }
        Some("lint") => {
            // mli lint [--root DIR] [--rule D001,C001,...] [--json [FILE]]
            //          [--deny] [--list-rules]
            if args.has_flag("list-rules") {
                for rule in lint::rules::ALL_RULES {
                    println!("{rule}  {}", lint::rules::rule_summary(rule));
                }
                return Ok(());
            }
            let rules: Vec<String> = match args.get("rule") {
                Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
                None => Vec::new(),
            };
            for r in &rules {
                if !lint::rules::ALL_RULES.contains(&r.as_str()) {
                    return Err(Error::Config(format!(
                        "unknown lint rule '{r}' (try `mli lint --list-rules`)"
                    )));
                }
            }
            let cfg = lint::LintConfig {
                root: args.get_str("root", ".").into(),
                rules,
            };
            let report = lint::run(&cfg)?;
            if let Some(path) = args.get("json") {
                // CI artifact: JSON to the file, human summary to stdout
                std::fs::write(path, format!("{}\n", report.to_json()))?;
                print!("{}", report.to_text());
                println!("json report written to {path}");
            } else if args.has_flag("json") {
                println!("{}", report.to_json());
            } else {
                print!("{}", report.to_text());
            }
            if args.has_flag("deny") && !report.clean() {
                return Err(Error::Lint(format!(
                    "{} finding{} (see report above); annotate intentional sites \
                     with `// mli-lint: allow(<rule>) <reason>`",
                    report.diags.len(),
                    if report.diags.len() == 1 { "" } else { "s" }
                )));
            }
            Ok(())
        }
        Some("help") | None => {
            println!("mli — MLI: An API for Distributed Machine Learning (reproduction)");
            println!();
            println!("USAGE: mli <subcommand> [--options] [--config file.toml]");
            println!();
            println!("  selftest                              compile+run one AOT artifact");
            println!("  train --algo logreg|als --machines M  train on the simulated cluster");
            println!("  bench --figure fig2|figA5|fig3|figA7  regenerate a paper figure (CLI scale)");
            println!("  exec-bench [--threads 1,2,4,8]        exec pool thread-scaling table");
            println!("  trace [--out trace.json]              traced run + span/counter summary");
            println!("  chaos [--algo logreg|als|both]        seeded kill schedule; asserts the");
            println!("        [--seed 7] [--kill-rate 0.1]    recovered run matches a failure-");
            println!("        [--restart-after R] [--spec-k K] free baseline (R=0: permanent)");
            println!("  chaos --net [--drop-rate 0.25]        seeded network fault schedule");
            println!("        [--dup-rate 0.2] [--degrade 0.3] (lossy links, duplicates, degraded");
            println!("        [--partition-rounds 2]           links, one partition); asserts");
            println!("        [--partition-policy wait|replace] weights stay bitwise-identical");
            println!("        [--trace-out F]                  while faults move sim time only");
            println!("  loc                                   Fig 2a/3a lines-of-code tables");
            println!("  lint [--deny] [--rule D001,..]        determinism/concurrency invariant");
            println!("       [--json [file]] [--root DIR]     checker over rust/{{src,tests,benches}}");
            println!("       [--list-rules]                   (see docs/lint.md)");
            println!("  help                                  this message");
            println!();
            println!("  --threads T   evaluate partitions on a T-thread work-stealing pool");
            println!("                (T=0: one thread per simulated machine, host-capped;");
            println!("                affects real wall-clock only — simulated time and");
            println!("                results are identical for any T)");
            println!("                e.g. `mli train --algo logreg --machines 8 --threads 4`");
            println!("  --trace-out F record per-task/per-stage spans and exec counters during");
            println!("                train/bench/exec-bench; write Chrome-trace JSON to F");
            println!("                (open in chrome://tracing or ui.perfetto.dev)");
            println!();
            println!("full-scale figures: `cargo bench` (see rust/benches/)");
            Ok(())
        }
        Some(other) => Err(Error::Config(format!("unknown subcommand '{other}'"))),
    }
}
