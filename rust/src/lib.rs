//! # MLI: An API for Distributed Machine Learning
//!
//! Rust + JAX + Pallas reproduction of *MLI: An API for Distributed Machine
//! Learning* (Sparks et al., 2013). The crate provides the paper's API
//! surface — [`mltable::MLTable`], [`localmatrix::LocalMatrix`], and the
//! [`optim::Optimizer`] / [`algorithms::Algorithm`] / [`algorithms::Model`]
//! interfaces — on top of an in-process Spark-surrogate dataflow engine
//! ([`engine`]) scheduled onto a simulated cluster ([`cluster`]) with an
//! analytic network cost model.
//!
//! The numeric hot paths (the paper's `localSGD` and `localALS` inner
//! loops) execute as AOT-compiled XLA programs: JAX/Pallas kernels are
//! lowered to HLO text at build time (`make artifacts`) and loaded/run by
//! [`runtime`] through the PJRT CPU client. Python never runs on the
//! training path.
//!
//! Beneath the engine sits [`exec`], a multi-threaded work-stealing task
//! executor. Attaching a pool (`SimCluster::with_executor` /
//! `EngineContext::with_executor`, or `--threads` on the CLI) makes
//! per-partition stages — dataset actions, SGD/GD epochs, ALS solves,
//! k-means assignment — evaluate concurrently on host threads. Two clocks
//! are in play: the executor shrinks *real* wall-clock time, while the
//! *simulated* cluster time charged by [`cluster::SimCluster`]'s analytic
//! ledger is unaffected by host thread count. Results are bitwise
//! identical for any thread count: workers compute per-partition pieces
//! in parallel, but every merge/fold happens on the calling thread in
//! partition-index order.
//!
//! Layout mirrors DESIGN.md §4; every paper table/figure has a bench in
//! `rust/benches/` (DESIGN.md §5).

pub mod algorithms;
pub mod baselines;
pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod data;
pub mod engine;
pub mod error;
pub mod exec;
pub mod features;
pub mod localmatrix;
pub mod metrics;
pub mod mltable;
pub mod optim;
pub mod runtime;
pub mod trace;
pub mod util;
pub mod xla;


pub use error::{Error, Result};

/// Convenience re-exports for application code (`use mli::prelude::*`).
pub mod prelude {
    pub use crate::algorithms::{
        Algorithm, AlsParams, KMeansParams, LinearRegression, LinearSVM,
        LogisticRegression, Model, ALS, KMeans,
    };
    pub use crate::cluster::{CommTopology, SimCluster};
    pub use crate::engine::EngineContext;
    pub use crate::error::{Error, Result};
    pub use crate::exec::{TaskSet, ThreadPool};
    pub use crate::features::{ngrams, standard_scale, tfidf};
    pub use crate::localmatrix::{CsrMatrix, DenseMatrix, LocalMatrix, MLVector};
    pub use crate::mltable::{
        csv_from_file, csv_from_str, text_from_file, text_from_str, MLNumericTable, MLRow,
        MLTable, Schema, Value,
    };
    pub use crate::optim::{GdParams, Reg, SgdParams};
    pub use crate::runtime::{Runtime, Tensor};
    pub use crate::trace::{MemorySink, TraceSink, Tracer};
}

/// Print the trace summary table and, when `out` is given, write the
/// Chrome-trace JSON (open in `chrome://tracing` or ui.perfetto.dev).
fn finish_trace(sink: &trace::MemorySink, out: Option<&str>) -> Result<()> {
    print!("{}", sink.summary());
    if let Some(path) = out {
        sink.write_chrome(path)?;
        println!("chrome trace written to {path}");
    }
    Ok(())
}

/// CLI entry point shared by `rust/src/main.rs` (kept here so integration
/// tests can drive the launcher without spawning a process).
pub fn run_cli(args: util::cli::Args) -> Result<()> {
    use algorithms::logreg::Backend;
    use bench_harness::{
        als_scaling_with, logreg_scaling_with, AlsBenchConfig, LogregBenchConfig, ScalingMode,
    };

    // optional config file + --section.key overrides
    let cfg = match args.get("config") {
        Some(path) => config::Config::from_file(path)?.with_overrides(&args),
        None => config::Config::empty().with_overrides(&args),
    };

    match args.subcommand.as_deref() {
        Some("selftest") => {
            // Smoke-check the AOT runtime: compile + run one small artifact.
            let rt = runtime::Runtime::new(runtime::Runtime::artifact_dir())?;
            let n = 256;
            let d = 64;
            let x = runtime::Tensor::F32(vec![0.0; n * d], vec![n, d]);
            let y = runtime::Tensor::F32(vec![0.0; n], vec![n]);
            let w = runtime::Tensor::F32(vec![0.0; d], vec![d]);
            let lr = runtime::Tensor::Scalar(0.1);
            let out = rt.execute("local_sgd_epoch", "small", &[x, y, w, lr])?;
            println!(
                "selftest OK: local_sgd_epoch(small) -> {} outputs, first len {}",
                out.len(),
                out[0].len()
            );
            Ok(())
        }
        Some("train") => {
            // mli train --algo logreg|als --machines M --iters N [--threads T]
            //           [--trace-out trace.json]
            let machines = args.get_usize("machines", 4)?;
            let iters = args.get_usize("iters", 10)?;
            let use_xla = !args.has_flag("no-xla");
            // --threads T attaches the exec pool (T=0 or bare --threads:
            // fleet-capped default); omitting it keeps evaluation serial
            let threads = if args.has_flag("threads") {
                Some(0)
            } else {
                args.get("threads").map(|_| args.get_usize("threads", 0)).transpose()?
            };
            let trace_out = args.get("trace-out");
            let (tracer, sink) = if trace_out.is_some() {
                let (t, s) = trace::Tracer::recording();
                (Some(t), Some(s))
            } else {
                (None, None)
            };
            let make_cluster = |m: usize| {
                let mut c = cluster::SimCluster::ec2(m);
                if let Some(t) = threads {
                    c = c.with_executor(t);
                }
                if let Some(tr) = &tracer {
                    c.set_tracer(tr.clone());
                }
                c
            };
            match args.get_str("algo", "logreg").as_str() {
                "logreg" => {
                    let ctx = engine::EngineContext::new();
                    let n = args.get_usize("n", 2048)?;
                    let d = args.get_usize("d", 64)?;
                    let data = data::dense_gen::generate(&ctx, n, d, machines, 1)?;
                    let cluster = make_cluster(machines);
                    let algo = algorithms::LogisticRegression::new(
                        algorithms::logreg::LogRegParams {
                            sgd: optim::SgdParams {
                                iters,
                                learning_rate: args.get_f64("lr", 0.02)?,
                                track_loss: true,
                                ..Default::default()
                            },
                            backend: if use_xla { Backend::Xla } else { Backend::Rust },
                        },
                    );
                    use algorithms::Algorithm;
                    let model = algo.train(&data.table, &cluster)?;
                    println!("loss history: {:?}", model.loss_history);
                    println!("sim walltime: {:.3}s", model.sim_seconds);
                    if let (Some(s), Some(p)) = (&sink, cluster.pool()) {
                        p.export_trace(s.as_ref());
                    }
                }
                "als" => {
                    let data = data::netflix::generate(&data::netflix::NetflixConfig {
                        users: args.get_usize("users", 512)?,
                        items: args.get_usize("items", 96)?,
                        ..Default::default()
                    });
                    let cluster = make_cluster(machines);
                    let model = algorithms::ALS::new(algorithms::AlsParams {
                        rank: args.get_usize("rank", 10)?,
                        iters,
                        lambda: args.get_f64("lambda", 0.01)?,
                        use_xla,
                        track_rmse: true,
                        ..Default::default()
                    })
                    .train_ratings(&data, &cluster)?;
                    println!("rmse history: {:?}", model.rmse_history);
                    println!("sim walltime: {:.3}s", cluster.total_sim_seconds());
                    if let (Some(s), Some(p)) = (&sink, cluster.pool()) {
                        p.export_trace(s.as_ref());
                    }
                }
                other => return Err(Error::Config(format!("unknown --algo '{other}'"))),
            }
            if let Some(s) = &sink {
                finish_trace(s, trace_out)?;
            }
            Ok(())
        }
        Some("bench") => {
            // mli bench --figure fig2|figA5|fig3|figA7 [--machines 1,2,4]
            //           [--trace-out trace.json]
            let machines = args.get_usize_list("machines", &[1, 2, 4])?;
            let iters = cfg.get_usize("bench", "iters", 5)?;
            let trace_out = args.get("trace-out");
            let (tracer, sink) = if trace_out.is_some() {
                let (t, s) = trace::Tracer::recording();
                (Some(t), Some(s))
            } else {
                (None, None)
            };
            match args.get_str("figure", "fig2").as_str() {
                "fig2" | "figA5" => {
                    let mode = if args.get_str("figure", "fig2") == "fig2" {
                        ScalingMode::Weak
                    } else {
                        ScalingMode::Strong
                    };
                    let c = LogregBenchConfig {
                        machines,
                        rows: args.get_usize("rows", 512)?,
                        d: args.get_usize("d", 64)?,
                        iters,
                        backend: Backend::Xla,
                        seed: 42,
                        reps: 1,
                        threads: args.get_usize("threads", 0)?,
                    };
                    println!("{}", logreg_scaling_with(&c, mode, tracer.as_ref())?.to_markdown());
                }
                "fig3" | "figA7" => {
                    let mode = if args.get_str("figure", "fig3") == "fig3" {
                        ScalingMode::Weak
                    } else {
                        ScalingMode::Strong
                    };
                    let c = AlsBenchConfig {
                        machines,
                        iters,
                        threads: args.get_usize("threads", 0)?,
                        ..Default::default()
                    };
                    println!("{}", als_scaling_with(&c, mode, tracer.as_ref())?.to_markdown());
                }
                other => return Err(Error::Config(format!("unknown --figure '{other}'"))),
            }
            if let Some(s) = &sink {
                finish_trace(s, trace_out)?;
            }
            Ok(())
        }
        Some("exec-bench") => {
            // mli exec-bench [--threads 1,2,4,8] [--partitions P] [--n N] [--d D]
            //
            // Thread-scaling table for the exec pool: trains the same logreg
            // workload (Rust backend — no AOT artifacts needed) at each host
            // thread count and reports real wall-clock, speedup over 1 thread,
            // and the pool's task/steal counters. Results are checked to be
            // bitwise identical across thread counts; simulated cluster time
            // is thread-independent by construction.
            let thread_counts = args.get_usize_list("threads", &[1, 2, 4, 8])?;
            let parts = args.get_usize("partitions", 8)?;
            let n = args.get_usize("n", 8192)?;
            let d = args.get_usize("d", 64)?;
            let iters = args.get_usize("iters", 10)?;
            let trace_out = args.get("trace-out");
            let (tracer, sink) = if trace_out.is_some() {
                let (t, s) = trace::Tracer::recording();
                (Some(t), Some(s))
            } else {
                (None, None)
            };
            let mut table = metrics::Table::new(
                "exec thread scaling (logreg, Rust backend)",
                &["threads", "wall_ms", "speedup", "tasks", "steals", "sim_s"],
            );
            let mut base_wall: Option<f64> = None;
            let mut base_weights: Option<localmatrix::MLVector> = None;
            for &t in &thread_counts {
                let ctx = engine::EngineContext::new();
                let data = data::dense_gen::generate(&ctx, n, d, parts, 7)?;
                let cluster = cluster::SimCluster::ec2(parts).with_executor(t.max(1));
                if let Some(tr) = &tracer {
                    cluster.set_tracer(tr.clone());
                }
                let algo = algorithms::LogisticRegression::new(
                    algorithms::logreg::LogRegParams {
                        sgd: optim::SgdParams { iters, ..Default::default() },
                        backend: Backend::Rust,
                    },
                );
                use algorithms::Algorithm;
                let start = std::time::Instant::now();
                let model = algo.train(&data.table, &cluster)?;
                let wall_ms = start.elapsed().as_secs_f64() * 1e3;
                match &base_weights {
                    None => base_weights = Some(model.weights.clone()),
                    Some(b) => {
                        if b != &model.weights {
                            return Err(Error::Engine(format!(
                                "exec-bench: weights diverged at {t} threads \
                                 (determinism contract violated)"
                            )));
                        }
                    }
                }
                let (tasks, steals) = cluster
                    .pool()
                    .map(|p| {
                        if let Some(s) = &sink {
                            p.export_trace(s.as_ref());
                        }
                        let s = p.worker_stats();
                        (
                            s.iter().map(|w| w.tasks).sum::<u64>(),
                            s.iter().map(|w| w.steals).sum::<u64>(),
                        )
                    })
                    .unwrap_or((0, 0));
                let base = *base_wall.get_or_insert(wall_ms);
                table.row(vec![
                    t.to_string(),
                    format!("{wall_ms:.1}"),
                    format!("{:.2}x", base / wall_ms),
                    tasks.to_string(),
                    steals.to_string(),
                    format!("{:.3}", cluster.total_sim_seconds()),
                ]);
            }
            println!("{}", table.to_markdown());
            println!("(results bitwise-identical across all thread counts)");
            if let Some(s) = &sink {
                finish_trace(s, trace_out)?;
            }
            Ok(())
        }
        Some("trace") => {
            // mli trace [--threads T] [--partitions P] [--iters N] [--n N]
            //           [--d D] [--out trace.json]
            //
            // Small traced logreg run (Rust backend): prints the span/counter
            // summary and the simulated-vs-wall clock attribution; --out
            // writes the Chrome-trace JSON for chrome://tracing / perfetto.
            let threads = args.get_usize("threads", 2)?;
            let parts = args.get_usize("partitions", 8)?;
            let iters = args.get_usize("iters", 6)?;
            let n = args.get_usize("n", 4096)?;
            let d = args.get_usize("d", 32)?;
            let (tracer, sink) = trace::Tracer::recording();
            let ctx = engine::EngineContext::new();
            let data = data::dense_gen::generate(&ctx, n, d, parts, 7)?;
            let cluster = cluster::SimCluster::ec2(parts).with_executor(threads.max(1));
            cluster.set_tracer(tracer.clone());
            let algo = algorithms::LogisticRegression::new(algorithms::logreg::LogRegParams {
                sgd: optim::SgdParams {
                    iters,
                    track_loss: true,
                    ..Default::default()
                },
                backend: Backend::Rust,
            });
            use algorithms::Algorithm;
            let model = algo.train(&data.table, &cluster)?;
            println!(
                "traced logreg: {n}x{d}, {parts} partitions, {iters} iters, \
                 {threads} threads; final loss {:.6}",
                model.loss_history.last().copied().unwrap_or(f64::NAN)
            );
            if let Some(p) = cluster.pool() {
                p.export_trace(sink.as_ref());
            }
            finish_trace(&sink, args.get("out"))?;
            Ok(())
        }
        Some("loc") => {
            println!("{}", bench_harness::loc::fig2a().to_markdown());
            println!("{}", bench_harness::loc::fig3a().to_markdown());
            Ok(())
        }
        Some("help") | None => {
            println!("mli — MLI: An API for Distributed Machine Learning (reproduction)");
            println!();
            println!("USAGE: mli <subcommand> [--options] [--config file.toml]");
            println!();
            println!("  selftest                              compile+run one AOT artifact");
            println!("  train --algo logreg|als --machines M  train on the simulated cluster");
            println!("  bench --figure fig2|figA5|fig3|figA7  regenerate a paper figure (CLI scale)");
            println!("  exec-bench [--threads 1,2,4,8]        exec pool thread-scaling table");
            println!("  trace [--out trace.json]              traced run + span/counter summary");
            println!("  loc                                   Fig 2a/3a lines-of-code tables");
            println!("  help                                  this message");
            println!();
            println!("  --threads T   evaluate partitions on a T-thread work-stealing pool");
            println!("                (T=0: one thread per simulated machine, host-capped;");
            println!("                affects real wall-clock only — simulated time and");
            println!("                results are identical for any T)");
            println!("                e.g. `mli train --algo logreg --machines 8 --threads 4`");
            println!("  --trace-out F record per-task/per-stage spans and exec counters during");
            println!("                train/bench/exec-bench; write Chrome-trace JSON to F");
            println!("                (open in chrome://tracing or ui.perfetto.dev)");
            println!();
            println!("full-scale figures: `cargo bench` (see rust/benches/)");
            Ok(())
        }
        Some(other) => Err(Error::Config(format!("unknown subcommand '{other}'"))),
    }
}
