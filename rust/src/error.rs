//! Crate-wide error type.
//!
//! Everything user-facing returns [`Result`]; internal invariant
//! violations panic (they indicate bugs, not user errors).
//!
//! `Display`/`Error` are hand-implemented (no `thiserror` in this
//! offline sandbox).

use std::fmt;

/// Unified error for the MLI crate.
#[derive(Debug)]
pub enum Error {
    /// Schema mismatch in an MLTable operation (union/join/cast).
    Schema(String),

    /// Shape mismatch in LocalMatrix algebra.
    Shape(String),

    /// Numerical failure (singular solve, non-convergence).
    Numerical(String),

    /// Engine / scheduler failure (lost partition beyond retry budget,
    /// missing dependency, bad partitioning).
    Engine(String),

    /// Simulated out-of-memory: a workload exceeded a machine's capacity.
    /// Benches report this as DNF, mirroring the paper's MATLAB OOMs.
    Oom(String),

    /// PJRT runtime failure (artifact missing, shape mismatch at the
    /// XLA boundary, execution error).
    Runtime(String),

    /// Configuration / CLI parse error.
    Config(String),

    /// Malformed input data (CSV/JSON/text loaders).
    Parse(String),

    Io(std::io::Error),

    Xla(String),

    /// Executor failure: a task in a [`crate::exec::TaskSet`] panicked.
    /// The pool survives; the stage that owned the task gets this error.
    Exec(String),

    /// Node-level fault recovery failed: no machine alive to place a
    /// partition, or a partition's retry budget (attempts + backoff
    /// timeout) was exhausted. Jobs fail-stop with this typed error
    /// instead of panicking or hanging.
    FaultRecovery(String),

    /// `mli lint --deny` found violations of the determinism /
    /// concurrency invariants (see `crate::lint` and docs/lint.md).
    Lint(String),

    /// Network-level fault: a message's retry/timeout budget was
    /// exhausted against a lossy or degraded link, or a destination sat
    /// on the wrong side of a partition under the `Replace` policy.
    /// Distinct from [`Error::FaultRecovery`] (node death) so callers
    /// and the chaos harness can tell the two failure domains apart.
    NetFault(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Schema(m) => write!(f, "schema error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Numerical(m) => write!(f, "numerical error: {m}"),
            Error::Engine(m) => write!(f, "engine error: {m}"),
            Error::Oom(m) => write!(f, "out of memory: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Parse(m) => write!(f, "parse error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Exec(m) => write!(f, "executor error: {m}"),
            Error::FaultRecovery(m) => write!(f, "fault recovery failed: {m}"),
            Error::Lint(m) => write!(f, "lint failed: {m}"),
            Error::NetFault(m) => write!(f, "network fault: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::xla::Error> for Error {
    fn from(e: crate::xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// True if this error models a *simulated* resource failure (OOM),
    /// which benches report as DNF rather than propagate.
    pub fn is_oom(&self) -> bool {
        matches!(self, Error::Oom(_))
    }

    /// True if this error is a node-fault recovery failure (dead fleet or
    /// exhausted retry budget); the chaos harness and tests match on it.
    pub fn is_fault_recovery(&self) -> bool {
        matches!(self, Error::FaultRecovery(_))
    }

    /// True if this error is a network fault (retry budget exhausted on a
    /// lossy/degraded link, or a partition cut under `Replace`).
    pub fn is_net_fault(&self) -> bool {
        matches!(self, Error::NetFault(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Shape("2x3 vs 4x5".into());
        assert!(e.to_string().contains("2x3 vs 4x5"));
    }

    #[test]
    fn oom_detection() {
        assert!(Error::Oom("68GB cap".into()).is_oom());
        assert!(!Error::Schema("x".into()).is_oom());
    }

    #[test]
    fn net_fault_detection() {
        let e = Error::NetFault("partition cut 0->7".into());
        assert!(e.is_net_fault());
        assert!(e.to_string().contains("network fault"));
        assert!(!e.is_fault_recovery());
        assert!(!Error::FaultRecovery("x".into()).is_net_fault());
    }

    #[test]
    fn fault_recovery_detection() {
        let e = Error::FaultRecovery("all 4 machines down".into());
        assert!(e.is_fault_recovery());
        assert!(e.to_string().contains("fault recovery failed"));
        assert!(!Error::Engine("x".into()).is_fault_recovery());
    }
}
