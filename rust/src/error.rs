//! Crate-wide error type.
//!
//! Everything user-facing returns [`Result`]; internal invariant
//! violations panic (they indicate bugs, not user errors).

use thiserror::Error;

/// Unified error for the MLI crate.
#[derive(Error, Debug)]
pub enum Error {
    /// Schema mismatch in an MLTable operation (union/join/cast).
    #[error("schema error: {0}")]
    Schema(String),

    /// Shape mismatch in LocalMatrix algebra.
    #[error("shape error: {0}")]
    Shape(String),

    /// Numerical failure (singular solve, non-convergence).
    #[error("numerical error: {0}")]
    Numerical(String),

    /// Engine / scheduler failure (lost partition beyond retry budget,
    /// missing dependency, bad partitioning).
    #[error("engine error: {0}")]
    Engine(String),

    /// Simulated out-of-memory: a workload exceeded a machine's capacity.
    /// Benches report this as DNF, mirroring the paper's MATLAB OOMs.
    #[error("out of memory: {0}")]
    Oom(String),

    /// PJRT runtime failure (artifact missing, shape mismatch at the
    /// XLA boundary, execution error).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Configuration / CLI parse error.
    #[error("config error: {0}")]
    Config(String),

    /// Malformed input data (CSV/JSON/text loaders).
    #[error("parse error: {0}")]
    Parse(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// True if this error models a *simulated* resource failure (OOM),
    /// which benches report as DNF rather than propagate.
    pub fn is_oom(&self) -> bool {
        matches!(self, Error::Oom(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Shape("2x3 vs 4x5".into());
        assert!(e.to_string().contains("2x3 vs 4x5"));
    }

    #[test]
    fn oom_detection() {
        assert!(Error::Oom("68GB cap".into()).is_oom());
        assert!(!Error::Schema("x".into()).is_oom());
    }
}
