//! Typed experiment configuration: an INI/TOML-subset file format plus
//! CLI overrides — the launcher's "real config system".
//!
//! Format (a strict subset of TOML):
//!
//! ```toml
//! [cluster]
//! machines = 8
//! topology = "star"        # star | allreduce | p2p
//!
//! [logreg]
//! iters = 10
//! learning_rate = 0.05
//! ```
//!
//! CLI `--section.key value` overrides file values.

use std::collections::BTreeMap;
use std::path::Path;

use crate::cluster::CommTopology;
use crate::error::{Error, Result};
use crate::util::cli::Args;

/// Parsed configuration: section -> key -> raw string value.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, BTreeMap<String, String>>,
}

impl Config {
    pub fn empty() -> Config {
        Config::default()
    }

    /// Parse the TOML-subset text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::from("global");
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.values.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let v = v.trim().trim_matches('"').to_string();
            cfg.values
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(cfg)
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Config> {
        Config::parse(&std::fs::read_to_string(path)?)
    }

    /// Apply `--section.key value` CLI overrides.
    pub fn with_overrides(mut self, args: &Args) -> Config {
        for (k, v) in &args.options {
            if let Some((sec, key)) = k.split_once('.') {
                self.values
                    .entry(sec.to_string())
                    .or_default()
                    .insert(key.to_string(), v.clone());
            }
        }
        self
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.values.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, section: &str, key: &str, default: usize) -> Result<usize> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("[{section}] {key} = '{v}' is not an integer"))
            }),
        }
    }

    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> Result<f64> {
        match self.get(section, key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                Error::Config(format!("[{section}] {key} = '{v}' is not a number"))
            }),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> Result<bool> {
        match self.get(section, key) {
            None => Ok(default),
            Some("true") | Some("1") => Ok(true),
            Some("false") | Some("0") => Ok(false),
            Some(v) => Err(Error::Config(format!(
                "[{section}] {key} = '{v}' is not a bool"
            ))),
        }
    }

    pub fn get_str(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).unwrap_or(default).to_string()
    }

    pub fn topology(&self, section: &str, default: CommTopology) -> Result<CommTopology> {
        match self.get(section, "topology") {
            None => Ok(default),
            Some("star") => Ok(CommTopology::StarGatherBroadcast),
            Some("allreduce") => Ok(CommTopology::AllReduceTree),
            Some("p2p") => Ok(CommTopology::PeerToPeer),
            Some(v) => Err(Error::Config(format!(
                "[{section}] topology = '{v}' (expected star|allreduce|p2p)"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
[cluster]
machines = 8
topology = "allreduce"
mem_scale = 0.5

[logreg]
iters = 10
learning_rate = 0.05
use_xla = true
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_usize("cluster", "machines", 1).unwrap(), 8);
        assert_eq!(c.get_f64("logreg", "learning_rate", 0.0).unwrap(), 0.05);
        assert!(c.get_bool("logreg", "use_xla", false).unwrap());
        assert_eq!(c.get_usize("cluster", "missing", 7).unwrap(), 7);
        assert_eq!(
            c.topology("cluster", CommTopology::StarGatherBroadcast).unwrap(),
            CommTopology::AllReduceTree
        );
    }

    #[test]
    fn parse_errors() {
        assert!(Config::parse("no_equals_here").is_err());
        let c = Config::parse("[s]\nx = abc\n").unwrap();
        assert!(c.get_usize("s", "x", 0).is_err());
        assert!(c.get_bool("s", "x", false).is_err());
        assert!(c.topology("s", CommTopology::PeerToPeer).is_ok()); // no key -> default
        let c2 = Config::parse("[s]\ntopology = ring\n").unwrap();
        assert!(c2.topology("s", CommTopology::PeerToPeer).is_err());
    }

    #[test]
    fn cli_overrides() {
        let c = Config::parse(SAMPLE).unwrap();
        let args = Args::parse(&[
            "bench".to_string(),
            "--cluster.machines".to_string(),
            "32".to_string(),
            "--new.key".to_string(),
            "v".to_string(),
        ]);
        let c = c.with_overrides(&args);
        assert_eq!(c.get_usize("cluster", "machines", 1).unwrap(), 32);
        assert_eq!(c.get("new", "key"), Some("v"));
    }

    #[test]
    fn comments_and_quotes() {
        let c = Config::parse("[a]\nk = \"quoted\" # trailing\n").unwrap();
        assert_eq!(c.get("a", "k"), Some("quoted"));
    }
}
