//! Lines-of-code tables (paper Fig. 2a and Fig. 3a): the paper's
//! usability metric. We report the paper's numbers verbatim alongside the
//! measured size of *our* implementations (counted the way the paper
//! counts: the algorithm/driver code a developer writes against the API,
//! not the framework underneath).

use std::path::Path;

use crate::metrics::Table;

/// Count effective lines of code in a source file: non-blank, non-comment
/// (line comments only — good enough for rust sources we control).
pub fn count_loc(path: impl AsRef<Path>) -> std::io::Result<usize> {
    let text = std::fs::read_to_string(path)?;
    Ok(count_loc_str(&text))
}

pub fn count_loc_str(text: &str) -> usize {
    let mut in_block = false;
    text.lines()
        .filter(|l| {
            let t = l.trim();
            if in_block {
                if t.contains("*/") {
                    in_block = false;
                }
                return false;
            }
            if t.starts_with("/*") {
                in_block = !t.contains("*/");
                return false;
            }
            !t.is_empty() && !t.starts_with("//") && !t.starts_with('#')
        })
        .count()
}

/// Count the user-facing algorithm code (what Fig. 2a/3a measure): the
/// lines of the `train`/optimizer bodies, not tests or docs. We measure
/// whole implementation files minus `#[cfg(test)]` modules.
pub fn count_impl_loc(path: impl AsRef<Path>) -> std::io::Result<usize> {
    let text = std::fs::read_to_string(path)?;
    let body = match text.find("#[cfg(test)]") {
        Some(i) => &text[..i],
        None => &text,
    };
    Ok(count_loc_str(body))
}

/// Fig. 2a — logistic regression lines of code.
pub fn fig2a() -> Table {
    let mut t = Table::new(
        "Fig 2a: Logistic regression, lines of code",
        &["System", "LoC (paper)", "LoC (this repo)"],
    );
    let ours = count_impl_loc("rust/src/algorithms/logreg.rs").unwrap_or(0)
        + count_impl_loc("rust/src/optim/sgd.rs").unwrap_or(0);
    t.row(vec!["MLI".into(), "55".into(), ours.to_string()]);
    t.row(vec!["Vowpal Wabbit".into(), "721".into(), "—".into()]);
    t.row(vec!["MATLAB".into(), "11".into(), "—".into()]);
    t
}

/// Fig. 3a — ALS lines of code. The paper's text gives the MATLAB-vs-MLI
/// comparison qualitatively ("about the same length") and cites the stark
/// gap to Mahout/GraphLab; the canonical public implementations at the
/// time were ~383 (GraphLab ALS vertex program) and ~865 (Mahout ALS
/// job) lines, which Fig. 3a plots.
pub fn fig3a() -> Table {
    let mut t = Table::new(
        "Fig 3a: ALS, lines of code",
        &["System", "LoC (paper-era impl)", "LoC (this repo)"],
    );
    let ours = count_impl_loc("rust/src/algorithms/als.rs").unwrap_or(0);
    t.row(vec!["MLI".into(), "~35".into(), ours.to_string()]);
    t.row(vec!["GraphLab".into(), "~383".into(), "—".into()]);
    t.row(vec!["Mahout".into(), "~865".into(), "—".into()]);
    t.row(vec!["MATLAB".into(), "~20".into(), "—".into()]);
    t.row(vec!["MATLAB-mex".into(), "~124".into(), "—".into()]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loc_counting_rules() {
        let src = "\n// comment\nlet x = 1;\n\n/* block\n still block\n*/\nlet y = 2; \n";
        assert_eq!(count_loc_str(src), 2);
        assert_eq!(count_loc_str(""), 0);
        assert_eq!(count_loc_str("// only comments\n// again"), 0);
    }

    #[test]
    fn tables_have_rows() {
        // paths resolve when run from the repo root (cargo does)
        let t = fig2a();
        assert_eq!(t.rows.len(), 3);
        let t3 = fig3a();
        assert_eq!(t3.rows.len(), 5);
    }
}
