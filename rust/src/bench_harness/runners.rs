//! Figure runners: the parameter sweeps behind Fig. 2b/2c, A5/A6 (logistic
//! regression weak/strong scaling) and Fig. 3b/3c, A7/A8 (ALS weak/strong
//! scaling), each comparing MLI against the paper's systems on the
//! simulated cluster.


use crate::algorithms::als::{AlsParams, ALS};
use crate::algorithms::logreg::{Backend, LogRegParams, LogisticRegression};
use crate::algorithms::Algorithm;
use crate::baselines::{graphlab, mahout, matlab, vw, SystemProfile, SystemRun};
use crate::data::netflix::{self, NetflixConfig, RatingsData};
use crate::data::dense_gen;
use crate::engine::EngineContext;
use crate::error::{Error, Result};
use crate::metrics::{fmt_time, Table};
use crate::optim::{GdParams, SgdParams};
use crate::trace::Tracer;
use std::sync::Arc;

/// Weak scaling: data grows with machines. Strong: total data fixed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalingMode {
    Weak,
    Strong,
}

// ---------------------------------------------------------------------------
// Logistic regression (Fig. 2b/2c weak; Fig. A5/A6 strong)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct LogregBenchConfig {
    pub machines: Vec<usize>,
    /// rows per machine (weak) or total rows (strong)
    pub rows: usize,
    pub d: usize,
    pub iters: usize,
    pub backend: Backend,
    pub seed: u64,
    /// Repetitions per point; the median is reported (single-core hosts
    /// jitter 2-3x run to run; see EXPERIMENTS.md §Scale-down caveats).
    pub reps: usize,
    /// Host exec-pool threads for the MLI runs (0 = serial evaluation).
    /// Shrinks real wall-clock only; simulated times are unaffected.
    pub threads: usize,
}

impl Default for LogregBenchConfig {
    fn default() -> Self {
        LogregBenchConfig {
            machines: vec![1, 2, 4, 8, 16, 32],
            rows: 2048,
            d: 512,
            iters: 10,
            backend: Backend::Xla,
            seed: 42,
            reps: 3,
            threads: 0,
        }
    }
}

/// Run the logreg scaling experiment. Emits one row per machine count
/// with MLI / VW / MATLAB simulated walltimes (MATLAB: single node, DNF on
/// OOM — the paper's weak-scaling behaviour at the largest point).
pub fn logreg_scaling(cfg: &LogregBenchConfig, mode: ScalingMode) -> Result<Table> {
    logreg_scaling_with(cfg, mode, None)
}

/// [`logreg_scaling`] with an optional tracer attached to the MLI runs
/// (spans + exec counters land in the tracer's sink).
pub fn logreg_scaling_with(
    cfg: &LogregBenchConfig,
    mode: ScalingMode,
    tracer: Option<&Arc<Tracer>>,
) -> Result<Table> {
    let title = match mode {
        ScalingMode::Weak => "Fig 2b/2c: logistic regression weak scaling",
        ScalingMode::Strong => "Fig A5/A6: logistic regression strong scaling",
    };
    let mut table = Table::new(
        title,
        &[
            "machines", "n_total", "d", "MLI_s", "VW_s", "MATLAB_s", "MLI_rel", "VW_rel",
        ],
    );

    let mut mli_base: Option<f64> = None;
    let mut vw_base: Option<f64> = None;
    let mut total_losses = 0usize;
    let mut total_recoveries = 0u64;
    let mut total_tasks = 0u64;
    let mut net_drops = 0u64;
    let mut net_retries = 0u64;
    let mut net_waits = 0u64;
    for &m in &cfg.machines {
        let n_total = match mode {
            ScalingMode::Weak => cfg.rows * m,
            ScalingMode::Strong => cfg.rows,
        };
        // partitions sized to fit the largest artifact (2048 rows)
        let parts = m.max(n_total.div_ceil(2048));
        let ctx = EngineContext::new();
        let data = dense_gen::generate(&ctx, n_total, cfg.d, parts, cfg.seed)?;

        let sgd = SgdParams {
            iters: cfg.iters,
            learning_rate: 0.02,
            topology: SystemProfile::mli().topology,
            ..Default::default()
        };
        let reps = cfg.reps.max(1);

        // MLI
        let mli_times: Vec<f64> = (0..reps)
            .map(|_| {
                let mut cluster = SystemProfile::mli().cluster(m);
                if cfg.threads > 0 {
                    cluster = cluster.with_executor(cfg.threads);
                }
                if let Some(t) = tracer {
                    cluster.set_tracer(t.clone());
                }
                LogisticRegression::new(LogRegParams {
                    sgd: sgd.clone(),
                    backend: cfg.backend.clone(),
                })
                .train(&data.table, &cluster)
                .map(|_| {
                    let ns = cluster.net_stats();
                    net_drops += ns.drops;
                    net_retries += ns.retries;
                    net_waits += ns.partition_waits;
                    cluster.total_sim_seconds()
                })
            })
            .collect::<Result<_>>()?;
        let mli = SystemRun {
            system: "MLI".into(),
            machines: m,
            sim_seconds: Some(crate::util::median(&mli_times)),
            quality: None,
        };

        // VW (same compute, allreduce tree, C++ factor)
        let vw_times: Vec<f64> = (0..reps)
            .map(|_| {
                vw::run_logreg(&data.table, m, &sgd, cfg.backend.clone()).and_then(|r| {
                    r.sim_seconds
                        .ok_or_else(|| Error::Engine("VW run reported no sim time".into()))
                })
            })
            .collect::<Result<_>>()?;
        let vw = SystemRun {
            system: "VW".into(),
            machines: m,
            sim_seconds: Some(crate::util::median(&vw_times)),
            quality: None,
        };

        // MATLAB (single machine full-batch GD; OOM => DNF)
        let matlab_runs: Vec<Option<f64>> = (0..reps)
            .map(|_| {
                matlab::run_logreg(
                    &data.table,
                    &GdParams {
                        iters: cfg.iters,
                        ..Default::default()
                    },
                    false,
                    cfg.backend == Backend::Xla,
                )
                .map(|r| r.sim_seconds)
            })
            .collect::<Result<_>>()?;
        let matlab = SystemRun {
            system: "MATLAB".into(),
            machines: 1,
            sim_seconds: if matlab_runs.iter().any(|t| t.is_none()) {
                None
            } else {
                let ts: Vec<f64> = matlab_runs.iter().copied().flatten().collect();
                Some(crate::util::median(&ts))
            },
            quality: None,
        };

        let missing = |s: &str| Error::Engine(format!("{s} run reported no sim time"));
        let mli_t = mli.sim_seconds.ok_or_else(|| missing("MLI"))?;
        let vw_t = vw.sim_seconds.ok_or_else(|| missing("VW"))?;
        let mli_b = *mli_base.get_or_insert(mli_t);
        let vw_b = *vw_base.get_or_insert(vw_t);
        table.row(vec![
            m.to_string(),
            n_total.to_string(),
            cfg.d.to_string(),
            fmt_time(mli.sim_seconds),
            fmt_time(vw.sim_seconds),
            fmt_time(matlab.sim_seconds),
            format!("{:.2}", mli_t / mli_b),
            format!("{:.2}", vw_t / vw_b),
        ]);
        let (tasks, _, recoveries) = ctx.stats();
        total_losses += ctx.failures.losses();
        total_recoveries += recoveries;
        total_tasks += tasks;
    }
    table.note(format!(
        "failure accounting across the sweep: {total_losses} partitions lost, \
         {total_recoveries} lineage recoveries, {total_tasks} engine tasks run; \
         net faults: {net_drops} drops, {net_retries} retries, {net_waits} partition waits"
    ));
    Ok(table)
}

// ---------------------------------------------------------------------------
// ALS (Fig. 3b/3c weak; Fig. A7/A8 strong)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct AlsBenchConfig {
    /// Machine counts; weak scaling tiles the base dataset by this factor
    /// (perfect squares per the paper), strong scaling fixes `strong_tile`.
    pub machines: Vec<usize>,
    pub strong_tile: usize,
    pub base: NetflixConfig,
    pub iters: usize,
    pub rank: usize,
    pub lambda: f64,
    pub use_xla: bool,
    /// Repetitions per point; medians reported.
    pub reps: usize,
    /// Host exec-pool threads for the MLI runs (0 = serial evaluation).
    pub threads: usize,
}

impl Default for AlsBenchConfig {
    fn default() -> Self {
        AlsBenchConfig {
            machines: vec![1, 4, 9, 16, 25],
            strong_tile: 9,
            base: NetflixConfig::default(),
            iters: 10,
            rank: 10,
            lambda: 0.01,
            use_xla: true,
            reps: 3,
            threads: 0,
        }
    }
}

fn tiled(base: &RatingsData, t: usize) -> RatingsData {
    netflix::tile(base, t)
}

/// Run the ALS scaling experiment: MLI vs GraphLab vs Mahout vs MATLAB vs
/// MATLAB-mex (paper Fig. 3b/3c; A7/A8 for strong).
pub fn als_scaling(cfg: &AlsBenchConfig, mode: ScalingMode) -> Result<Table> {
    als_scaling_with(cfg, mode, None)
}

/// [`als_scaling`] with an optional tracer attached to the MLI runs.
pub fn als_scaling_with(
    cfg: &AlsBenchConfig,
    mode: ScalingMode,
    tracer: Option<&Arc<Tracer>>,
) -> Result<Table> {
    let title = match mode {
        ScalingMode::Weak => "Fig 3b/3c: ALS weak scaling (Netflix x machines)",
        ScalingMode::Strong => "Fig A7/A8: ALS strong scaling (9x Netflix)",
    };
    let mut table = Table::new(
        title,
        &[
            "machines",
            "tile",
            "users",
            "nnz",
            "MLI_s",
            "GraphLab_s",
            "Mahout_s",
            "MATLAB_s",
            "MATLABmex_s",
            "MLI_rel",
        ],
    );
    let base = netflix::generate(&cfg.base);
    let base_data = RatingsData {
        ratings: base.ratings.clone(),
        users: base.users,
        items: base.items,
        rank: base.rank,
    };

    let mut mli_base: Option<f64> = None;
    let mut total_kills = 0u64;
    let mut total_restarts = 0u64;
    let mut net_drops = 0u64;
    let mut net_retries = 0u64;
    let mut net_waits = 0u64;
    for &m in &cfg.machines {
        let t = match mode {
            ScalingMode::Weak => m,
            ScalingMode::Strong => cfg.strong_tile,
        };
        let data = tiled(&base_data, t);
        let params = AlsParams {
            rank: cfg.rank,
            iters: cfg.iters,
            lambda: cfg.lambda,
            use_xla: cfg.use_xla,
            track_rmse: false,
            ..Default::default()
        };

        let reps = cfg.reps.max(1);
        let med = |ts: Vec<Option<f64>>| -> Option<f64> {
            if ts.iter().any(|t| t.is_none()) {
                None
            } else {
                let v: Vec<f64> = ts.into_iter().flatten().collect();
                Some(crate::util::median(&v))
            }
        };

        // MLI
        let profile = SystemProfile::mli();
        let mut p = params.clone();
        p.topology = profile.topology;
        let mli_times: Vec<Option<f64>> = (0..reps)
            .map(|_| {
                let mut cluster = profile.cluster(m);
                if cfg.threads > 0 {
                    cluster = cluster.with_executor(cfg.threads);
                }
                if let Some(t) = tracer {
                    cluster.set_tracer(t.clone());
                }
                let r = ALS::new(p.clone())
                    .train_ratings(&data, &cluster)
                    .map(|_| Some(cluster.total_sim_seconds()));
                let (kills, restarts) = cluster.fault_stats();
                total_kills += kills;
                total_restarts += restarts;
                let ns = cluster.net_stats();
                net_drops += ns.drops;
                net_retries += ns.retries;
                net_waits += ns.partition_waits;
                r
            })
            .collect::<Result<_>>()?;
        let mli_t = med(mli_times)
            .ok_or_else(|| Error::Engine("MLI ALS run reported no sim time".into()))?;
        let mli_b = *mli_base.get_or_insert(mli_t);

        // baselines: SAME compute backend as MLI so gaps come only from
        // topology + compute factors (DESIGN.md §3)
        let bl_params = params.clone();
        let rep_runs = |f: &dyn Fn() -> Result<crate::baselines::SystemRun>| -> Result<Option<f64>> {
            let ts: Vec<Option<f64>> = (0..reps)
                .map(|_| f().map(|r| r.sim_seconds))
                .collect::<Result<_>>()?;
            Ok(med(ts))
        };
        let gl_t = rep_runs(&|| graphlab::run_als(&data, m, &bl_params))?;
        let mh_t = rep_runs(&|| mahout::run_als(&data, m, &bl_params))?;
        let ml_t = rep_runs(&|| matlab::run_als(&data, &bl_params, false))?;
        let mx_t = rep_runs(&|| matlab::run_als(&data, &bl_params, true))?;

        table.row(vec![
            m.to_string(),
            format!("{t}x"),
            data.users.to_string(),
            data.ratings.nnz().to_string(),
            fmt_time(Some(mli_t)),
            fmt_time(gl_t),
            fmt_time(mh_t),
            fmt_time(ml_t),
            fmt_time(mx_t),
            format!("{:.2}", mli_t / mli_b),
        ]);
    }
    table.note(format!(
        "node faults across the MLI runs: {total_kills} kills, {total_restarts} restarts; \
         net faults: {net_drops} drops, {net_retries} retries, {net_waits} partition waits"
    ));
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logreg_scaling_tiny_smoke() {
        // tiny configuration exercising the full sweep machinery
        let cfg = LogregBenchConfig {
            machines: vec![1, 2],
            rows: 64,
            d: 16,
            iters: 2,
            backend: Backend::Rust,
            seed: 1,
            reps: 1,
            threads: 0,
        };
        let t = logreg_scaling(&cfg, ScalingMode::Weak).unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.headers.len(), 8);
        assert_eq!(t.notes.len(), 1, "failure-accounting footnote present");
        assert!(t.to_markdown().contains("failure accounting"));
        // first row is the baseline: relative walltime 1.00
        assert_eq!(t.rows[0][6], "1.00");
        let strong = logreg_scaling(&cfg, ScalingMode::Strong).unwrap();
        // strong scaling: n_total constant
        assert_eq!(strong.rows[0][1], strong.rows[1][1]);
    }

    #[test]
    fn als_scaling_tiny_smoke() {
        let cfg = AlsBenchConfig {
            machines: vec![1, 4],
            strong_tile: 4,
            base: NetflixConfig {
                users: 64,
                items: 24,
                rank: 4,
                mean_nnz_per_user: 6,
                max_nnz_per_user: 10,
                ..Default::default()
            },
            iters: 1,
            rank: 4,
            lambda: 0.01,
            use_xla: false,
            reps: 1,
            threads: 0,
        };
        let t = als_scaling(&cfg, ScalingMode::Weak).unwrap();
        assert_eq!(t.rows.len(), 2);
        // weak scaling tiles with machines
        assert_eq!(t.rows[1][1], "4x");
    }
}
