//! Bench harness: regenerates every table and figure of the paper's
//! evaluation (DESIGN.md §5). The `cargo bench` targets in
//! `rust/benches/` are thin wrappers over [`runners`]; [`loc`] produces
//! the lines-of-code tables (Fig. 2a / 3a).

pub mod loc;
pub mod runners;

pub use runners::{
    als_scaling, als_scaling_with, logreg_scaling, logreg_scaling_with, AlsBenchConfig,
    LogregBenchConfig, ScalingMode,
};
