//! Node-failure schedules for the simulated cluster.
//!
//! [`crate::engine::FailurePlan`] injects *task*-level failures (a compute
//! attempt that throws and is retried in place). A [`FaultPlan`] models the
//! other — dominant — real-world failure mode: a whole machine crashing,
//! taking every cached partition resident on it down with it. The cluster
//! applies due events at round boundaries ([`super::SimCluster::begin_round`]):
//! the machine is marked down, its resident bytes are dropped and charged
//! as an HDFS re-read, and machine-loss listeners invalidate the affected
//! cached partitions so the engine recovers them through lineage (or a
//! checkpoint, see `Dataset::checkpoint`).

use crate::exec::lock_unpoisoned;
use crate::util::rng::Rng;
use std::sync::Mutex;

/// What happens to a killed machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The machine crashes and rejoins the fleet `restart_after` rounds
    /// later (empty — its cached state died with the crash). A value of 0
    /// is treated as 1: a restart is never visible within the same round.
    Crash { restart_after: usize },
    /// The machine never comes back.
    Permanent,
}

/// One scheduled machine kill.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Round index (0-based, counted over `SimCluster::begin_round` calls)
    /// at which the kill fires, before any work of that round runs.
    pub round: usize,
    pub machine: usize,
    pub kind: FaultKind,
}

/// A schedule of machine kills, applied by the cluster at round
/// boundaries. Shared (`Arc`) between the driver that authors it and the
/// cluster that drains it.
#[derive(Debug, Default)]
pub struct FaultPlan {
    events: Mutex<Vec<FaultEvent>>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Schedule machine `machine` to die at round `round`.
    pub fn kill_at(&self, round: usize, machine: usize, kind: FaultKind) {
        lock_unpoisoned(&self.events).push(FaultEvent { round, machine, kind });
    }

    /// Seeded random kill schedule: each (round, machine) pair in
    /// `1..rounds` x `0..machines` is killed independently with probability
    /// `kill_rate`. Round 0 is spared so a job can land its initial
    /// broadcast / checkpoint before the first crash. `restart_after == 0`
    /// makes kills permanent; otherwise machines rejoin after that many
    /// rounds. Identical seeds yield identical schedules.
    pub fn random(
        seed: u64,
        machines: usize,
        rounds: usize,
        kill_rate: f64,
        restart_after: usize,
    ) -> FaultPlan {
        let plan = FaultPlan::new();
        let mut rng = Rng::new(seed).split(0x666175_6c74); // "fault"
        let kind = if restart_after == 0 {
            FaultKind::Permanent
        } else {
            FaultKind::Crash { restart_after }
        };
        for round in 1..rounds {
            for machine in 0..machines {
                if rng.f64() < kill_rate {
                    plan.kill_at(round, machine, kind);
                }
            }
        }
        plan
    }

    /// Drain and return every event due at or before `round`, in schedule
    /// order. Called by the cluster once per `begin_round`.
    pub fn take_due(&self, round: usize) -> Vec<FaultEvent> {
        let mut events = lock_unpoisoned(&self.events);
        let mut due = Vec::new();
        let mut i = 0;
        while i < events.len() {
            if events[i].round <= round {
                due.push(events.remove(i));
            } else {
                i += 1;
            }
        }
        due
    }

    /// Events not yet applied.
    pub fn remaining(&self) -> usize {
        lock_unpoisoned(&self.events).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_due_drains_in_schedule_order() {
        let p = FaultPlan::new();
        p.kill_at(2, 0, FaultKind::Permanent);
        p.kill_at(1, 3, FaultKind::Crash { restart_after: 2 });
        p.kill_at(1, 1, FaultKind::Permanent);
        assert_eq!(p.take_due(0), vec![]);
        let due = p.take_due(1);
        assert_eq!(due.len(), 2);
        assert_eq!((due[0].machine, due[1].machine), (3, 1));
        assert_eq!(p.remaining(), 1);
        assert_eq!(p.take_due(5).len(), 1);
        assert_eq!(p.remaining(), 0);
    }

    #[test]
    fn random_schedule_is_seed_deterministic() {
        let a = FaultPlan::random(7, 8, 10, 0.2, 2);
        let b = FaultPlan::random(7, 8, 10, 0.2, 2);
        assert_eq!(a.take_due(usize::MAX), b.take_due(usize::MAX));
        // a different seed gives a different schedule (overwhelmingly)
        let c = FaultPlan::random(8, 8, 10, 0.2, 2);
        let d = FaultPlan::random(7, 8, 10, 0.2, 2);
        assert_ne!(c.take_due(usize::MAX), d.take_due(usize::MAX));
    }

    #[test]
    fn random_spares_round_zero_and_respects_rate() {
        let p = FaultPlan::random(42, 4, 50, 0.5, 0);
        let events = p.take_due(usize::MAX);
        assert!(events.iter().all(|e| e.round >= 1));
        assert!(events.iter().all(|e| e.kind == FaultKind::Permanent));
        assert!(!events.is_empty());
        let zero = FaultPlan::random(42, 4, 50, 0.0, 0);
        assert_eq!(zero.remaining(), 0);
    }
}
