//! Communication topologies and their aggregate/broadcast cost formulas.
//!
//! This module encodes the paper's own comparison (§IV-A Implementation):
//! MLI averages parameters *at the master* and broadcasts one-to-many
//! (star), while VW builds a binary **AllReduce tree** — "theoretically
//! more efficient from the perspective of communication". The ablation
//! bench `ablation_comm` regenerates exactly that trade-off.

use super::network::NetworkModel;

/// How model state is combined across machines each round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommTopology {
    /// MLI/Spark: workers send to master (gather), master sends back
    /// (one-to-many broadcast). Master NIC serializes both directions.
    StarGatherBroadcast,
    /// VW: binary aggregation tree; combine up, broadcast down the same
    /// tree. Latency x 2 log2(M), each link carries the full vector.
    AllReduceTree,
    /// GraphLab-style peer-to-peer: no global aggregate; cost charged
    /// per-message by the caller. `aggregate_time` here models a
    /// bulk-synchronous barrier exchange of equal-size messages.
    PeerToPeer,
}

impl CommTopology {
    /// Time for every machine to contribute `bytes` of state and receive
    /// the combined `bytes` back (one model-average round).
    pub fn allreduce_time(&self, net: &NetworkModel, machines: usize, bytes: u64) -> f64 {
        if machines <= 1 {
            return 0.0;
        }
        let m = machines as f64;
        match self {
            CommTopology::StarGatherBroadcast => {
                // gather: master receives (M-1) messages serially on its NIC
                let gather = net.latency_s + (m - 1.0) * bytes as f64 / net.bandwidth_bps;
                // broadcast: master sends (M-1) copies serially
                let bcast = net.latency_s + (m - 1.0) * bytes as f64 / net.bandwidth_bps;
                gather + bcast
            }
            CommTopology::AllReduceTree => {
                // up + down a binary tree: 2*ceil(log2 M) hops, each hop
                // latency + payload; interior nodes pipeline siblings (2
                // children per node => 2x payload per hop up).
                let hops = (m.log2().ceil()).max(1.0);
                2.0 * hops * (net.latency_s + 2.0 * bytes as f64 / net.bandwidth_bps)
            }
            CommTopology::PeerToPeer => {
                // bulk-synchronous neighbor exchange: each machine sends and
                // receives `bytes` concurrently; NICs are independent.
                net.latency_s + bytes as f64 / net.bandwidth_bps
            }
        }
    }

    /// One-to-many broadcast of `bytes` from the master (e.g. initial
    /// model shipping, ALS factor broadcast).
    pub fn broadcast_time(&self, net: &NetworkModel, machines: usize, bytes: u64) -> f64 {
        if machines <= 1 {
            return 0.0;
        }
        let m = machines as f64;
        match self {
            CommTopology::StarGatherBroadcast => {
                net.latency_s + (m - 1.0) * bytes as f64 / net.bandwidth_bps
            }
            CommTopology::AllReduceTree | CommTopology::PeerToPeer => {
                // tree broadcast: log2(M) pipelined hops
                let hops = (m.log2().ceil()).max(1.0);
                hops * (net.latency_s + bytes as f64 / net.bandwidth_bps)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net() -> NetworkModel {
        NetworkModel::ec2_2013()
    }

    #[test]
    fn single_machine_is_free() {
        for t in [
            CommTopology::StarGatherBroadcast,
            CommTopology::AllReduceTree,
            CommTopology::PeerToPeer,
        ] {
            assert_eq!(t.allreduce_time(&net(), 1, 1 << 20), 0.0);
            assert_eq!(t.broadcast_time(&net(), 1, 1 << 20), 0.0);
        }
    }

    #[test]
    fn star_scales_linearly_tree_logarithmically() {
        let n = net();
        let bytes = 4 * 640_000; // a 640K-float model (paper: d=160K x4 nodes avg)
        let star_8 = CommTopology::StarGatherBroadcast.allreduce_time(&n, 8, bytes);
        let star_32 = CommTopology::StarGatherBroadcast.allreduce_time(&n, 32, bytes);
        let tree_8 = CommTopology::AllReduceTree.allreduce_time(&n, 8, bytes);
        let tree_32 = CommTopology::AllReduceTree.allreduce_time(&n, 32, bytes);
        // star grows ~4x from 8->32 machines; tree grows ~5/3
        assert!(star_32 / star_8 > 3.5);
        assert!(tree_32 / tree_8 < 2.0);
        // at 32 machines with a large model the tree must win
        assert!(tree_32 < star_32);
    }

    #[test]
    fn star_beats_tree_for_small_messages_few_machines() {
        // latency-dominated regime: the tree pays 2*log2(M) latencies,
        // the star pays 2. This is the paper's observed "MLI scales fine
        // in practice" region.
        let n = net();
        let star = CommTopology::StarGatherBroadcast.allreduce_time(&n, 4, 64);
        let tree = CommTopology::AllReduceTree.allreduce_time(&n, 4, 64);
        assert!(star < tree);
    }

    #[test]
    fn monotone_in_machines_and_bytes() {
        let n = net();
        for t in [
            CommTopology::StarGatherBroadcast,
            CommTopology::AllReduceTree,
            CommTopology::PeerToPeer,
        ] {
            assert!(t.allreduce_time(&n, 4, 1000) <= t.allreduce_time(&n, 16, 1000));
            assert!(t.allreduce_time(&n, 4, 1000) <= t.allreduce_time(&n, 4, 100_000));
            assert!(t.broadcast_time(&n, 2, 10) > 0.0);
        }
    }
}
