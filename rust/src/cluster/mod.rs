//! Simulated cluster: machines + network cost model + simulated-time
//! ledger.
//!
//! The paper's experiments ran on 1–32 EC2 m2.4xlarge nodes. This sandbox
//! has one core, so multi-node *walltime* is reconstructed rather than
//! measured (DESIGN.md §3): per-partition compute is **really executed and
//! really timed** on the host, and communication is **charged analytically**
//! from message sizes and the system's topology (star gather/broadcast for
//! MLI, AllReduce tree for VW, peer-to-peer for GraphLab, HDFS disk for
//! Mahout). Simulated time for a round is
//!
//! ```text
//! round = max_over_machines(compute) * compute_factor + comm(topology, bytes)
//! ```
//!
//! which is exactly the bulk-synchronous model the paper's systems follow.
//! Scaling *shape* therefore emerges from measured compute + modelled
//! communication, not from hard-coded curves.

pub mod fault;
pub mod machine;
pub mod netfault;
pub mod network;
pub mod sim;
pub mod topology;

pub use fault::{FaultEvent, FaultKind, FaultPlan};
pub use machine::MachineSpec;
pub use netfault::{
    LinkState, NetChaosConfig, NetFaultEvent, NetFaultKind, NetFaultPlan, NetStats,
    PartitionPolicy,
};
pub use network::NetworkModel;
pub use sim::{RoundStats, SimCluster, SimLedger, StragglerModel};
pub use topology::CommTopology;
