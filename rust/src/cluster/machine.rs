//! Machine specifications for the simulated cluster.

/// One simulated machine (modelled on the paper's EC2 m2.4xlarge fleet,
/// scaled down so workloads fit this sandbox).
#[derive(Debug, Clone)]
pub struct MachineSpec {
    /// Virtual cores (m2.4xlarge: 8). Tasks on the same machine run
    /// `min(cores, tasks)`-way parallel in the time model.
    pub cores: usize,
    /// Memory capacity in bytes. Exceeding it raises a simulated OOM —
    /// this is how the paper's "MATLAB runs out of memory at 16x/25x
    /// Netflix" reproduces.
    pub mem_bytes: u64,
    /// Multiplier applied to *measured* compute seconds to model a
    /// system's constant factor relative to this crate's rust hot path
    /// (e.g. the paper's JVM/Scala MLI vs C++ VW gap). 1.0 = as measured.
    pub compute_factor: f64,
}

impl MachineSpec {
    /// The paper's m2.4xlarge: 8 vcores, 68 GB. Memory is scaled by
    /// `mem_scale` because our datasets are ~1000x smaller than the
    /// paper's 200 GB ImageNet run (DESIGN.md §3).
    pub fn m2_4xlarge(mem_scale: f64) -> MachineSpec {
        MachineSpec {
            cores: 8,
            mem_bytes: (68.0 * 1e9 * mem_scale) as u64,
            compute_factor: 1.0,
        }
    }

    pub fn with_compute_factor(mut self, f: f64) -> MachineSpec {
        self.compute_factor = f;
        self
    }

    pub fn with_mem_bytes(mut self, b: u64) -> MachineSpec {
        self.mem_bytes = b;
        self
    }
}

impl Default for MachineSpec {
    fn default() -> Self {
        MachineSpec::m2_4xlarge(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m2_defaults() {
        let m = MachineSpec::m2_4xlarge(1.0);
        assert_eq!(m.cores, 8);
        assert_eq!(m.mem_bytes, 68_000_000_000);
        assert_eq!(m.compute_factor, 1.0);
    }

    #[test]
    fn builders() {
        let m = MachineSpec::default()
            .with_compute_factor(0.65)
            .with_mem_bytes(1024);
        assert_eq!(m.compute_factor, 0.65);
        assert_eq!(m.mem_bytes, 1024);
    }

    #[test]
    fn mem_scaling() {
        let m = MachineSpec::m2_4xlarge(0.001);
        assert_eq!(m.mem_bytes, 68_000_000);
    }
}
