//! SimCluster: the simulated-time ledger that turns really-measured
//! per-partition compute plus analytically-charged communication into
//! per-round and total walltime estimates.
//!
//! Usage pattern (bulk-synchronous, as all of the paper's systems are):
//!
//! ```text
//! let cluster = SimCluster::new(32, MachineSpec::default(), NetworkModel::default());
//! for round in 0..iters {
//!     cluster.begin_round();
//!     for (p, task) in partitions { cluster.run_task(machine_of(p), || compute(p)); }
//!     cluster.charge_allreduce(CommTopology::StarGatherBroadcast, model_bytes);
//!     cluster.end_round();
//! }
//! let t = cluster.total_sim_seconds();
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use std::time::Duration;

use super::fault::{FaultKind, FaultPlan};
use super::machine::MachineSpec;
use super::netfault::{
    msg_roll, LinkState, NetFaultKind, NetFaultPlan, NetStats, PartitionPolicy, ROLL_DROP,
    ROLL_DUP,
};
use super::network::NetworkModel;
use super::topology::CommTopology;
use crate::engine::RetryPolicy;
use crate::error::{Error, Result};
use crate::exec::{lock_unpoisoned, ThreadPool};
use crate::trace::Tracer;
use crate::util::lockdep::TrackedMutex;
use crate::util::timer::Stopwatch;

/// A message's delivery timeout is this many multiples of its (degraded)
/// one-way time: the sender declares a drop after the ack window passes
/// and either backs off and retries or gives up under its `RetryPolicy`.
const NET_TIMEOUT_FACTOR: f64 = 4.0;

/// Per-round accounting.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    /// Per-machine accumulated compute seconds this round (after
    /// compute_factor and core-parallelism adjustment).
    pub machine_compute_s: Vec<f64>,
    /// Tasks executed per machine this round (for the parallelism model).
    pub machine_tasks: Vec<usize>,
    /// Communication seconds charged this round.
    pub comm_s: f64,
    /// Disk seconds charged this round (HDFS surrogate).
    pub disk_s: f64,
    /// Bytes moved over the network this round.
    pub net_bytes: u64,
    /// Individual (machine, seconds) task charges this round, kept so the
    /// speculative-execution model can find per-task stragglers (the
    /// per-machine sums above can't distinguish one slow task from many
    /// fast ones).
    pub task_times: Vec<(usize, f64)>,
    /// Logical messages allocated this round (per-round sequence counter;
    /// the (round, message id) pair seeds each message's fault rolls).
    pub net_msgs: u64,
}

impl RoundStats {
    fn new(machines: usize) -> RoundStats {
        RoundStats {
            machine_compute_s: vec![0.0; machines],
            machine_tasks: vec![0; machines],
            ..Default::default()
        }
    }

    /// Per-machine effective compute seconds this round.
    fn machine_times(&self, specs: &[MachineSpec]) -> Vec<f64> {
        self.machine_compute_s
            .iter()
            .zip(self.machine_tasks.iter())
            .zip(specs.iter())
            .map(|((&secs, &tasks), spec)| {
                // tasks on one machine run min(cores, tasks)-way parallel
                let par = spec.cores.min(tasks.max(1)) as f64;
                secs * spec.compute_factor / par
            })
            .collect()
    }

    /// The bulk-synchronous round time: slowest machine + comm + disk.
    pub fn round_time(&self, specs: &[MachineSpec]) -> f64 {
        self.round_time_with(specs, StragglerModel::Max)
    }

    /// Round time under a chosen straggler model.
    pub fn round_time_with(&self, specs: &[MachineSpec], s: StragglerModel) -> f64 {
        let times = self.machine_times(specs);
        let compute = match s {
            StragglerModel::Max => times.iter().fold(0.0f64, |a, &b| a.max(b)),
            StragglerModel::Median => {
                let active: Vec<f64> = times.iter().copied().filter(|&t| t > 0.0).collect();
                crate::util::median(&active)
            }
        };
        compute + self.comm_s + self.disk_s
    }
}

/// How the bulk-synchronous barrier treats per-machine compute spread.
///
/// `Max` is the true BSP semantics (slowest machine gates the round).
/// `Median` models a *homogeneous* fleet: on this 1-core host all
/// "machines" share one core, so the empirical max is contaminated by
/// host noise (page cache, allocator, XLA thread pool) that real,
/// independent machines would not correlate on. Benches over homogeneous
/// synthetic partitions use `Median`; heterogeneity experiments use `Max`.
/// (DESIGN.md §3.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StragglerModel {
    Max,
    Median,
}

/// The running ledger of simulated time.
#[derive(Debug, Default)]
pub struct SimLedger {
    pub total_s: f64,
    pub total_comm_s: f64,
    pub total_disk_s: f64,
    pub total_net_bytes: u64,
    pub rounds: usize,
    current: Option<RoundStats>,
    /// Wall-clock stopwatch for the open round (trace attribution only;
    /// simulated time never reads it).
    round_wall: Option<Stopwatch>,
    /// Per-machine resident bytes (simulated memory accounting).
    pub resident_bytes: Vec<u64>,
    /// Speculative task copies launched / won across all rounds (the
    /// analytic straggler-mitigation model; see `with_speculation`).
    pub spec_launched: u64,
    pub spec_wins: u64,
}

/// Health of one simulated machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MachineHealth {
    Up,
    /// Down until round `until` (crash with restart), or forever (`None`).
    Down { until: Option<usize> },
}

/// Callback invoked with the machine index when a machine dies, so
/// engine-level state (cached partitions resident there) can be
/// invalidated. See `Dataset::bind_cluster`.
type LossListener = Box<dyn Fn(usize) + Send + Sync>;

/// Link-fault state for the open round (network-failure model). The
/// `windows` vec holds `(close_round_exclusive, kind)` for every window
/// still open; `link` is the per-round snapshot rebuilt from it at each
/// `begin_round` and cloned out of the lock by the send path.
struct NetState {
    seed: u64,
    windows: Vec<(usize, NetFaultKind)>,
    link: LinkState,
    policy: PartitionPolicy,
    retry: RetryPolicy,
}

/// Per-call message accounting, flushed into the cluster's atomics (and
/// the tracer) once per logical collective/transfer rather than per
/// message, so counter updates stay race-free under concurrent charges.
#[derive(Debug, Clone, Copy, Default)]
struct SendTally {
    sends: u64,
    drops: u64,
    retries: u64,
    dups: u64,
    partition_waits: u64,
}

/// A simulated cluster: machine fleet + network + time ledger.
///
/// Interior mutability is mutex-guarded (`Send + Sync`) so that tasks
/// running concurrently on the `exec` thread pool can record compute time
/// into the ledger; charges are commutative sums, so simulated time is
/// independent of the host thread count.
pub struct SimCluster {
    pub specs: Vec<MachineSpec>,
    pub net: NetworkModel,
    pub straggler: Mutex<StragglerModel>,
    ledger: Mutex<SimLedger>,
    executor: Mutex<Option<Arc<ThreadPool>>>,
    tracer: Mutex<Arc<Tracer>>,
    /// Per-machine up/down state (node-failure model).
    health: Mutex<Vec<MachineHealth>>,
    /// Scheduled machine kills, drained at round boundaries.
    faults: Mutex<Option<Arc<FaultPlan>>>,
    /// Machine-loss callbacks (cache invalidation hooks).
    loss_listeners: Mutex<Vec<LossListener>>,
    /// Speculative-execution threshold k: a task taking >= k x the round
    /// median gets a simulated backup copy. `None` disables.
    speculation: Mutex<Option<f64>>,
    fault_kills: AtomicU64,
    fault_restarts: AtomicU64,
    /// Link-fault state for the open round (`net` stays the healthy
    /// analytic model; this layers per-round drop/dup/degrade/partition
    /// windows on top of it).
    netstate: TrackedMutex<NetState>,
    /// Scheduled link faults, drained at round boundaries alongside
    /// `faults`.
    netfaults: Mutex<Option<Arc<NetFaultPlan>>>,
    net_sends: AtomicU64,
    net_drops: AtomicU64,
    net_retries: AtomicU64,
    net_dups: AtomicU64,
    net_partition_waits: AtomicU64,
    net_replacements: AtomicU64,
}

impl SimCluster {
    pub fn new(machines: usize, spec: MachineSpec, net: NetworkModel) -> SimCluster {
        assert!(machines > 0, "cluster needs >= 1 machine");
        let mut ledger = SimLedger::default();
        ledger.resident_bytes = vec![0; machines];
        SimCluster {
            specs: vec![spec; machines],
            net,
            straggler: Mutex::new(StragglerModel::Max),
            ledger: Mutex::new(ledger),
            executor: Mutex::new(None),
            tracer: Mutex::new(Tracer::disabled()),
            health: Mutex::new(vec![MachineHealth::Up; machines]),
            faults: Mutex::new(None),
            loss_listeners: Mutex::new(Vec::new()),
            speculation: Mutex::new(None),
            fault_kills: AtomicU64::new(0),
            fault_restarts: AtomicU64::new(0),
            netstate: TrackedMutex::new(
                "sim.netstate",
                NetState {
                    seed: 0,
                    windows: Vec::new(),
                    link: LinkState::inactive(machines),
                    policy: PartitionPolicy::default(),
                    // messages are cheap to retry compared to recomputing a
                    // partition, so the per-message budget allows far more
                    // attempts than the task-level default of 4
                    retry: RetryPolicy {
                        max_attempts: 16,
                        ..RetryPolicy::default()
                    },
                },
            ),
            netfaults: Mutex::new(None),
            net_sends: AtomicU64::new(0),
            net_drops: AtomicU64::new(0),
            net_retries: AtomicU64::new(0),
            net_dups: AtomicU64::new(0),
            net_partition_waits: AtomicU64::new(0),
            net_replacements: AtomicU64::new(0),
        }
    }

    /// Homogeneous fleet, default EC2 specs (the common case in benches).
    pub fn ec2(machines: usize) -> SimCluster {
        SimCluster::new(machines, MachineSpec::default(), NetworkModel::ec2_2013())
    }

    pub fn num_machines(&self) -> usize {
        self.specs.len()
    }

    /// Machine owning partition `p` under round-robin placement. This is
    /// the *primary* (failure-oblivious) placement; schedulers should use
    /// [`SimCluster::assign_machine`], which re-routes around dead nodes.
    pub fn machine_of(&self, partition: usize) -> usize {
        partition % self.specs.len()
    }

    // -- node-failure model ----------------------------------------------

    /// Failure-aware placement: partition `p`'s primary machine when it
    /// is alive, otherwise the first alive machine scanning up from the
    /// primary. Under [`PartitionPolicy::Replace`] with an active network
    /// partition, machines cut off from the master's side are skipped the
    /// same way dead ones are (they're unreachable, so placing work there
    /// would stall the round). The fallback is a pure function of
    /// (partition, health vector, link state), so re-assignment is
    /// deterministic for any host thread count. Errors with
    /// [`Error::FaultRecovery`] when the whole fleet is down, and with
    /// [`Error::NetFault`] when machines are alive but all behind the cut.
    pub fn assign_machine(&self, partition: usize) -> Result<usize> {
        let n = self.specs.len();
        let primary = partition % n;
        // snapshot the cut (if any) before taking the health lock; the
        // two locks are never held together
        let unreachable: Option<Vec<bool>> = {
            let ns = self.netstate.lock();
            if ns.policy == PartitionPolicy::Replace && ns.link.is_active() {
                Some((0..n).map(|m| !ns.link.same_side_as_master(m)).collect())
            } else {
                None
            }
        };
        let (chosen, primary_up, alive_but_cut) = {
            let h = lock_unpoisoned(&self.health);
            let mut chosen = None;
            let mut alive_but_cut = false;
            for k in 0..n {
                let m = (primary + k) % n;
                if h[m] != MachineHealth::Up {
                    continue;
                }
                match &unreachable {
                    Some(cut) if cut[m] => alive_but_cut = true,
                    _ => {
                        chosen = Some(m);
                        break;
                    }
                }
            }
            (chosen, h[primary] == MachineHealth::Up, alive_but_cut)
        };
        if let Some(m) = chosen {
            // re-routed off an alive-but-unreachable primary: that's a
            // network replacement, not a node-fault one
            if m != primary
                && primary_up
                && unreachable.as_ref().is_some_and(|cut| cut[primary])
            {
                self.net_replacements.fetch_add(1, Ordering::Relaxed);
            }
            return Ok(m);
        }
        if alive_but_cut {
            return Err(Error::NetFault(format!(
                "no reachable machine for partition {partition}: every alive \
                 machine is behind the active network partition"
            )));
        }
        Err(Error::FaultRecovery(format!(
            "no machine alive to place partition {partition} (all {n} down)"
        )))
    }

    pub fn is_up(&self, machine: usize) -> bool {
        lock_unpoisoned(&self.health)[machine] == MachineHealth::Up
    }

    pub fn num_alive(&self) -> usize {
        lock_unpoisoned(&self.health)
            .iter()
            .filter(|h| **h == MachineHealth::Up)
            .count()
    }

    /// Attach a [`FaultPlan`]; due kills are applied at each
    /// `begin_round`, before any work of that round runs.
    pub fn with_faults(self, plan: Arc<FaultPlan>) -> SimCluster {
        *lock_unpoisoned(&self.faults) = Some(plan);
        self
    }

    /// Enable the speculative-execution model: any task whose charged
    /// time is >= `k` x the round median gets a simulated backup copy on
    /// the least-loaded alive machine, and the round is gated by whichever
    /// copy finishes first (see `apply_speculation`). Mirrors Spark's
    /// `spark.speculation.multiplier`.
    pub fn with_speculation(self, k: f64) -> SimCluster {
        assert!(k > 1.0, "speculation threshold must exceed 1.0");
        *lock_unpoisoned(&self.speculation) = Some(k);
        self
    }

    pub fn speculation(&self) -> Option<f64> {
        *lock_unpoisoned(&self.speculation)
    }

    /// Register a machine-loss callback, invoked with the machine index
    /// whenever a machine dies (scheduled or manual). Listeners run after
    /// the cluster has dropped the machine's resident bytes; they are the
    /// hook by which cached dataset partitions placed there are
    /// invalidated (`Dataset::bind_cluster`). Permanent for the cluster's
    /// lifetime.
    pub fn on_machine_loss(&self, f: impl Fn(usize) + Send + Sync + 'static) {
        lock_unpoisoned(&self.loss_listeners).push(Box::new(f));
    }

    /// (kills, restarts) applied so far.
    pub fn fault_stats(&self) -> (u64, u64) {
        (
            self.fault_kills.load(Ordering::Relaxed),
            self.fault_restarts.load(Ordering::Relaxed),
        )
    }

    /// (speculative copies launched, copies that beat the original) so far.
    pub fn speculation_stats(&self) -> (u64, u64) {
        let l = lock_unpoisoned(&self.ledger);
        (l.spec_launched, l.spec_wins)
    }

    /// Kill `machine` now: mark it down (until `restart_round`, forever
    /// for `None`), drop its resident bytes, charge the open round an
    /// HDFS re-read of those bytes (survivors must re-fetch the dead
    /// node's input shards from stable storage before recomputing), and
    /// notify loss listeners. Returns the lost bytes; no-op (0) when the
    /// machine is already down.
    pub fn kill_machine(&self, machine: usize, restart_round: Option<usize>) -> u64 {
        {
            let mut h = lock_unpoisoned(&self.health);
            if h[machine] != MachineHealth::Up {
                return 0;
            }
            h[machine] = MachineHealth::Down { until: restart_round };
        }
        let lost = {
            let mut l = lock_unpoisoned(&self.ledger);
            let lost = std::mem::take(&mut l.resident_bytes[machine]);
            if lost > 0 {
                if let Some(cur) = l.current.as_mut() {
                    cur.disk_s += self.net.hdfs_read_time(lost);
                }
            }
            lost
        };
        self.fault_kills.fetch_add(1, Ordering::Relaxed);
        {
            let listeners = lock_unpoisoned(&self.loss_listeners);
            for f in listeners.iter() {
                f(machine);
            }
        }
        let tracer = self.tracer();
        if let Some(t0) = tracer.start() {
            tracer.span(
                format!("fault:kill-machine-{machine}"),
                "fault",
                0,
                t0,
                &[("lost_bytes", lost as f64)],
            );
            tracer.count("fault.kills", 1);
        }
        lost
    }

    /// Bring a dead machine back (empty: its cached state died with it).
    pub fn restore_machine(&self, machine: usize) {
        let mut h = lock_unpoisoned(&self.health);
        if h[machine] != MachineHealth::Up {
            h[machine] = MachineHealth::Up;
            drop(h);
            self.fault_restarts.fetch_add(1, Ordering::Relaxed);
            let tracer = self.tracer();
            if tracer.is_enabled() {
                tracer.count("fault.restarts", 1);
            }
        }
    }

    /// Apply the fault schedule at a round boundary: restart machines
    /// whose crash delay has elapsed, then fire kills due this round.
    fn apply_due_faults(&self, round: usize) {
        let restart: Vec<usize> = {
            let h = lock_unpoisoned(&self.health);
            h.iter()
                .enumerate()
                .filter_map(|(m, s)| match s {
                    MachineHealth::Down { until: Some(u) } if round >= *u => Some(m),
                    _ => None,
                })
                .collect()
        };
        for m in restart {
            self.restore_machine(m);
        }
        let plan = lock_unpoisoned(&self.faults).clone();
        if let Some(plan) = plan {
            for ev in plan.take_due(round) {
                let restart_round = match ev.kind {
                    FaultKind::Crash { restart_after } => Some(round + restart_after.max(1)),
                    FaultKind::Permanent => None,
                };
                self.kill_machine(ev.machine, restart_round);
            }
        }
    }

    /// The analytic speculative-execution model, applied when a round
    /// closes: any task charged >= `k` x the round's median task time is
    /// assumed to have had a backup copy launched at `k x median` on the
    /// least-loaded alive machine (replaying at median speed). If the
    /// backup would finish first — at `(k + 1) x median` — the straggling
    /// machine is only gated until then and the backup's cost lands on
    /// its host. Candidates are processed in a canonical order so the
    /// rebalanced ledger is identical for any host thread count. Returns
    /// (copies launched, copies that won).
    fn apply_speculation(cur: &mut RoundStats, k: f64, alive: &[bool]) -> (u64, u64) {
        if cur.task_times.len() < 2 {
            return (0, 0);
        }
        let times: Vec<f64> = cur.task_times.iter().map(|&(_, t)| t).collect();
        let med = crate::util::median(&times);
        if med <= 0.0 {
            return (0, 0);
        }
        let mut candidates: Vec<(usize, f64)> = cur
            .task_times
            .iter()
            .copied()
            .filter(|&(_, t)| t >= k * med)
            .collect();
        candidates.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.total_cmp(&a.1)));
        let mut launched = 0u64;
        let mut wins = 0u64;
        for (m, t) in candidates {
            // backup host: least-loaded alive machine other than the
            // straggler's own (ties broken by lowest index)
            let backup = cur
                .machine_compute_s
                .iter()
                .enumerate()
                .filter(|&(b, _)| b != m && alive.get(b).copied().unwrap_or(false))
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(b, _)| b);
            let Some(backup) = backup else { continue };
            launched += 1;
            let backup_finish = (k + 1.0) * med;
            if backup_finish < t {
                wins += 1;
                cur.machine_compute_s[m] -= t - backup_finish;
                cur.machine_compute_s[backup] += med;
                cur.machine_tasks[backup] += 1;
            }
        }
        (launched, wins)
    }

    // -- network-failure model --------------------------------------------

    /// Attach a [`NetFaultPlan`]; due link-fault windows open at each
    /// `begin_round` (alongside `with_faults` node kills) and the plan's
    /// seed drives every per-message drop/duplicate roll.
    pub fn with_netfaults(self, plan: Arc<NetFaultPlan>) -> SimCluster {
        self.netstate.lock().seed = plan.seed();
        *lock_unpoisoned(&self.netfaults) = Some(plan);
        self
    }

    /// Choose what senders do when a partition cuts them off from their
    /// destination (default [`PartitionPolicy::WaitOut`]).
    pub fn with_partition_policy(self, p: PartitionPolicy) -> SimCluster {
        self.netstate.lock().policy = p;
        self
    }

    pub fn partition_policy(&self) -> PartitionPolicy {
        self.netstate.lock().policy
    }

    /// Swap the per-message retry policy (attempts / backoff / timeout
    /// budget, all in simulated seconds on this path). The default allows
    /// 16 attempts — messages are cheap to retry compared to tasks.
    pub fn set_net_retry_policy(&self, r: RetryPolicy) {
        self.netstate.lock().retry = r;
    }

    /// Message-level accounting so far (drops, retries, duplicates,
    /// partition waits/replacements).
    pub fn net_stats(&self) -> NetStats {
        NetStats {
            sends: self.net_sends.load(Ordering::Relaxed),
            drops: self.net_drops.load(Ordering::Relaxed),
            retries: self.net_retries.load(Ordering::Relaxed),
            dups: self.net_dups.load(Ordering::Relaxed),
            partition_waits: self.net_partition_waits.load(Ordering::Relaxed),
            replacements: self.net_replacements.load(Ordering::Relaxed),
        }
    }

    /// Apply the link-fault schedule at a round boundary: expire windows
    /// that have healed, open windows due this round, and rebuild the
    /// per-round [`LinkState`] snapshot the send path reads.
    fn apply_due_netfaults(&self, round: usize) {
        let plan = lock_unpoisoned(&self.netfaults).clone();
        let machines = self.specs.len();
        let opened: Vec<&'static str> = {
            let mut ns = self.netstate.lock();
            ns.windows.retain(|(until, _)| *until > round);
            let mut opened = Vec::new();
            if let Some(plan) = &plan {
                for ev in plan.take_due(round) {
                    opened.push(ev.kind.label());
                    ns.windows.push((round + ev.rounds.max(1), ev.kind));
                }
            }
            if ns.windows.is_empty() && !ns.link.is_active() && opened.is_empty() {
                return; // steady healthy state: skip the rebuild
            }
            ns.link = LinkState::build(ns.seed, machines, round, &ns.windows);
            opened
        };
        // spans are emitted after the netstate lock is dropped
        let tracer = self.tracer();
        if tracer.is_enabled() {
            for label in opened {
                if let Some(t0) = tracer.start() {
                    tracer.span(
                        format!("netfault:{label}-round-{round}"),
                        "fault",
                        0,
                        t0,
                        &[],
                    );
                }
                tracer.count("net.windows", 1);
            }
        }
    }

    /// Clone the send path's inputs out of the netstate lock (never held
    /// across a charge).
    fn net_snapshot(&self) -> (LinkState, RetryPolicy, PartitionPolicy) {
        let ns = self.netstate.lock();
        (ns.link.clone(), ns.retry, ns.policy)
    }

    /// Allocate `n` message ids in the open round's sequence; the
    /// (round, id) pair makes every message's fault rolls unique and
    /// deterministic. Charges are driver-side and sequential, so ids are
    /// stable for any host thread count.
    fn reserve_msgs(&self, n: u64) -> Result<u64> {
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l
            .current
            .as_mut()
            .ok_or_else(|| Error::Engine("net transfer outside an open round".into()))?;
        let base = cur.net_msgs;
        cur.net_msgs += n;
        Ok(base)
    }

    /// Charge `secs` of communication and `bytes` moved to the open round.
    fn charge_net(&self, secs: f64, bytes: u64) -> Result<()> {
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l
            .current
            .as_mut()
            .ok_or_else(|| Error::Engine("net transfer outside an open round".into()))?;
        cur.comm_s += secs;
        cur.net_bytes += bytes;
        Ok(())
    }

    /// Flush a call's message tally into the run totals and the tracer.
    fn flush_tally(&self, t: SendTally) {
        self.net_sends.fetch_add(t.sends, Ordering::Relaxed);
        self.net_drops.fetch_add(t.drops, Ordering::Relaxed);
        self.net_retries.fetch_add(t.retries, Ordering::Relaxed);
        self.net_dups.fetch_add(t.dups, Ordering::Relaxed);
        self.net_partition_waits
            .fetch_add(t.partition_waits, Ordering::Relaxed);
        let tracer = self.tracer();
        if tracer.is_enabled() {
            if t.sends > 0 {
                tracer.count("net.sends", t.sends);
            }
            if t.drops > 0 {
                tracer.count("net.drops", t.drops);
            }
            if t.retries > 0 {
                tracer.count("net.retries", t.retries);
            }
            if t.dups > 0 {
                tracer.count("net.dups", t.dups);
            }
            if t.partition_waits > 0 {
                tracer.count("net.partition.waits", t.partition_waits);
            }
        }
    }

    /// Deliver one logical message over the faulted link model. Returns
    /// the simulated seconds charged and the bytes that crossed the wire
    /// (duplicates pay twice). Faults only ever move *time* and counters —
    /// never payloads — so results stay bitwise-identical to the healthy
    /// run whenever every message eventually lands.
    #[allow(clippy::too_many_arguments)]
    fn send_one(
        &self,
        ls: &LinkState,
        retry: &RetryPolicy,
        policy: PartitionPolicy,
        src: usize,
        dst: usize,
        bytes: u64,
        msg: u64,
        tally: &mut SendTally,
    ) -> Result<(f64, u64)> {
        tally.sends += 1;
        let q = ls.quality(src, dst);
        // one-way time over the (possibly degraded) link, and the ack
        // window after which the sender declares the attempt lost
        let one = self.net.msg_time_scaled(bytes, q.latency_x, q.bandwidth_div);
        let timeout = one * NET_TIMEOUT_FACTOR;
        let mut secs = 0.0;
        let mut moved = 0u64;
        if ls.partitioned(src, dst) {
            match policy {
                PartitionPolicy::Replace => {
                    return Err(Error::NetFault(format!(
                        "partition: {src}->{dst} is cut for {} more round(s)",
                        ls.heal_in.max(1)
                    )));
                }
                PartitionPolicy::WaitOut => {
                    // the cut outlives any retry budget; the sender blocks
                    // until the window heals, probing once per remaining
                    // round, then delivers below
                    secs += ls.heal_in.max(1) as f64 * timeout;
                    tally.partition_waits += 1;
                }
            }
        }
        let mut attempt = 1usize;
        loop {
            if msg_roll(ls.seed(), ls.round, msg, attempt, ROLL_DROP) >= q.drop_p {
                // delivered: charge the transfer; a duplicate delivery
                // consumes the link a second time but is deduped by the
                // receiver (values never change)
                secs += one;
                moved += bytes;
                if msg_roll(ls.seed(), ls.round, msg, attempt, ROLL_DUP) < q.dup_p {
                    secs += one;
                    moved += bytes;
                    tally.dups += 1;
                }
                return Ok((secs, moved));
            }
            // lost: the sender burns the ack window discovering it
            tally.drops += 1;
            secs += timeout;
            match retry.next_backoff(attempt, Duration::from_secs_f64(secs)) {
                Some(backoff) => {
                    secs += backoff.as_secs_f64();
                    tally.retries += 1;
                    attempt += 1;
                }
                None => {
                    return Err(Error::NetFault(format!(
                        "message {msg} ({src}->{dst}, {bytes} B) dropped \
                         {attempt} time(s); retry budget exhausted \
                         (drop_p={:.2}, round {})",
                        q.drop_p, ls.round
                    )));
                }
            }
        }
    }

    /// Master broadcast through the fault layer: identical to
    /// [`SimCluster::charge_broadcast`] while no window is open; under
    /// active faults it decomposes into per-link master->m messages, each
    /// with retry/timeout semantics. (Modeling simplification: a faulted
    /// collective serializes its per-link messages, an upper bound on the
    /// topology's healthy schedule.)
    pub fn net_broadcast(&self, topo: CommTopology, bytes: u64) -> Result<()> {
        let (ls, retry, policy) = self.net_snapshot();
        if !ls.is_active() {
            self.charge_broadcast(topo, bytes);
            return Ok(());
        }
        let m = self.specs.len();
        let base = self.reserve_msgs(m.saturating_sub(1) as u64)?;
        let mut tally = SendTally::default();
        let mut secs = 0.0;
        let mut moved = 0u64;
        let mut result = Ok(());
        for (i, dst) in (1..m).enumerate() {
            // under Replace, cut-off destinations are skipped: their work
            // was re-placed onto the master's side by assign_machine
            if policy == PartitionPolicy::Replace && ls.partitioned(0, dst) {
                continue;
            }
            match self.send_one(&ls, &retry, policy, 0, dst, bytes, base + i as u64, &mut tally)
            {
                Ok((s, b)) => {
                    secs += s;
                    moved += b;
                }
                Err(e) => {
                    result = Err(e);
                    break;
                }
            }
        }
        self.flush_tally(tally);
        self.charge_net(secs, moved)?;
        result
    }

    /// Model allreduce through the fault layer: identical to
    /// [`SimCluster::charge_allreduce`] while no window is open; under
    /// active faults it decomposes into m->master gather messages plus a
    /// master->m broadcast, each with retry/timeout semantics.
    pub fn net_allreduce(&self, topo: CommTopology, bytes: u64) -> Result<()> {
        let (ls, retry, policy) = self.net_snapshot();
        if !ls.is_active() {
            self.charge_allreduce(topo, bytes);
            return Ok(());
        }
        let m = self.specs.len();
        let base = self.reserve_msgs(2 * m.saturating_sub(1) as u64)?;
        let mut tally = SendTally::default();
        let mut secs = 0.0;
        let mut moved = 0u64;
        let mut result = Ok(());
        'outer: for (leg, flip) in [(0u64, false), (1u64, true)] {
            for (i, peer) in (1..m).enumerate() {
                if policy == PartitionPolicy::Replace && ls.partitioned(0, peer) {
                    continue;
                }
                let (src, dst) = if flip { (0, peer) } else { (peer, 0) };
                let msg = base + leg * m.saturating_sub(1) as u64 + i as u64;
                match self.send_one(&ls, &retry, policy, src, dst, bytes, msg, &mut tally) {
                    Ok((s, b)) => {
                        secs += s;
                        moved += b;
                    }
                    Err(e) => {
                        result = Err(e);
                        break 'outer;
                    }
                }
            }
        }
        self.flush_tally(tally);
        self.charge_net(secs, moved)?;
        result
    }

    /// One point-to-point transfer (shuffle bucket move) through the
    /// fault layer; an alpha-beta message while no window is open.
    pub fn net_transfer(&self, src: usize, dst: usize, bytes: u64) -> Result<()> {
        if src == dst {
            return Ok(()); // local move: no wire
        }
        let (ls, retry, policy) = self.net_snapshot();
        if !ls.is_active() {
            return self.charge_net(self.net.msg_time(bytes), bytes);
        }
        let msg = self.reserve_msgs(1)?;
        let mut tally = SendTally::default();
        let sent = self.send_one(&ls, &retry, policy, src, dst, bytes, msg, &mut tally);
        self.flush_tally(tally);
        let (secs, moved) = sent?;
        self.charge_net(secs, moved)
    }

    // -- memory model ---------------------------------------------------

    /// Charge `bytes` of resident memory on a machine; simulated OOM if
    /// capacity is exceeded (the paper's MATLAB 16x/25x failures).
    pub fn alloc(&self, machine: usize, bytes: u64) -> Result<()> {
        let mut l = lock_unpoisoned(&self.ledger);
        let resident = &mut l.resident_bytes[machine];
        let cap = self.specs[machine].mem_bytes;
        if *resident + bytes > cap {
            return Err(Error::Oom(format!(
                "machine {machine}: {} + {} exceeds {} capacity",
                crate::util::human_bytes(*resident),
                crate::util::human_bytes(bytes),
                crate::util::human_bytes(cap)
            )));
        }
        *resident += bytes;
        Ok(())
    }

    pub fn free(&self, machine: usize, bytes: u64) {
        let mut l = lock_unpoisoned(&self.ledger);
        let r = &mut l.resident_bytes[machine];
        *r = r.saturating_sub(bytes);
    }

    pub fn resident(&self, machine: usize) -> u64 {
        lock_unpoisoned(&self.ledger).resident_bytes[machine]
    }

    // -- round lifecycle --------------------------------------------------

    /// Open a round. Fault-schedule events due at this round index fire
    /// here, before any work of the round runs: crashed machines restart,
    /// due kills mark machines down, drop their cached bytes (charged as
    /// an HDFS re-read into this round), and invalidate affected
    /// partitions via the loss listeners.
    pub fn begin_round(&self) {
        let round_idx = {
            let mut l = lock_unpoisoned(&self.ledger);
            assert!(l.current.is_none(), "begin_round inside an open round");
            l.current = Some(RoundStats::new(self.specs.len()));
            // mli-lint: allow(D002) wall-clock attribution for trace spans, never the sim ledger
            l.round_wall = Some(Stopwatch::start());
            l.rounds
        };
        self.apply_due_faults(round_idx);
        self.apply_due_netfaults(round_idx);
    }

    /// Execute `f` on behalf of `machine`, really timing it and charging
    /// the measured seconds to that machine's budget for this round.
    pub fn run_task<T>(&self, machine: usize, f: impl FnOnce() -> T) -> T {
        // mli-lint: allow(D002) by design: really measures f and charges the sim ledger
        let sw = Stopwatch::start();
        let out = f();
        let secs = sw.elapsed_secs();
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l
            .current
            .as_mut()
            .expect("run_task outside begin_round/end_round");
        cur.machine_compute_s[machine] += secs;
        cur.machine_tasks[machine] += 1;
        cur.task_times.push((machine, secs));
        out
    }

    /// Charge pre-measured compute seconds (used when a task's cost was
    /// measured once and replayed for many simulated machines).
    pub fn charge_compute(&self, machine: usize, secs: f64) {
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l.current.as_mut().expect("charge_compute outside round");
        cur.machine_compute_s[machine] += secs;
        cur.machine_tasks[machine] += 1;
        cur.task_times.push((machine, secs));
    }

    /// Charge one model-allreduce with the given topology.
    pub fn charge_allreduce(&self, topo: CommTopology, bytes: u64) {
        let t = topo.allreduce_time(&self.net, self.specs.len(), bytes);
        let mut l = lock_unpoisoned(&self.ledger);
        let m = self.specs.len() as u64;
        let cur = l.current.as_mut().expect("charge_allreduce outside round");
        cur.comm_s += t;
        cur.net_bytes += 2 * bytes * m.saturating_sub(1);
    }

    /// Charge a master broadcast.
    pub fn charge_broadcast(&self, topo: CommTopology, bytes: u64) {
        let t = topo.broadcast_time(&self.net, self.specs.len(), bytes);
        let mut l = lock_unpoisoned(&self.ledger);
        let m = self.specs.len() as u64;
        let cur = l.current.as_mut().expect("charge_broadcast outside round");
        cur.comm_s += t;
        cur.net_bytes += bytes * m.saturating_sub(1);
    }

    /// Charge an all-to-all shuffle: `bytes_by_src[i]` leaves machine i,
    /// spread evenly over the others. Bottleneck-link model.
    pub fn charge_shuffle(&self, bytes_by_src: &[u64]) {
        let m = self.specs.len();
        if m <= 1 {
            return;
        }
        let total: u64 = bytes_by_src.iter().sum();
        // each machine receives ~total/m; sends its own share. NIC is
        // full-duplex; time = max over machines of max(out, in)/bw.
        let max_out = bytes_by_src.iter().copied().max().unwrap_or(0) as f64;
        let avg_in = total as f64 / m as f64;
        let t = self.net.latency_s * (m as f64).log2().max(1.0)
            + max_out.max(avg_in) / self.net.bandwidth_bps;
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l.current.as_mut().expect("charge_shuffle outside round");
        cur.comm_s += t;
        cur.net_bytes += total;
    }

    /// Charge an HDFS-surrogate write+read of intermediate state (the
    /// Mahout baseline's per-iteration materialization).
    pub fn charge_hdfs_roundtrip(&self, bytes_per_machine: u64) {
        let t = self.net.hdfs_write_time(bytes_per_machine)
            + self.net.hdfs_read_time(bytes_per_machine);
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l.current.as_mut().expect("charge_hdfs outside round");
        cur.disk_s += t;
    }

    /// Charge a fixed job-startup overhead (Hadoop JVM spawn).
    pub fn charge_job_startup(&self) {
        let t = self.net.job_startup_s;
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l.current.as_mut().expect("charge_job_startup outside round");
        cur.disk_s += t;
    }

    /// Switch the straggler model (see [`StragglerModel`]).
    pub fn with_straggler(self, s: StragglerModel) -> SimCluster {
        *lock_unpoisoned(&self.straggler) = s;
        self
    }

    /// Attach a work-stealing [`ThreadPool`] so algorithm layers can fan
    /// partition tasks out across host threads (`SimCluster::ec2(8)
    /// .with_executor(4)`). `threads == 0` picks a default sized by the
    /// host (`ThreadPool::default_threads`) capped at the fleet size —
    /// more host threads than simulated machines buys nothing in a
    /// bulk-synchronous round. Simulated time is unaffected either way.
    pub fn with_executor(self, threads: usize) -> SimCluster {
        let n = if threads == 0 {
            ThreadPool::default_threads().min(self.num_machines()).max(1)
        } else {
            threads
        };
        let pool = ThreadPool::new(n);
        pool.set_tracer(self.tracer());
        *lock_unpoisoned(&self.executor) = Some(pool);
        self
    }

    /// The attached executor, if any.
    pub fn pool(&self) -> Option<Arc<ThreadPool>> {
        lock_unpoisoned(&self.executor).clone()
    }

    /// Attach a tracer: `end_round` records one span per simulated round
    /// (wall-clock duration, simulated seconds in the args) plus the
    /// `sim.micros` / `wall.micros` counters behind the summary's
    /// two-clock attribution. Chains like `with_executor`.
    pub fn with_tracer(self, tracer: Arc<Tracer>) -> SimCluster {
        self.set_tracer(tracer);
        self
    }

    /// Swap the tracer, propagating it to the attached pool (if any).
    pub fn set_tracer(&self, tracer: Arc<Tracer>) {
        if let Some(pool) = self.pool() {
            pool.set_tracer(tracer.clone());
        }
        *lock_unpoisoned(&self.tracer) = tracer;
    }

    pub fn tracer(&self) -> Arc<Tracer> {
        lock_unpoisoned(&self.tracer).clone()
    }

    /// Close the round: apply the speculative-execution rebalance (if
    /// enabled), fold the round into the total, and return its stats.
    pub fn end_round(&self) -> RoundStats {
        let spec_k = self.speculation();
        // under an active network partition, machines behind the cut are
        // excluded from hosting speculative backups — a backup the master
        // can't reach would never win the round
        let reachable: Option<Vec<bool>> = {
            let ns = self.netstate.lock();
            if ns.link.is_active() {
                Some(
                    (0..self.specs.len())
                        .map(|m| ns.link.same_side_as_master(m))
                        .collect(),
                )
            } else {
                None
            }
        };
        let alive: Vec<bool> = lock_unpoisoned(&self.health)
            .iter()
            .enumerate()
            .map(|(m, h)| {
                *h == MachineHealth::Up && reachable.as_ref().is_none_or(|r| r[m])
            })
            .collect();
        let (cur, t, wall_s, round_idx, launched, wins) = {
            let mut l = lock_unpoisoned(&self.ledger);
            let mut cur = l.current.take().expect("end_round without begin_round");
            let (launched, wins) = match spec_k {
                Some(k) => Self::apply_speculation(&mut cur, k, &alive),
                None => (0, 0),
            };
            l.spec_launched += launched;
            l.spec_wins += wins;
            let t = cur.round_time_with(&self.specs, *lock_unpoisoned(&self.straggler));
            l.total_s += t;
            l.total_comm_s += cur.comm_s;
            l.total_disk_s += cur.disk_s;
            l.total_net_bytes += cur.net_bytes;
            l.rounds += 1;
            let wall_s = l
                .round_wall
                .take()
                .map(|sw| sw.elapsed_secs())
                .unwrap_or(0.0);
            (cur, t, wall_s, l.rounds - 1, launched, wins)
        };
        // Record the round span outside the ledger lock: wall-clock time
        // as the span duration, simulated seconds in the args — the
        // two-clock attribution the trace summary reports.
        let tracer = self.tracer();
        if tracer.is_enabled() {
            let wall_ns = (wall_s * 1e9) as u64;
            let start = tracer.now_ns().saturating_sub(wall_ns);
            tracer.span(
                format!("sim-round-{round_idx}"),
                "sim",
                0,
                start,
                &[("sim_s", t), ("comm_s", cur.comm_s), ("disk_s", cur.disk_s)],
            );
            tracer.count("sim.rounds", 1);
            tracer.count("sim.micros", (t * 1e6) as u64);
            tracer.count("wall.micros", (wall_s * 1e6) as u64);
            if launched > 0 {
                tracer.count("spec.launched", launched);
                tracer.count("spec.wins", wins);
                tracer.count("spec.losses", launched - wins);
            }
        }
        cur
    }

    // -- queries ----------------------------------------------------------

    pub fn total_sim_seconds(&self) -> f64 {
        lock_unpoisoned(&self.ledger).total_s
    }

    pub fn total_comm_seconds(&self) -> f64 {
        lock_unpoisoned(&self.ledger).total_comm_s
    }

    pub fn total_disk_seconds(&self) -> f64 {
        lock_unpoisoned(&self.ledger).total_disk_s
    }

    pub fn total_net_bytes(&self) -> u64 {
        lock_unpoisoned(&self.ledger).total_net_bytes
    }

    pub fn rounds(&self) -> usize {
        lock_unpoisoned(&self.ledger).rounds
    }

    /// Reset the ledger (memory accounting persists).
    pub fn reset_time(&self) {
        let mut l = lock_unpoisoned(&self.ledger);
        l.total_s = 0.0;
        l.total_comm_s = 0.0;
        l.total_disk_s = 0.0;
        l.total_net_bytes = 0;
        l.rounds = 0;
        l.current = None;
        l.round_wall = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_accumulates_max_compute_plus_comm() {
        let c = SimCluster::ec2(4);
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.charge_compute(1, 3.0);
        c.charge_compute(2, 2.0);
        c.charge_allreduce(CommTopology::StarGatherBroadcast, 1_000_000);
        let stats = c.end_round();
        // slowest machine (3s) dominates; 1 task/machine => no core speedup
        let round = stats.round_time(&c.specs);
        assert!(round > 3.0 && round < 3.1, "round={round}");
        assert_eq!(c.rounds(), 1);
        assert!(c.total_sim_seconds() > 3.0);
        assert!(c.total_net_bytes() > 0);
    }

    #[test]
    fn multicore_parallelism_divides_task_time() {
        let c = SimCluster::ec2(1); // 8 cores
        c.begin_round();
        for _ in 0..8 {
            c.charge_compute(0, 1.0);
        }
        let stats = c.end_round();
        let t = stats.round_time(&c.specs);
        assert!((t - 1.0).abs() < 1e-9, "8 tasks on 8 cores = 1s, got {t}");
    }

    #[test]
    fn compute_factor_scales() {
        let spec = MachineSpec::default().with_compute_factor(0.5);
        let c = SimCluster::new(2, spec, NetworkModel::ec2_2013());
        c.begin_round();
        c.charge_compute(0, 2.0);
        let t = c.end_round().round_time(&c.specs);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn run_task_measures_and_returns() {
        let c = SimCluster::ec2(2);
        c.begin_round();
        let v = c.run_task(1, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        let stats = c.end_round();
        assert!(stats.machine_compute_s[1] >= 0.004);
        assert_eq!(stats.machine_tasks[1], 1);
        assert_eq!(stats.machine_tasks[0], 0);
    }

    #[test]
    fn oom_when_capacity_exceeded() {
        let spec = MachineSpec::default().with_mem_bytes(1000);
        let c = SimCluster::new(1, spec, NetworkModel::ec2_2013());
        assert!(c.alloc(0, 800).is_ok());
        let err = c.alloc(0, 300).unwrap_err();
        assert!(err.is_oom());
        c.free(0, 800);
        assert!(c.alloc(0, 900).is_ok());
        assert_eq!(c.resident(0), 900);
    }

    #[test]
    fn hdfs_and_startup_charges_to_disk() {
        let c = SimCluster::ec2(2);
        c.begin_round();
        c.charge_job_startup();
        c.charge_hdfs_roundtrip(100_000_000); // 0.3s wr*3repl + 1s... = 4s
        let stats = c.end_round();
        assert!(stats.disk_s > 10.0); // 10s startup dominates
        assert!(c.total_disk_seconds() > 10.0);
    }

    #[test]
    fn shuffle_bottleneck_model() {
        let c = SimCluster::ec2(4);
        c.begin_round();
        c.charge_shuffle(&[1_000_000, 1_000_000, 1_000_000, 9_000_000]);
        let stats = c.end_round();
        // bottleneck is the 9MB sender at 125MB/s ~ 72ms
        assert!(stats.comm_s > 0.07 && stats.comm_s < 0.08, "{}", stats.comm_s);
        // single machine: free
        let c1 = SimCluster::ec2(1);
        c1.begin_round();
        c1.charge_shuffle(&[123]);
        assert_eq!(c1.end_round().comm_s, 0.0);
    }

    #[test]
    fn reset_clears_time_not_memory() {
        let c = SimCluster::ec2(1);
        c.alloc(0, 100).unwrap();
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.end_round();
        c.reset_time();
        assert_eq!(c.total_sim_seconds(), 0.0);
        assert_eq!(c.rounds(), 0);
        assert_eq!(c.resident(0), 100);
    }

    #[test]
    #[should_panic(expected = "outside round")]
    fn task_outside_round_panics() {
        let c = SimCluster::ec2(1);
        c.charge_compute(0, 1.0);
    }

    #[test]
    fn traced_round_records_both_clocks() {
        let (tracer, sink) = Tracer::recording();
        let c = SimCluster::ec2(2).with_tracer(tracer);
        c.begin_round();
        c.charge_compute(0, 2.0);
        c.end_round();
        let spans = sink.spans();
        assert!(
            spans.iter().any(|s| s.name == "sim-round-0" && s.cat == "sim"),
            "round span missing: {spans:?}"
        );
        assert_eq!(sink.counter("sim.rounds"), 1);
        // 2.0 simulated seconds = 2M micros (1 task, factor 1.0, no comm)
        assert_eq!(sink.counter("sim.micros"), 2_000_000);
    }

    #[test]
    fn executor_attach_and_parallel_run_task() {
        let c = SimCluster::ec2(4).with_executor(2);
        let pool = c.pool().expect("pool attached");
        assert_eq!(pool.threads(), 2);
        // concurrent run_task charges from pool workers all land
        c.begin_round();
        let outs = pool.run(8, |p| c.run_task(c.machine_of(p), || p * 2));
        assert_eq!(outs, (0..8).map(|p| p * 2).collect::<Vec<_>>());
        let stats = c.end_round();
        assert_eq!(stats.machine_tasks.iter().sum::<usize>(), 8);
        // default sizing caps at fleet size
        let c1 = SimCluster::ec2(1).with_executor(0);
        assert_eq!(c1.pool().unwrap().threads(), 1);
    }

    #[test]
    fn kill_reroutes_placement_and_restore_reverts() {
        let c = SimCluster::ec2(4);
        assert_eq!(c.assign_machine(1).unwrap(), 1);
        c.kill_machine(1, None);
        assert!(!c.is_up(1));
        assert_eq!(c.num_alive(), 3);
        // primary dead: first alive machine scanning up
        assert_eq!(c.assign_machine(1).unwrap(), 2);
        assert_eq!(c.assign_machine(5).unwrap(), 2);
        assert_eq!(c.assign_machine(0).unwrap(), 0);
        c.restore_machine(1);
        assert_eq!(c.assign_machine(1).unwrap(), 1);
        assert_eq!(c.fault_stats(), (1, 1));
        // killing an already-dead machine is a no-op
        c.kill_machine(2, None);
        assert_eq!(c.kill_machine(2, None), 0);
        assert_eq!(c.fault_stats().0, 2);
    }

    #[test]
    fn all_machines_dead_is_typed_fault_recovery() {
        let c = SimCluster::ec2(2);
        c.kill_machine(0, None);
        c.kill_machine(1, None);
        let err = c.assign_machine(0).unwrap_err();
        assert!(err.is_fault_recovery(), "got {err}");
    }

    #[test]
    fn kill_drops_resident_bytes_and_charges_reread() {
        let c = SimCluster::ec2(2);
        c.alloc(1, 100_000_000).unwrap(); // 100 MB @ 100 MB/s disk
        c.begin_round();
        let lost = c.kill_machine(1, None);
        assert_eq!(lost, 100_000_000);
        assert_eq!(c.resident(1), 0);
        let stats = c.end_round();
        assert!((stats.disk_s - 1.0).abs() < 1e-9, "disk_s={}", stats.disk_s);
    }

    #[test]
    fn fault_plan_fires_at_round_and_restarts_after_delay() {
        let plan = Arc::new(FaultPlan::new());
        plan.kill_at(1, 0, FaultKind::Crash { restart_after: 1 });
        let c = SimCluster::ec2(2).with_faults(plan.clone());
        c.begin_round(); // round 0: nothing due
        assert!(c.is_up(0));
        c.end_round();
        c.begin_round(); // round 1: kill fires before work runs
        assert!(!c.is_up(0));
        assert_eq!(c.assign_machine(0).unwrap(), 1);
        c.end_round();
        c.begin_round(); // round 2: restart delay elapsed
        assert!(c.is_up(0));
        c.end_round();
        assert_eq!(c.fault_stats(), (1, 1));
        assert_eq!(plan.remaining(), 0);
    }

    #[test]
    fn loss_listener_fires_with_machine_index() {
        use std::sync::atomic::AtomicUsize;
        let c = SimCluster::ec2(4);
        let seen = Arc::new(AtomicUsize::new(usize::MAX));
        let s = seen.clone();
        c.on_machine_loss(move |m| s.store(m, Ordering::SeqCst));
        c.kill_machine(3, None);
        assert_eq!(seen.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn speculation_rebalances_straggler_to_backup() {
        let c = SimCluster::ec2(4).with_speculation(2.0);
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.charge_compute(1, 1.0);
        c.charge_compute(2, 1.0);
        c.charge_compute(3, 10.0); // straggler: 10 >= 2 x median(1.0)
        let stats = c.end_round();
        // backup launched at 2s, replays at median speed: done at 3s; the
        // straggler machine is gated at 3s, the copy (1s) lands on the
        // least-loaded machine (0), which still finishes in 2s/2 cores
        let t = stats.round_time(&c.specs);
        assert!((t - 3.0).abs() < 1e-9, "round={t}");
        assert_eq!(c.speculation_stats(), (1, 1));
        assert!((stats.machine_compute_s[3] - 3.0).abs() < 1e-9);
        assert!((stats.machine_compute_s[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn speculation_skips_mild_spread_and_is_off_by_default() {
        let c = SimCluster::ec2(2).with_speculation(4.0);
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.charge_compute(1, 2.0); // 2 < 4 x median(1.5): no candidate
        let t = c.end_round().round_time(&c.specs);
        assert!((t - 2.0).abs() < 1e-9);
        assert_eq!(c.speculation_stats(), (0, 0));
        // disabled: stragglers keep their full time
        let c2 = SimCluster::ec2(2);
        c2.begin_round();
        c2.charge_compute(0, 1.0);
        c2.charge_compute(1, 10.0);
        assert!((c2.end_round().round_time(&c2.specs) - 10.0).abs() < 1e-9);
        assert_eq!(c2.speculation_stats(), (0, 0));
    }

    #[test]
    fn net_paths_match_analytic_charges_when_healthy() {
        // no plan attached: net_* wrappers must charge bit-for-bit what
        // the analytic methods do
        let a = SimCluster::ec2(4);
        a.begin_round();
        a.charge_broadcast(CommTopology::StarGatherBroadcast, 1_000_000);
        a.charge_allreduce(CommTopology::StarGatherBroadcast, 1_000_000);
        let sa = a.end_round();
        let b = SimCluster::ec2(4);
        b.begin_round();
        b.net_broadcast(CommTopology::StarGatherBroadcast, 1_000_000).unwrap();
        b.net_allreduce(CommTopology::StarGatherBroadcast, 1_000_000).unwrap();
        let sb = b.end_round();
        assert_eq!(sa.comm_s, sb.comm_s);
        assert_eq!(sa.net_bytes, sb.net_bytes);
        assert_eq!(b.net_stats(), NetStats::default());
        // point-to-point healthy transfer is one alpha-beta message
        let c = SimCluster::ec2(4);
        c.begin_round();
        c.net_transfer(0, 3, 1_000_000).unwrap();
        let sc = c.end_round();
        assert_eq!(sc.comm_s, c.net.msg_time(1_000_000));
        assert_eq!(sc.net_bytes, 1_000_000);
    }

    #[test]
    fn drop_window_charges_retries_and_is_deterministic() {
        let run = || {
            let plan = Arc::new(NetFaultPlan::new(42));
            plan.window(0, 1, NetFaultKind::Drop { machine: None, prob: 0.5 });
            let c = SimCluster::ec2(8).with_netfaults(plan);
            c.begin_round();
            c.net_allreduce(CommTopology::StarGatherBroadcast, 100_000).unwrap();
            let s = c.end_round();
            (s.comm_s, s.net_bytes, c.net_stats())
        };
        let (comm, bytes, stats) = run();
        // at p=0.5 over 14 messages some drops are near-certain, and each
        // drop burns an ack window, so time exceeds the healthy charge
        assert!(stats.drops > 0, "{stats:?}");
        assert_eq!(stats.retries, stats.drops, "every drop retried: {stats:?}");
        assert_eq!(stats.sends, 14);
        let healthy = SimCluster::ec2(8);
        healthy.begin_round();
        healthy.net_allreduce(CommTopology::StarGatherBroadcast, 100_000).unwrap();
        let hs = healthy.end_round();
        assert!(comm > hs.comm_s, "faulted {comm} vs healthy {}", hs.comm_s);
        // bit-for-bit replay under the same seed
        let (comm2, bytes2, stats2) = run();
        assert_eq!(comm.to_bits(), comm2.to_bits());
        assert_eq!(bytes, bytes2);
        assert_eq!(stats, stats2);
    }

    #[test]
    fn duplicate_window_pays_bandwidth_twice() {
        let plan = Arc::new(NetFaultPlan::new(7));
        plan.window(0, 1, NetFaultKind::Duplicate { machine: None, prob: 1.0 });
        let c = SimCluster::ec2(4).with_netfaults(plan);
        c.begin_round();
        c.net_broadcast(CommTopology::StarGatherBroadcast, 1_000).unwrap();
        let s = c.end_round();
        let stats = c.net_stats();
        assert_eq!(stats.dups, 3, "{stats:?}");
        assert_eq!(s.net_bytes, 2 * 3 * 1_000);
        assert_eq!(stats.drops, 0);
    }

    #[test]
    fn degrade_window_slows_the_link() {
        let plan = Arc::new(NetFaultPlan::new(7));
        plan.window(
            0,
            1,
            NetFaultKind::Degrade { machine: Some(3), latency_x: 10.0, bandwidth_div: 10.0 },
        );
        let c = SimCluster::ec2(4).with_netfaults(plan);
        c.begin_round();
        c.net_transfer(0, 3, 1_000_000).unwrap(); // degraded endpoint
        c.net_transfer(0, 1, 1_000_000).unwrap(); // untouched link
        let s = c.end_round();
        let slow = c.net.msg_time_scaled(1_000_000, 10.0, 10.0);
        let fast = c.net.msg_time(1_000_000);
        assert!((s.comm_s - (slow + fast)).abs() < 1e-12, "{}", s.comm_s);
        assert_eq!(c.net_stats().drops, 0);
    }

    #[test]
    fn partition_wait_out_charges_and_heals() {
        let plan = Arc::new(NetFaultPlan::new(7));
        plan.window(0, 2, NetFaultKind::Partition { minority: vec![3] });
        let c = SimCluster::ec2(4).with_netfaults(plan);
        c.begin_round();
        c.net_transfer(0, 3, 1_000).unwrap();
        c.net_transfer(0, 1, 1_000).unwrap();
        let s0 = c.end_round();
        let stats = c.net_stats();
        assert_eq!(stats.partition_waits, 1, "{stats:?}");
        // the cut transfer waited ~2 rounds of ack windows on top of its
        // delivery; the same-side one paid only the alpha-beta time
        assert!(s0.comm_s > 2.0 * c.net.msg_time(1_000), "{}", s0.comm_s);
        // round 2: window closed, links healthy again
        c.begin_round();
        c.end_round();
        c.begin_round();
        c.net_transfer(0, 3, 1_000).unwrap();
        let s2 = c.end_round();
        assert_eq!(s2.comm_s, c.net.msg_time(1_000));
        assert_eq!(c.net_stats().partition_waits, 1);
    }

    #[test]
    fn partition_replace_reroutes_placement_and_fails_direct_sends() {
        let plan = Arc::new(NetFaultPlan::new(7));
        plan.window(0, 1, NetFaultKind::Partition { minority: vec![2, 3] });
        let c = SimCluster::ec2(4)
            .with_netfaults(plan)
            .with_partition_policy(PartitionPolicy::Replace);
        c.begin_round();
        // placement: cut machines are skipped like dead ones
        assert_eq!(c.assign_machine(0).unwrap(), 0);
        assert_eq!(c.assign_machine(2).unwrap(), 0, "2 is cut; scan wraps to 0");
        assert_eq!(c.assign_machine(3).unwrap(), 0);
        assert_eq!(c.net_stats().replacements, 2);
        // a direct send across the cut is a typed NetFault
        let err = c.net_transfer(0, 3, 1_000).unwrap_err();
        assert!(err.is_net_fault(), "got {err}");
        // a broadcast skips the unreachable half but reaches machine 1
        c.net_broadcast(CommTopology::StarGatherBroadcast, 1_000).unwrap();
        assert_eq!(c.net_stats().sends, 2, "one failed transfer + one bcast leg");
        c.end_round();
        // master side dead + everything else cut: alive-but-unreachable
        c.kill_machine(0, None);
        c.kill_machine(1, None);
        c.begin_round(); // reopens nothing; windows expired
        c.end_round();
        // re-open a cut for the error-path check
        let plan2 = Arc::new(NetFaultPlan::new(8));
        plan2.window(2, 1, NetFaultKind::Partition { minority: vec![2, 3] });
        let c2 = SimCluster::ec2(4)
            .with_netfaults(plan2)
            .with_partition_policy(PartitionPolicy::Replace);
        c2.kill_machine(0, None);
        c2.kill_machine(1, None);
        c2.begin_round();
        c2.end_round();
        c2.begin_round();
        c2.end_round();
        c2.begin_round(); // round 2: cut opens; machines 2,3 alive but cut
        let err = c2.assign_machine(0).unwrap_err();
        assert!(err.is_net_fault(), "alive-but-cut must be NetFault, got {err}");
        c2.end_round();
    }

    #[test]
    fn total_drop_exhausts_retry_budget_with_typed_error() {
        let plan = Arc::new(NetFaultPlan::new(7));
        plan.window(0, 1, NetFaultKind::Drop { machine: None, prob: 1.0 });
        let c = SimCluster::ec2(2).with_netfaults(plan);
        c.set_net_retry_policy(RetryPolicy {
            max_attempts: 3,
            ..RetryPolicy::default()
        });
        c.begin_round();
        let err = c.net_transfer(0, 1, 1_000).unwrap_err();
        assert!(err.is_net_fault(), "got {err}");
        assert!(err.to_string().contains("retry budget exhausted"), "got {err}");
        let stats = c.net_stats();
        assert_eq!(stats.drops, 3);
        assert_eq!(stats.retries, 2, "last attempt has no retry after it");
        c.end_round();
    }

    #[test]
    fn netfault_windows_emit_spans_and_counters() {
        let (tracer, sink) = Tracer::recording();
        let plan = Arc::new(NetFaultPlan::new(3));
        plan.window(0, 1, NetFaultKind::Drop { machine: None, prob: 0.75 });
        let c = SimCluster::ec2(8).with_netfaults(plan).with_tracer(tracer);
        // with p=0.75, 64 attempts make exhaustion vanishingly unlikely
        // while 14 messages make at least one drop a statistical certainty
        // (tiny backoff base keeps the summed backoffs inside the budget)
        c.set_net_retry_policy(RetryPolicy {
            max_attempts: 64,
            backoff_base: Duration::from_micros(1),
            ..RetryPolicy::default()
        });
        c.begin_round();
        c.net_allreduce(CommTopology::StarGatherBroadcast, 50_000).unwrap();
        c.end_round();
        assert_eq!(sink.counter("net.windows"), 1);
        assert_eq!(sink.counter("net.sends"), 14);
        assert!(sink.counter("net.drops") > 0, "p=0.75 over 14 messages");
        assert!(
            sink.spans()
                .iter()
                .any(|s| s.name == "netfault:drop-round-0" && s.cat == "fault"),
            "window span missing: {:?}",
            sink.spans().iter().map(|s| s.name.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn speculation_backups_avoid_cut_machines() {
        // machine 3 straggles; machines 1,2 are behind the cut, so the
        // backup must land on machine 0 (the only reachable peer)
        let plan = Arc::new(NetFaultPlan::new(7));
        plan.window(0, 1, NetFaultKind::Partition { minority: vec![1, 2] });
        let c = SimCluster::ec2(4).with_netfaults(plan).with_speculation(2.0);
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.charge_compute(1, 0.1);
        c.charge_compute(2, 0.1);
        c.charge_compute(3, 10.0);
        let stats = c.end_round();
        assert_eq!(c.speculation_stats(), (1, 1));
        // least-loaded *reachable* machine is 0 (1.0s) even though 1 and 2
        // are idle-ish — they're behind the cut
        assert!(stats.machine_compute_s[0] > 1.0, "{:?}", stats.machine_compute_s);
        assert!((stats.machine_compute_s[1] - 0.1).abs() < 1e-9);
    }

    #[test]
    fn fault_events_emit_trace_counters() {
        let (tracer, sink) = Tracer::recording();
        let c = SimCluster::ec2(2).with_tracer(tracer);
        c.alloc(0, 1_000).unwrap();
        c.kill_machine(0, None);
        c.restore_machine(0);
        assert_eq!(sink.counter("fault.kills"), 1);
        assert_eq!(sink.counter("fault.restarts"), 1);
        assert!(
            sink.spans().iter().any(|s| s.name == "fault:kill-machine-0" && s.cat == "fault"),
            "kill span missing"
        );
    }
}
