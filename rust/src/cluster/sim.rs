//! SimCluster: the simulated-time ledger that turns really-measured
//! per-partition compute plus analytically-charged communication into
//! per-round and total walltime estimates.
//!
//! Usage pattern (bulk-synchronous, as all of the paper's systems are):
//!
//! ```text
//! let cluster = SimCluster::new(32, MachineSpec::default(), NetworkModel::default());
//! for round in 0..iters {
//!     cluster.begin_round();
//!     for (p, task) in partitions { cluster.run_task(machine_of(p), || compute(p)); }
//!     cluster.charge_allreduce(CommTopology::StarGatherBroadcast, model_bytes);
//!     cluster.end_round();
//! }
//! let t = cluster.total_sim_seconds();
//! ```

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::fault::{FaultKind, FaultPlan};
use super::machine::MachineSpec;
use super::network::NetworkModel;
use super::topology::CommTopology;
use crate::error::{Error, Result};
use crate::exec::{lock_unpoisoned, ThreadPool};
use crate::trace::Tracer;
use crate::util::timer::Stopwatch;

/// Per-round accounting.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    /// Per-machine accumulated compute seconds this round (after
    /// compute_factor and core-parallelism adjustment).
    pub machine_compute_s: Vec<f64>,
    /// Tasks executed per machine this round (for the parallelism model).
    pub machine_tasks: Vec<usize>,
    /// Communication seconds charged this round.
    pub comm_s: f64,
    /// Disk seconds charged this round (HDFS surrogate).
    pub disk_s: f64,
    /// Bytes moved over the network this round.
    pub net_bytes: u64,
    /// Individual (machine, seconds) task charges this round, kept so the
    /// speculative-execution model can find per-task stragglers (the
    /// per-machine sums above can't distinguish one slow task from many
    /// fast ones).
    pub task_times: Vec<(usize, f64)>,
}

impl RoundStats {
    fn new(machines: usize) -> RoundStats {
        RoundStats {
            machine_compute_s: vec![0.0; machines],
            machine_tasks: vec![0; machines],
            ..Default::default()
        }
    }

    /// Per-machine effective compute seconds this round.
    fn machine_times(&self, specs: &[MachineSpec]) -> Vec<f64> {
        self.machine_compute_s
            .iter()
            .zip(self.machine_tasks.iter())
            .zip(specs.iter())
            .map(|((&secs, &tasks), spec)| {
                // tasks on one machine run min(cores, tasks)-way parallel
                let par = spec.cores.min(tasks.max(1)) as f64;
                secs * spec.compute_factor / par
            })
            .collect()
    }

    /// The bulk-synchronous round time: slowest machine + comm + disk.
    pub fn round_time(&self, specs: &[MachineSpec]) -> f64 {
        self.round_time_with(specs, StragglerModel::Max)
    }

    /// Round time under a chosen straggler model.
    pub fn round_time_with(&self, specs: &[MachineSpec], s: StragglerModel) -> f64 {
        let times = self.machine_times(specs);
        let compute = match s {
            StragglerModel::Max => times.iter().fold(0.0f64, |a, &b| a.max(b)),
            StragglerModel::Median => {
                let active: Vec<f64> = times.iter().copied().filter(|&t| t > 0.0).collect();
                crate::util::median(&active)
            }
        };
        compute + self.comm_s + self.disk_s
    }
}

/// How the bulk-synchronous barrier treats per-machine compute spread.
///
/// `Max` is the true BSP semantics (slowest machine gates the round).
/// `Median` models a *homogeneous* fleet: on this 1-core host all
/// "machines" share one core, so the empirical max is contaminated by
/// host noise (page cache, allocator, XLA thread pool) that real,
/// independent machines would not correlate on. Benches over homogeneous
/// synthetic partitions use `Median`; heterogeneity experiments use `Max`.
/// (DESIGN.md §3.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StragglerModel {
    Max,
    Median,
}

/// The running ledger of simulated time.
#[derive(Debug, Default)]
pub struct SimLedger {
    pub total_s: f64,
    pub total_comm_s: f64,
    pub total_disk_s: f64,
    pub total_net_bytes: u64,
    pub rounds: usize,
    current: Option<RoundStats>,
    /// Wall-clock stopwatch for the open round (trace attribution only;
    /// simulated time never reads it).
    round_wall: Option<Stopwatch>,
    /// Per-machine resident bytes (simulated memory accounting).
    pub resident_bytes: Vec<u64>,
    /// Speculative task copies launched / won across all rounds (the
    /// analytic straggler-mitigation model; see `with_speculation`).
    pub spec_launched: u64,
    pub spec_wins: u64,
}

/// Health of one simulated machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MachineHealth {
    Up,
    /// Down until round `until` (crash with restart), or forever (`None`).
    Down { until: Option<usize> },
}

/// Callback invoked with the machine index when a machine dies, so
/// engine-level state (cached partitions resident there) can be
/// invalidated. See `Dataset::bind_cluster`.
type LossListener = Box<dyn Fn(usize) + Send + Sync>;

/// A simulated cluster: machine fleet + network + time ledger.
///
/// Interior mutability is mutex-guarded (`Send + Sync`) so that tasks
/// running concurrently on the `exec` thread pool can record compute time
/// into the ledger; charges are commutative sums, so simulated time is
/// independent of the host thread count.
pub struct SimCluster {
    pub specs: Vec<MachineSpec>,
    pub net: NetworkModel,
    pub straggler: Mutex<StragglerModel>,
    ledger: Mutex<SimLedger>,
    executor: Mutex<Option<Arc<ThreadPool>>>,
    tracer: Mutex<Arc<Tracer>>,
    /// Per-machine up/down state (node-failure model).
    health: Mutex<Vec<MachineHealth>>,
    /// Scheduled machine kills, drained at round boundaries.
    faults: Mutex<Option<Arc<FaultPlan>>>,
    /// Machine-loss callbacks (cache invalidation hooks).
    loss_listeners: Mutex<Vec<LossListener>>,
    /// Speculative-execution threshold k: a task taking >= k x the round
    /// median gets a simulated backup copy. `None` disables.
    speculation: Mutex<Option<f64>>,
    fault_kills: AtomicU64,
    fault_restarts: AtomicU64,
}

impl SimCluster {
    pub fn new(machines: usize, spec: MachineSpec, net: NetworkModel) -> SimCluster {
        assert!(machines > 0, "cluster needs >= 1 machine");
        let mut ledger = SimLedger::default();
        ledger.resident_bytes = vec![0; machines];
        SimCluster {
            specs: vec![spec; machines],
            net,
            straggler: Mutex::new(StragglerModel::Max),
            ledger: Mutex::new(ledger),
            executor: Mutex::new(None),
            tracer: Mutex::new(Tracer::disabled()),
            health: Mutex::new(vec![MachineHealth::Up; machines]),
            faults: Mutex::new(None),
            loss_listeners: Mutex::new(Vec::new()),
            speculation: Mutex::new(None),
            fault_kills: AtomicU64::new(0),
            fault_restarts: AtomicU64::new(0),
        }
    }

    /// Homogeneous fleet, default EC2 specs (the common case in benches).
    pub fn ec2(machines: usize) -> SimCluster {
        SimCluster::new(machines, MachineSpec::default(), NetworkModel::ec2_2013())
    }

    pub fn num_machines(&self) -> usize {
        self.specs.len()
    }

    /// Machine owning partition `p` under round-robin placement. This is
    /// the *primary* (failure-oblivious) placement; schedulers should use
    /// [`SimCluster::assign_machine`], which re-routes around dead nodes.
    pub fn machine_of(&self, partition: usize) -> usize {
        partition % self.specs.len()
    }

    // -- node-failure model ----------------------------------------------

    /// Failure-aware placement: partition `p`'s primary machine when it
    /// is alive, otherwise the first alive machine scanning up from the
    /// primary. The fallback is a pure function of (partition, health
    /// vector), so re-assignment is deterministic for any host thread
    /// count. Errors with [`Error::FaultRecovery`] when the whole fleet
    /// is down.
    pub fn assign_machine(&self, partition: usize) -> Result<usize> {
        let n = self.specs.len();
        let primary = partition % n;
        let h = lock_unpoisoned(&self.health);
        for k in 0..n {
            let m = (primary + k) % n;
            if h[m] == MachineHealth::Up {
                return Ok(m);
            }
        }
        Err(Error::FaultRecovery(format!(
            "no machine alive to place partition {partition} (all {n} down)"
        )))
    }

    pub fn is_up(&self, machine: usize) -> bool {
        lock_unpoisoned(&self.health)[machine] == MachineHealth::Up
    }

    pub fn num_alive(&self) -> usize {
        lock_unpoisoned(&self.health)
            .iter()
            .filter(|h| **h == MachineHealth::Up)
            .count()
    }

    /// Attach a [`FaultPlan`]; due kills are applied at each
    /// `begin_round`, before any work of that round runs.
    pub fn with_faults(self, plan: Arc<FaultPlan>) -> SimCluster {
        *lock_unpoisoned(&self.faults) = Some(plan);
        self
    }

    /// Enable the speculative-execution model: any task whose charged
    /// time is >= `k` x the round median gets a simulated backup copy on
    /// the least-loaded alive machine, and the round is gated by whichever
    /// copy finishes first (see `apply_speculation`). Mirrors Spark's
    /// `spark.speculation.multiplier`.
    pub fn with_speculation(self, k: f64) -> SimCluster {
        assert!(k > 1.0, "speculation threshold must exceed 1.0");
        *lock_unpoisoned(&self.speculation) = Some(k);
        self
    }

    pub fn speculation(&self) -> Option<f64> {
        *lock_unpoisoned(&self.speculation)
    }

    /// Register a machine-loss callback, invoked with the machine index
    /// whenever a machine dies (scheduled or manual). Listeners run after
    /// the cluster has dropped the machine's resident bytes; they are the
    /// hook by which cached dataset partitions placed there are
    /// invalidated (`Dataset::bind_cluster`). Permanent for the cluster's
    /// lifetime.
    pub fn on_machine_loss(&self, f: impl Fn(usize) + Send + Sync + 'static) {
        lock_unpoisoned(&self.loss_listeners).push(Box::new(f));
    }

    /// (kills, restarts) applied so far.
    pub fn fault_stats(&self) -> (u64, u64) {
        (
            self.fault_kills.load(Ordering::Relaxed),
            self.fault_restarts.load(Ordering::Relaxed),
        )
    }

    /// (speculative copies launched, copies that beat the original) so far.
    pub fn speculation_stats(&self) -> (u64, u64) {
        let l = lock_unpoisoned(&self.ledger);
        (l.spec_launched, l.spec_wins)
    }

    /// Kill `machine` now: mark it down (until `restart_round`, forever
    /// for `None`), drop its resident bytes, charge the open round an
    /// HDFS re-read of those bytes (survivors must re-fetch the dead
    /// node's input shards from stable storage before recomputing), and
    /// notify loss listeners. Returns the lost bytes; no-op (0) when the
    /// machine is already down.
    pub fn kill_machine(&self, machine: usize, restart_round: Option<usize>) -> u64 {
        {
            let mut h = lock_unpoisoned(&self.health);
            if h[machine] != MachineHealth::Up {
                return 0;
            }
            h[machine] = MachineHealth::Down { until: restart_round };
        }
        let lost = {
            let mut l = lock_unpoisoned(&self.ledger);
            let lost = std::mem::take(&mut l.resident_bytes[machine]);
            if lost > 0 {
                if let Some(cur) = l.current.as_mut() {
                    cur.disk_s += self.net.hdfs_read_time(lost);
                }
            }
            lost
        };
        self.fault_kills.fetch_add(1, Ordering::Relaxed);
        {
            let listeners = lock_unpoisoned(&self.loss_listeners);
            for f in listeners.iter() {
                f(machine);
            }
        }
        let tracer = self.tracer();
        if let Some(t0) = tracer.start() {
            tracer.span(
                format!("fault:kill-machine-{machine}"),
                "fault",
                0,
                t0,
                &[("lost_bytes", lost as f64)],
            );
            tracer.count("fault.kills", 1);
        }
        lost
    }

    /// Bring a dead machine back (empty: its cached state died with it).
    pub fn restore_machine(&self, machine: usize) {
        let mut h = lock_unpoisoned(&self.health);
        if h[machine] != MachineHealth::Up {
            h[machine] = MachineHealth::Up;
            drop(h);
            self.fault_restarts.fetch_add(1, Ordering::Relaxed);
            let tracer = self.tracer();
            if tracer.is_enabled() {
                tracer.count("fault.restarts", 1);
            }
        }
    }

    /// Apply the fault schedule at a round boundary: restart machines
    /// whose crash delay has elapsed, then fire kills due this round.
    fn apply_due_faults(&self, round: usize) {
        let restart: Vec<usize> = {
            let h = lock_unpoisoned(&self.health);
            h.iter()
                .enumerate()
                .filter_map(|(m, s)| match s {
                    MachineHealth::Down { until: Some(u) } if round >= *u => Some(m),
                    _ => None,
                })
                .collect()
        };
        for m in restart {
            self.restore_machine(m);
        }
        let plan = lock_unpoisoned(&self.faults).clone();
        if let Some(plan) = plan {
            for ev in plan.take_due(round) {
                let restart_round = match ev.kind {
                    FaultKind::Crash { restart_after } => Some(round + restart_after.max(1)),
                    FaultKind::Permanent => None,
                };
                self.kill_machine(ev.machine, restart_round);
            }
        }
    }

    /// The analytic speculative-execution model, applied when a round
    /// closes: any task charged >= `k` x the round's median task time is
    /// assumed to have had a backup copy launched at `k x median` on the
    /// least-loaded alive machine (replaying at median speed). If the
    /// backup would finish first — at `(k + 1) x median` — the straggling
    /// machine is only gated until then and the backup's cost lands on
    /// its host. Candidates are processed in a canonical order so the
    /// rebalanced ledger is identical for any host thread count. Returns
    /// (copies launched, copies that won).
    fn apply_speculation(cur: &mut RoundStats, k: f64, alive: &[bool]) -> (u64, u64) {
        if cur.task_times.len() < 2 {
            return (0, 0);
        }
        let times: Vec<f64> = cur.task_times.iter().map(|&(_, t)| t).collect();
        let med = crate::util::median(&times);
        if med <= 0.0 {
            return (0, 0);
        }
        let mut candidates: Vec<(usize, f64)> = cur
            .task_times
            .iter()
            .copied()
            .filter(|&(_, t)| t >= k * med)
            .collect();
        candidates.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.total_cmp(&a.1)));
        let mut launched = 0u64;
        let mut wins = 0u64;
        for (m, t) in candidates {
            // backup host: least-loaded alive machine other than the
            // straggler's own (ties broken by lowest index)
            let backup = cur
                .machine_compute_s
                .iter()
                .enumerate()
                .filter(|&(b, _)| b != m && alive.get(b).copied().unwrap_or(false))
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(b, _)| b);
            let Some(backup) = backup else { continue };
            launched += 1;
            let backup_finish = (k + 1.0) * med;
            if backup_finish < t {
                wins += 1;
                cur.machine_compute_s[m] -= t - backup_finish;
                cur.machine_compute_s[backup] += med;
                cur.machine_tasks[backup] += 1;
            }
        }
        (launched, wins)
    }

    // -- memory model ---------------------------------------------------

    /// Charge `bytes` of resident memory on a machine; simulated OOM if
    /// capacity is exceeded (the paper's MATLAB 16x/25x failures).
    pub fn alloc(&self, machine: usize, bytes: u64) -> Result<()> {
        let mut l = lock_unpoisoned(&self.ledger);
        let resident = &mut l.resident_bytes[machine];
        let cap = self.specs[machine].mem_bytes;
        if *resident + bytes > cap {
            return Err(Error::Oom(format!(
                "machine {machine}: {} + {} exceeds {} capacity",
                crate::util::human_bytes(*resident),
                crate::util::human_bytes(bytes),
                crate::util::human_bytes(cap)
            )));
        }
        *resident += bytes;
        Ok(())
    }

    pub fn free(&self, machine: usize, bytes: u64) {
        let mut l = lock_unpoisoned(&self.ledger);
        let r = &mut l.resident_bytes[machine];
        *r = r.saturating_sub(bytes);
    }

    pub fn resident(&self, machine: usize) -> u64 {
        lock_unpoisoned(&self.ledger).resident_bytes[machine]
    }

    // -- round lifecycle --------------------------------------------------

    /// Open a round. Fault-schedule events due at this round index fire
    /// here, before any work of the round runs: crashed machines restart,
    /// due kills mark machines down, drop their cached bytes (charged as
    /// an HDFS re-read into this round), and invalidate affected
    /// partitions via the loss listeners.
    pub fn begin_round(&self) {
        let round_idx = {
            let mut l = lock_unpoisoned(&self.ledger);
            assert!(l.current.is_none(), "begin_round inside an open round");
            l.current = Some(RoundStats::new(self.specs.len()));
            // mli-lint: allow(D002) wall-clock attribution for trace spans, never the sim ledger
            l.round_wall = Some(Stopwatch::start());
            l.rounds
        };
        self.apply_due_faults(round_idx);
    }

    /// Execute `f` on behalf of `machine`, really timing it and charging
    /// the measured seconds to that machine's budget for this round.
    pub fn run_task<T>(&self, machine: usize, f: impl FnOnce() -> T) -> T {
        // mli-lint: allow(D002) by design: really measures f and charges the sim ledger
        let sw = Stopwatch::start();
        let out = f();
        let secs = sw.elapsed_secs();
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l
            .current
            .as_mut()
            .expect("run_task outside begin_round/end_round");
        cur.machine_compute_s[machine] += secs;
        cur.machine_tasks[machine] += 1;
        cur.task_times.push((machine, secs));
        out
    }

    /// Charge pre-measured compute seconds (used when a task's cost was
    /// measured once and replayed for many simulated machines).
    pub fn charge_compute(&self, machine: usize, secs: f64) {
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l.current.as_mut().expect("charge_compute outside round");
        cur.machine_compute_s[machine] += secs;
        cur.machine_tasks[machine] += 1;
        cur.task_times.push((machine, secs));
    }

    /// Charge one model-allreduce with the given topology.
    pub fn charge_allreduce(&self, topo: CommTopology, bytes: u64) {
        let t = topo.allreduce_time(&self.net, self.specs.len(), bytes);
        let mut l = lock_unpoisoned(&self.ledger);
        let m = self.specs.len() as u64;
        let cur = l.current.as_mut().expect("charge_allreduce outside round");
        cur.comm_s += t;
        cur.net_bytes += 2 * bytes * m.saturating_sub(1);
    }

    /// Charge a master broadcast.
    pub fn charge_broadcast(&self, topo: CommTopology, bytes: u64) {
        let t = topo.broadcast_time(&self.net, self.specs.len(), bytes);
        let mut l = lock_unpoisoned(&self.ledger);
        let m = self.specs.len() as u64;
        let cur = l.current.as_mut().expect("charge_broadcast outside round");
        cur.comm_s += t;
        cur.net_bytes += bytes * m.saturating_sub(1);
    }

    /// Charge an all-to-all shuffle: `bytes_by_src[i]` leaves machine i,
    /// spread evenly over the others. Bottleneck-link model.
    pub fn charge_shuffle(&self, bytes_by_src: &[u64]) {
        let m = self.specs.len();
        if m <= 1 {
            return;
        }
        let total: u64 = bytes_by_src.iter().sum();
        // each machine receives ~total/m; sends its own share. NIC is
        // full-duplex; time = max over machines of max(out, in)/bw.
        let max_out = bytes_by_src.iter().copied().max().unwrap_or(0) as f64;
        let avg_in = total as f64 / m as f64;
        let t = self.net.latency_s * (m as f64).log2().max(1.0)
            + max_out.max(avg_in) / self.net.bandwidth_bps;
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l.current.as_mut().expect("charge_shuffle outside round");
        cur.comm_s += t;
        cur.net_bytes += total;
    }

    /// Charge an HDFS-surrogate write+read of intermediate state (the
    /// Mahout baseline's per-iteration materialization).
    pub fn charge_hdfs_roundtrip(&self, bytes_per_machine: u64) {
        let t = self.net.hdfs_write_time(bytes_per_machine)
            + self.net.hdfs_read_time(bytes_per_machine);
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l.current.as_mut().expect("charge_hdfs outside round");
        cur.disk_s += t;
    }

    /// Charge a fixed job-startup overhead (Hadoop JVM spawn).
    pub fn charge_job_startup(&self) {
        let t = self.net.job_startup_s;
        let mut l = lock_unpoisoned(&self.ledger);
        let cur = l.current.as_mut().expect("charge_job_startup outside round");
        cur.disk_s += t;
    }

    /// Switch the straggler model (see [`StragglerModel`]).
    pub fn with_straggler(self, s: StragglerModel) -> SimCluster {
        *lock_unpoisoned(&self.straggler) = s;
        self
    }

    /// Attach a work-stealing [`ThreadPool`] so algorithm layers can fan
    /// partition tasks out across host threads (`SimCluster::ec2(8)
    /// .with_executor(4)`). `threads == 0` picks a default sized by the
    /// host (`ThreadPool::default_threads`) capped at the fleet size —
    /// more host threads than simulated machines buys nothing in a
    /// bulk-synchronous round. Simulated time is unaffected either way.
    pub fn with_executor(self, threads: usize) -> SimCluster {
        let n = if threads == 0 {
            ThreadPool::default_threads().min(self.num_machines()).max(1)
        } else {
            threads
        };
        let pool = ThreadPool::new(n);
        pool.set_tracer(self.tracer());
        *lock_unpoisoned(&self.executor) = Some(pool);
        self
    }

    /// The attached executor, if any.
    pub fn pool(&self) -> Option<Arc<ThreadPool>> {
        lock_unpoisoned(&self.executor).clone()
    }

    /// Attach a tracer: `end_round` records one span per simulated round
    /// (wall-clock duration, simulated seconds in the args) plus the
    /// `sim.micros` / `wall.micros` counters behind the summary's
    /// two-clock attribution. Chains like `with_executor`.
    pub fn with_tracer(self, tracer: Arc<Tracer>) -> SimCluster {
        self.set_tracer(tracer);
        self
    }

    /// Swap the tracer, propagating it to the attached pool (if any).
    pub fn set_tracer(&self, tracer: Arc<Tracer>) {
        if let Some(pool) = self.pool() {
            pool.set_tracer(tracer.clone());
        }
        *lock_unpoisoned(&self.tracer) = tracer;
    }

    pub fn tracer(&self) -> Arc<Tracer> {
        lock_unpoisoned(&self.tracer).clone()
    }

    /// Close the round: apply the speculative-execution rebalance (if
    /// enabled), fold the round into the total, and return its stats.
    pub fn end_round(&self) -> RoundStats {
        let spec_k = self.speculation();
        let alive: Vec<bool> = lock_unpoisoned(&self.health)
            .iter()
            .map(|h| *h == MachineHealth::Up)
            .collect();
        let (cur, t, wall_s, round_idx, launched, wins) = {
            let mut l = lock_unpoisoned(&self.ledger);
            let mut cur = l.current.take().expect("end_round without begin_round");
            let (launched, wins) = match spec_k {
                Some(k) => Self::apply_speculation(&mut cur, k, &alive),
                None => (0, 0),
            };
            l.spec_launched += launched;
            l.spec_wins += wins;
            let t = cur.round_time_with(&self.specs, *lock_unpoisoned(&self.straggler));
            l.total_s += t;
            l.total_comm_s += cur.comm_s;
            l.total_disk_s += cur.disk_s;
            l.total_net_bytes += cur.net_bytes;
            l.rounds += 1;
            let wall_s = l
                .round_wall
                .take()
                .map(|sw| sw.elapsed_secs())
                .unwrap_or(0.0);
            (cur, t, wall_s, l.rounds - 1, launched, wins)
        };
        // Record the round span outside the ledger lock: wall-clock time
        // as the span duration, simulated seconds in the args — the
        // two-clock attribution the trace summary reports.
        let tracer = self.tracer();
        if tracer.is_enabled() {
            let wall_ns = (wall_s * 1e9) as u64;
            let start = tracer.now_ns().saturating_sub(wall_ns);
            tracer.span(
                format!("sim-round-{round_idx}"),
                "sim",
                0,
                start,
                &[("sim_s", t), ("comm_s", cur.comm_s), ("disk_s", cur.disk_s)],
            );
            tracer.count("sim.rounds", 1);
            tracer.count("sim.micros", (t * 1e6) as u64);
            tracer.count("wall.micros", (wall_s * 1e6) as u64);
            if launched > 0 {
                tracer.count("spec.launched", launched);
                tracer.count("spec.wins", wins);
                tracer.count("spec.losses", launched - wins);
            }
        }
        cur
    }

    // -- queries ----------------------------------------------------------

    pub fn total_sim_seconds(&self) -> f64 {
        lock_unpoisoned(&self.ledger).total_s
    }

    pub fn total_comm_seconds(&self) -> f64 {
        lock_unpoisoned(&self.ledger).total_comm_s
    }

    pub fn total_disk_seconds(&self) -> f64 {
        lock_unpoisoned(&self.ledger).total_disk_s
    }

    pub fn total_net_bytes(&self) -> u64 {
        lock_unpoisoned(&self.ledger).total_net_bytes
    }

    pub fn rounds(&self) -> usize {
        lock_unpoisoned(&self.ledger).rounds
    }

    /// Reset the ledger (memory accounting persists).
    pub fn reset_time(&self) {
        let mut l = lock_unpoisoned(&self.ledger);
        l.total_s = 0.0;
        l.total_comm_s = 0.0;
        l.total_disk_s = 0.0;
        l.total_net_bytes = 0;
        l.rounds = 0;
        l.current = None;
        l.round_wall = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_accumulates_max_compute_plus_comm() {
        let c = SimCluster::ec2(4);
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.charge_compute(1, 3.0);
        c.charge_compute(2, 2.0);
        c.charge_allreduce(CommTopology::StarGatherBroadcast, 1_000_000);
        let stats = c.end_round();
        // slowest machine (3s) dominates; 1 task/machine => no core speedup
        let round = stats.round_time(&c.specs);
        assert!(round > 3.0 && round < 3.1, "round={round}");
        assert_eq!(c.rounds(), 1);
        assert!(c.total_sim_seconds() > 3.0);
        assert!(c.total_net_bytes() > 0);
    }

    #[test]
    fn multicore_parallelism_divides_task_time() {
        let c = SimCluster::ec2(1); // 8 cores
        c.begin_round();
        for _ in 0..8 {
            c.charge_compute(0, 1.0);
        }
        let stats = c.end_round();
        let t = stats.round_time(&c.specs);
        assert!((t - 1.0).abs() < 1e-9, "8 tasks on 8 cores = 1s, got {t}");
    }

    #[test]
    fn compute_factor_scales() {
        let spec = MachineSpec::default().with_compute_factor(0.5);
        let c = SimCluster::new(2, spec, NetworkModel::ec2_2013());
        c.begin_round();
        c.charge_compute(0, 2.0);
        let t = c.end_round().round_time(&c.specs);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn run_task_measures_and_returns() {
        let c = SimCluster::ec2(2);
        c.begin_round();
        let v = c.run_task(1, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        let stats = c.end_round();
        assert!(stats.machine_compute_s[1] >= 0.004);
        assert_eq!(stats.machine_tasks[1], 1);
        assert_eq!(stats.machine_tasks[0], 0);
    }

    #[test]
    fn oom_when_capacity_exceeded() {
        let spec = MachineSpec::default().with_mem_bytes(1000);
        let c = SimCluster::new(1, spec, NetworkModel::ec2_2013());
        assert!(c.alloc(0, 800).is_ok());
        let err = c.alloc(0, 300).unwrap_err();
        assert!(err.is_oom());
        c.free(0, 800);
        assert!(c.alloc(0, 900).is_ok());
        assert_eq!(c.resident(0), 900);
    }

    #[test]
    fn hdfs_and_startup_charges_to_disk() {
        let c = SimCluster::ec2(2);
        c.begin_round();
        c.charge_job_startup();
        c.charge_hdfs_roundtrip(100_000_000); // 0.3s wr*3repl + 1s... = 4s
        let stats = c.end_round();
        assert!(stats.disk_s > 10.0); // 10s startup dominates
        assert!(c.total_disk_seconds() > 10.0);
    }

    #[test]
    fn shuffle_bottleneck_model() {
        let c = SimCluster::ec2(4);
        c.begin_round();
        c.charge_shuffle(&[1_000_000, 1_000_000, 1_000_000, 9_000_000]);
        let stats = c.end_round();
        // bottleneck is the 9MB sender at 125MB/s ~ 72ms
        assert!(stats.comm_s > 0.07 && stats.comm_s < 0.08, "{}", stats.comm_s);
        // single machine: free
        let c1 = SimCluster::ec2(1);
        c1.begin_round();
        c1.charge_shuffle(&[123]);
        assert_eq!(c1.end_round().comm_s, 0.0);
    }

    #[test]
    fn reset_clears_time_not_memory() {
        let c = SimCluster::ec2(1);
        c.alloc(0, 100).unwrap();
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.end_round();
        c.reset_time();
        assert_eq!(c.total_sim_seconds(), 0.0);
        assert_eq!(c.rounds(), 0);
        assert_eq!(c.resident(0), 100);
    }

    #[test]
    #[should_panic(expected = "outside round")]
    fn task_outside_round_panics() {
        let c = SimCluster::ec2(1);
        c.charge_compute(0, 1.0);
    }

    #[test]
    fn traced_round_records_both_clocks() {
        let (tracer, sink) = Tracer::recording();
        let c = SimCluster::ec2(2).with_tracer(tracer);
        c.begin_round();
        c.charge_compute(0, 2.0);
        c.end_round();
        let spans = sink.spans();
        assert!(
            spans.iter().any(|s| s.name == "sim-round-0" && s.cat == "sim"),
            "round span missing: {spans:?}"
        );
        assert_eq!(sink.counter("sim.rounds"), 1);
        // 2.0 simulated seconds = 2M micros (1 task, factor 1.0, no comm)
        assert_eq!(sink.counter("sim.micros"), 2_000_000);
    }

    #[test]
    fn executor_attach_and_parallel_run_task() {
        let c = SimCluster::ec2(4).with_executor(2);
        let pool = c.pool().expect("pool attached");
        assert_eq!(pool.threads(), 2);
        // concurrent run_task charges from pool workers all land
        c.begin_round();
        let outs = pool.run(8, |p| c.run_task(c.machine_of(p), || p * 2));
        assert_eq!(outs, (0..8).map(|p| p * 2).collect::<Vec<_>>());
        let stats = c.end_round();
        assert_eq!(stats.machine_tasks.iter().sum::<usize>(), 8);
        // default sizing caps at fleet size
        let c1 = SimCluster::ec2(1).with_executor(0);
        assert_eq!(c1.pool().unwrap().threads(), 1);
    }

    #[test]
    fn kill_reroutes_placement_and_restore_reverts() {
        let c = SimCluster::ec2(4);
        assert_eq!(c.assign_machine(1).unwrap(), 1);
        c.kill_machine(1, None);
        assert!(!c.is_up(1));
        assert_eq!(c.num_alive(), 3);
        // primary dead: first alive machine scanning up
        assert_eq!(c.assign_machine(1).unwrap(), 2);
        assert_eq!(c.assign_machine(5).unwrap(), 2);
        assert_eq!(c.assign_machine(0).unwrap(), 0);
        c.restore_machine(1);
        assert_eq!(c.assign_machine(1).unwrap(), 1);
        assert_eq!(c.fault_stats(), (1, 1));
        // killing an already-dead machine is a no-op
        c.kill_machine(2, None);
        assert_eq!(c.kill_machine(2, None), 0);
        assert_eq!(c.fault_stats().0, 2);
    }

    #[test]
    fn all_machines_dead_is_typed_fault_recovery() {
        let c = SimCluster::ec2(2);
        c.kill_machine(0, None);
        c.kill_machine(1, None);
        let err = c.assign_machine(0).unwrap_err();
        assert!(err.is_fault_recovery(), "got {err}");
    }

    #[test]
    fn kill_drops_resident_bytes_and_charges_reread() {
        let c = SimCluster::ec2(2);
        c.alloc(1, 100_000_000).unwrap(); // 100 MB @ 100 MB/s disk
        c.begin_round();
        let lost = c.kill_machine(1, None);
        assert_eq!(lost, 100_000_000);
        assert_eq!(c.resident(1), 0);
        let stats = c.end_round();
        assert!((stats.disk_s - 1.0).abs() < 1e-9, "disk_s={}", stats.disk_s);
    }

    #[test]
    fn fault_plan_fires_at_round_and_restarts_after_delay() {
        let plan = Arc::new(FaultPlan::new());
        plan.kill_at(1, 0, FaultKind::Crash { restart_after: 1 });
        let c = SimCluster::ec2(2).with_faults(plan.clone());
        c.begin_round(); // round 0: nothing due
        assert!(c.is_up(0));
        c.end_round();
        c.begin_round(); // round 1: kill fires before work runs
        assert!(!c.is_up(0));
        assert_eq!(c.assign_machine(0).unwrap(), 1);
        c.end_round();
        c.begin_round(); // round 2: restart delay elapsed
        assert!(c.is_up(0));
        c.end_round();
        assert_eq!(c.fault_stats(), (1, 1));
        assert_eq!(plan.remaining(), 0);
    }

    #[test]
    fn loss_listener_fires_with_machine_index() {
        use std::sync::atomic::AtomicUsize;
        let c = SimCluster::ec2(4);
        let seen = Arc::new(AtomicUsize::new(usize::MAX));
        let s = seen.clone();
        c.on_machine_loss(move |m| s.store(m, Ordering::SeqCst));
        c.kill_machine(3, None);
        assert_eq!(seen.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn speculation_rebalances_straggler_to_backup() {
        let c = SimCluster::ec2(4).with_speculation(2.0);
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.charge_compute(1, 1.0);
        c.charge_compute(2, 1.0);
        c.charge_compute(3, 10.0); // straggler: 10 >= 2 x median(1.0)
        let stats = c.end_round();
        // backup launched at 2s, replays at median speed: done at 3s; the
        // straggler machine is gated at 3s, the copy (1s) lands on the
        // least-loaded machine (0), which still finishes in 2s/2 cores
        let t = stats.round_time(&c.specs);
        assert!((t - 3.0).abs() < 1e-9, "round={t}");
        assert_eq!(c.speculation_stats(), (1, 1));
        assert!((stats.machine_compute_s[3] - 3.0).abs() < 1e-9);
        assert!((stats.machine_compute_s[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn speculation_skips_mild_spread_and_is_off_by_default() {
        let c = SimCluster::ec2(2).with_speculation(4.0);
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.charge_compute(1, 2.0); // 2 < 4 x median(1.5): no candidate
        let t = c.end_round().round_time(&c.specs);
        assert!((t - 2.0).abs() < 1e-9);
        assert_eq!(c.speculation_stats(), (0, 0));
        // disabled: stragglers keep their full time
        let c2 = SimCluster::ec2(2);
        c2.begin_round();
        c2.charge_compute(0, 1.0);
        c2.charge_compute(1, 10.0);
        assert!((c2.end_round().round_time(&c2.specs) - 10.0).abs() < 1e-9);
        assert_eq!(c2.speculation_stats(), (0, 0));
    }

    #[test]
    fn fault_events_emit_trace_counters() {
        let (tracer, sink) = Tracer::recording();
        let c = SimCluster::ec2(2).with_tracer(tracer);
        c.alloc(0, 1_000).unwrap();
        c.kill_machine(0, None);
        c.restore_machine(0);
        assert_eq!(sink.counter("fault.kills"), 1);
        assert_eq!(sink.counter("fault.restarts"), 1);
        assert!(
            sink.spans().iter().any(|s| s.name == "fault:kill-machine-0" && s.cat == "fault"),
            "kill span missing"
        );
    }
}
