//! SimCluster: the simulated-time ledger that turns really-measured
//! per-partition compute plus analytically-charged communication into
//! per-round and total walltime estimates.
//!
//! Usage pattern (bulk-synchronous, as all of the paper's systems are):
//!
//! ```text
//! let cluster = SimCluster::new(32, MachineSpec::default(), NetworkModel::default());
//! for round in 0..iters {
//!     cluster.begin_round();
//!     for (p, task) in partitions { cluster.run_task(machine_of(p), || compute(p)); }
//!     cluster.charge_allreduce(CommTopology::StarGatherBroadcast, model_bytes);
//!     cluster.end_round();
//! }
//! let t = cluster.total_sim_seconds();
//! ```

use std::sync::{Arc, Mutex};

use super::machine::MachineSpec;
use super::network::NetworkModel;
use super::topology::CommTopology;
use crate::error::{Error, Result};
use crate::exec::ThreadPool;
use crate::trace::Tracer;
use crate::util::timer::Stopwatch;

/// Per-round accounting.
#[derive(Debug, Clone, Default)]
pub struct RoundStats {
    /// Per-machine accumulated compute seconds this round (after
    /// compute_factor and core-parallelism adjustment).
    pub machine_compute_s: Vec<f64>,
    /// Tasks executed per machine this round (for the parallelism model).
    pub machine_tasks: Vec<usize>,
    /// Communication seconds charged this round.
    pub comm_s: f64,
    /// Disk seconds charged this round (HDFS surrogate).
    pub disk_s: f64,
    /// Bytes moved over the network this round.
    pub net_bytes: u64,
}

impl RoundStats {
    fn new(machines: usize) -> RoundStats {
        RoundStats {
            machine_compute_s: vec![0.0; machines],
            machine_tasks: vec![0; machines],
            ..Default::default()
        }
    }

    /// Per-machine effective compute seconds this round.
    fn machine_times(&self, specs: &[MachineSpec]) -> Vec<f64> {
        self.machine_compute_s
            .iter()
            .zip(self.machine_tasks.iter())
            .zip(specs.iter())
            .map(|((&secs, &tasks), spec)| {
                // tasks on one machine run min(cores, tasks)-way parallel
                let par = spec.cores.min(tasks.max(1)) as f64;
                secs * spec.compute_factor / par
            })
            .collect()
    }

    /// The bulk-synchronous round time: slowest machine + comm + disk.
    pub fn round_time(&self, specs: &[MachineSpec]) -> f64 {
        self.round_time_with(specs, StragglerModel::Max)
    }

    /// Round time under a chosen straggler model.
    pub fn round_time_with(&self, specs: &[MachineSpec], s: StragglerModel) -> f64 {
        let times = self.machine_times(specs);
        let compute = match s {
            StragglerModel::Max => times.iter().fold(0.0f64, |a, &b| a.max(b)),
            StragglerModel::Median => {
                let active: Vec<f64> = times.iter().copied().filter(|&t| t > 0.0).collect();
                crate::util::median(&active)
            }
        };
        compute + self.comm_s + self.disk_s
    }
}

/// How the bulk-synchronous barrier treats per-machine compute spread.
///
/// `Max` is the true BSP semantics (slowest machine gates the round).
/// `Median` models a *homogeneous* fleet: on this 1-core host all
/// "machines" share one core, so the empirical max is contaminated by
/// host noise (page cache, allocator, XLA thread pool) that real,
/// independent machines would not correlate on. Benches over homogeneous
/// synthetic partitions use `Median`; heterogeneity experiments use `Max`.
/// (DESIGN.md §3.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StragglerModel {
    Max,
    Median,
}

/// The running ledger of simulated time.
#[derive(Debug, Default)]
pub struct SimLedger {
    pub total_s: f64,
    pub total_comm_s: f64,
    pub total_disk_s: f64,
    pub total_net_bytes: u64,
    pub rounds: usize,
    current: Option<RoundStats>,
    /// Wall-clock stopwatch for the open round (trace attribution only;
    /// simulated time never reads it).
    round_wall: Option<Stopwatch>,
    /// Per-machine resident bytes (simulated memory accounting).
    pub resident_bytes: Vec<u64>,
}

/// A simulated cluster: machine fleet + network + time ledger.
///
/// Interior mutability is mutex-guarded (`Send + Sync`) so that tasks
/// running concurrently on the `exec` thread pool can record compute time
/// into the ledger; charges are commutative sums, so simulated time is
/// independent of the host thread count.
pub struct SimCluster {
    pub specs: Vec<MachineSpec>,
    pub net: NetworkModel,
    pub straggler: Mutex<StragglerModel>,
    ledger: Mutex<SimLedger>,
    executor: Mutex<Option<Arc<ThreadPool>>>,
    tracer: Mutex<Arc<Tracer>>,
}

impl SimCluster {
    pub fn new(machines: usize, spec: MachineSpec, net: NetworkModel) -> SimCluster {
        assert!(machines > 0, "cluster needs >= 1 machine");
        let mut ledger = SimLedger::default();
        ledger.resident_bytes = vec![0; machines];
        SimCluster {
            specs: vec![spec; machines],
            net,
            straggler: Mutex::new(StragglerModel::Max),
            ledger: Mutex::new(ledger),
            executor: Mutex::new(None),
            tracer: Mutex::new(Tracer::disabled()),
        }
    }

    /// Homogeneous fleet, default EC2 specs (the common case in benches).
    pub fn ec2(machines: usize) -> SimCluster {
        SimCluster::new(machines, MachineSpec::default(), NetworkModel::ec2_2013())
    }

    pub fn num_machines(&self) -> usize {
        self.specs.len()
    }

    /// Machine owning partition `p` under round-robin placement.
    pub fn machine_of(&self, partition: usize) -> usize {
        partition % self.specs.len()
    }

    // -- memory model ---------------------------------------------------

    /// Charge `bytes` of resident memory on a machine; simulated OOM if
    /// capacity is exceeded (the paper's MATLAB 16x/25x failures).
    pub fn alloc(&self, machine: usize, bytes: u64) -> Result<()> {
        let mut l = self.ledger.lock().unwrap();
        let resident = &mut l.resident_bytes[machine];
        let cap = self.specs[machine].mem_bytes;
        if *resident + bytes > cap {
            return Err(Error::Oom(format!(
                "machine {machine}: {} + {} exceeds {} capacity",
                crate::util::human_bytes(*resident),
                crate::util::human_bytes(bytes),
                crate::util::human_bytes(cap)
            )));
        }
        *resident += bytes;
        Ok(())
    }

    pub fn free(&self, machine: usize, bytes: u64) {
        let mut l = self.ledger.lock().unwrap();
        let r = &mut l.resident_bytes[machine];
        *r = r.saturating_sub(bytes);
    }

    pub fn resident(&self, machine: usize) -> u64 {
        self.ledger.lock().unwrap().resident_bytes[machine]
    }

    // -- round lifecycle --------------------------------------------------

    pub fn begin_round(&self) {
        let mut l = self.ledger.lock().unwrap();
        assert!(l.current.is_none(), "begin_round inside an open round");
        l.current = Some(RoundStats::new(self.specs.len()));
        l.round_wall = Some(Stopwatch::start());
    }

    /// Execute `f` on behalf of `machine`, really timing it and charging
    /// the measured seconds to that machine's budget for this round.
    pub fn run_task<T>(&self, machine: usize, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::start();
        let out = f();
        let secs = sw.elapsed_secs();
        let mut l = self.ledger.lock().unwrap();
        let cur = l
            .current
            .as_mut()
            .expect("run_task outside begin_round/end_round");
        cur.machine_compute_s[machine] += secs;
        cur.machine_tasks[machine] += 1;
        out
    }

    /// Charge pre-measured compute seconds (used when a task's cost was
    /// measured once and replayed for many simulated machines).
    pub fn charge_compute(&self, machine: usize, secs: f64) {
        let mut l = self.ledger.lock().unwrap();
        let cur = l.current.as_mut().expect("charge_compute outside round");
        cur.machine_compute_s[machine] += secs;
        cur.machine_tasks[machine] += 1;
    }

    /// Charge one model-allreduce with the given topology.
    pub fn charge_allreduce(&self, topo: CommTopology, bytes: u64) {
        let t = topo.allreduce_time(&self.net, self.specs.len(), bytes);
        let mut l = self.ledger.lock().unwrap();
        let m = self.specs.len() as u64;
        let cur = l.current.as_mut().expect("charge_allreduce outside round");
        cur.comm_s += t;
        cur.net_bytes += 2 * bytes * m.saturating_sub(1);
    }

    /// Charge a master broadcast.
    pub fn charge_broadcast(&self, topo: CommTopology, bytes: u64) {
        let t = topo.broadcast_time(&self.net, self.specs.len(), bytes);
        let mut l = self.ledger.lock().unwrap();
        let m = self.specs.len() as u64;
        let cur = l.current.as_mut().expect("charge_broadcast outside round");
        cur.comm_s += t;
        cur.net_bytes += bytes * m.saturating_sub(1);
    }

    /// Charge an all-to-all shuffle: `bytes_by_src[i]` leaves machine i,
    /// spread evenly over the others. Bottleneck-link model.
    pub fn charge_shuffle(&self, bytes_by_src: &[u64]) {
        let m = self.specs.len();
        if m <= 1 {
            return;
        }
        let total: u64 = bytes_by_src.iter().sum();
        // each machine receives ~total/m; sends its own share. NIC is
        // full-duplex; time = max over machines of max(out, in)/bw.
        let max_out = bytes_by_src.iter().copied().max().unwrap_or(0) as f64;
        let avg_in = total as f64 / m as f64;
        let t = self.net.latency_s * (m as f64).log2().max(1.0)
            + max_out.max(avg_in) / self.net.bandwidth_bps;
        let mut l = self.ledger.lock().unwrap();
        let cur = l.current.as_mut().expect("charge_shuffle outside round");
        cur.comm_s += t;
        cur.net_bytes += total;
    }

    /// Charge an HDFS-surrogate write+read of intermediate state (the
    /// Mahout baseline's per-iteration materialization).
    pub fn charge_hdfs_roundtrip(&self, bytes_per_machine: u64) {
        let t = self.net.hdfs_write_time(bytes_per_machine)
            + self.net.hdfs_read_time(bytes_per_machine);
        let mut l = self.ledger.lock().unwrap();
        let cur = l.current.as_mut().expect("charge_hdfs outside round");
        cur.disk_s += t;
    }

    /// Charge a fixed job-startup overhead (Hadoop JVM spawn).
    pub fn charge_job_startup(&self) {
        let t = self.net.job_startup_s;
        let mut l = self.ledger.lock().unwrap();
        let cur = l.current.as_mut().expect("charge_job_startup outside round");
        cur.disk_s += t;
    }

    /// Switch the straggler model (see [`StragglerModel`]).
    pub fn with_straggler(self, s: StragglerModel) -> SimCluster {
        *self.straggler.lock().unwrap() = s;
        self
    }

    /// Attach a work-stealing [`ThreadPool`] so algorithm layers can fan
    /// partition tasks out across host threads (`SimCluster::ec2(8)
    /// .with_executor(4)`). `threads == 0` picks a default sized by the
    /// host (`ThreadPool::default_threads`) capped at the fleet size —
    /// more host threads than simulated machines buys nothing in a
    /// bulk-synchronous round. Simulated time is unaffected either way.
    pub fn with_executor(self, threads: usize) -> SimCluster {
        let n = if threads == 0 {
            ThreadPool::default_threads().min(self.num_machines()).max(1)
        } else {
            threads
        };
        let pool = ThreadPool::new(n);
        pool.set_tracer(self.tracer());
        *self.executor.lock().unwrap() = Some(pool);
        self
    }

    /// The attached executor, if any.
    pub fn pool(&self) -> Option<Arc<ThreadPool>> {
        self.executor.lock().unwrap().clone()
    }

    /// Attach a tracer: `end_round` records one span per simulated round
    /// (wall-clock duration, simulated seconds in the args) plus the
    /// `sim.micros` / `wall.micros` counters behind the summary's
    /// two-clock attribution. Chains like `with_executor`.
    pub fn with_tracer(self, tracer: Arc<Tracer>) -> SimCluster {
        self.set_tracer(tracer);
        self
    }

    /// Swap the tracer, propagating it to the attached pool (if any).
    pub fn set_tracer(&self, tracer: Arc<Tracer>) {
        if let Some(pool) = self.pool() {
            pool.set_tracer(tracer.clone());
        }
        *self.tracer.lock().unwrap_or_else(|e| e.into_inner()) = tracer;
    }

    pub fn tracer(&self) -> Arc<Tracer> {
        self.tracer.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Close the round: fold it into the total and return its stats.
    pub fn end_round(&self) -> RoundStats {
        let (cur, t, wall_s, round_idx) = {
            let mut l = self.ledger.lock().unwrap();
            let cur = l.current.take().expect("end_round without begin_round");
            let t = cur.round_time_with(&self.specs, *self.straggler.lock().unwrap());
            l.total_s += t;
            l.total_comm_s += cur.comm_s;
            l.total_disk_s += cur.disk_s;
            l.total_net_bytes += cur.net_bytes;
            l.rounds += 1;
            let wall_s = l
                .round_wall
                .take()
                .map(|sw| sw.elapsed_secs())
                .unwrap_or(0.0);
            (cur, t, wall_s, l.rounds - 1)
        };
        // Record the round span outside the ledger lock: wall-clock time
        // as the span duration, simulated seconds in the args — the
        // two-clock attribution the trace summary reports.
        let tracer = self.tracer();
        if tracer.is_enabled() {
            let wall_ns = (wall_s * 1e9) as u64;
            let start = tracer.now_ns().saturating_sub(wall_ns);
            tracer.span(
                format!("sim-round-{round_idx}"),
                "sim",
                0,
                start,
                &[("sim_s", t), ("comm_s", cur.comm_s), ("disk_s", cur.disk_s)],
            );
            tracer.count("sim.rounds", 1);
            tracer.count("sim.micros", (t * 1e6) as u64);
            tracer.count("wall.micros", (wall_s * 1e6) as u64);
        }
        cur
    }

    // -- queries ----------------------------------------------------------

    pub fn total_sim_seconds(&self) -> f64 {
        self.ledger.lock().unwrap().total_s
    }

    pub fn total_comm_seconds(&self) -> f64 {
        self.ledger.lock().unwrap().total_comm_s
    }

    pub fn total_disk_seconds(&self) -> f64 {
        self.ledger.lock().unwrap().total_disk_s
    }

    pub fn total_net_bytes(&self) -> u64 {
        self.ledger.lock().unwrap().total_net_bytes
    }

    pub fn rounds(&self) -> usize {
        self.ledger.lock().unwrap().rounds
    }

    /// Reset the ledger (memory accounting persists).
    pub fn reset_time(&self) {
        let mut l = self.ledger.lock().unwrap();
        l.total_s = 0.0;
        l.total_comm_s = 0.0;
        l.total_disk_s = 0.0;
        l.total_net_bytes = 0;
        l.rounds = 0;
        l.current = None;
        l.round_wall = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_accumulates_max_compute_plus_comm() {
        let c = SimCluster::ec2(4);
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.charge_compute(1, 3.0);
        c.charge_compute(2, 2.0);
        c.charge_allreduce(CommTopology::StarGatherBroadcast, 1_000_000);
        let stats = c.end_round();
        // slowest machine (3s) dominates; 1 task/machine => no core speedup
        let round = stats.round_time(&c.specs);
        assert!(round > 3.0 && round < 3.1, "round={round}");
        assert_eq!(c.rounds(), 1);
        assert!(c.total_sim_seconds() > 3.0);
        assert!(c.total_net_bytes() > 0);
    }

    #[test]
    fn multicore_parallelism_divides_task_time() {
        let c = SimCluster::ec2(1); // 8 cores
        c.begin_round();
        for _ in 0..8 {
            c.charge_compute(0, 1.0);
        }
        let stats = c.end_round();
        let t = stats.round_time(&c.specs);
        assert!((t - 1.0).abs() < 1e-9, "8 tasks on 8 cores = 1s, got {t}");
    }

    #[test]
    fn compute_factor_scales() {
        let spec = MachineSpec::default().with_compute_factor(0.5);
        let c = SimCluster::new(2, spec, NetworkModel::ec2_2013());
        c.begin_round();
        c.charge_compute(0, 2.0);
        let t = c.end_round().round_time(&c.specs);
        assert!((t - 1.0).abs() < 1e-9);
    }

    #[test]
    fn run_task_measures_and_returns() {
        let c = SimCluster::ec2(2);
        c.begin_round();
        let v = c.run_task(1, || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        let stats = c.end_round();
        assert!(stats.machine_compute_s[1] >= 0.004);
        assert_eq!(stats.machine_tasks[1], 1);
        assert_eq!(stats.machine_tasks[0], 0);
    }

    #[test]
    fn oom_when_capacity_exceeded() {
        let spec = MachineSpec::default().with_mem_bytes(1000);
        let c = SimCluster::new(1, spec, NetworkModel::ec2_2013());
        assert!(c.alloc(0, 800).is_ok());
        let err = c.alloc(0, 300).unwrap_err();
        assert!(err.is_oom());
        c.free(0, 800);
        assert!(c.alloc(0, 900).is_ok());
        assert_eq!(c.resident(0), 900);
    }

    #[test]
    fn hdfs_and_startup_charges_to_disk() {
        let c = SimCluster::ec2(2);
        c.begin_round();
        c.charge_job_startup();
        c.charge_hdfs_roundtrip(100_000_000); // 0.3s wr*3repl + 1s... = 4s
        let stats = c.end_round();
        assert!(stats.disk_s > 10.0); // 10s startup dominates
        assert!(c.total_disk_seconds() > 10.0);
    }

    #[test]
    fn shuffle_bottleneck_model() {
        let c = SimCluster::ec2(4);
        c.begin_round();
        c.charge_shuffle(&[1_000_000, 1_000_000, 1_000_000, 9_000_000]);
        let stats = c.end_round();
        // bottleneck is the 9MB sender at 125MB/s ~ 72ms
        assert!(stats.comm_s > 0.07 && stats.comm_s < 0.08, "{}", stats.comm_s);
        // single machine: free
        let c1 = SimCluster::ec2(1);
        c1.begin_round();
        c1.charge_shuffle(&[123]);
        assert_eq!(c1.end_round().comm_s, 0.0);
    }

    #[test]
    fn reset_clears_time_not_memory() {
        let c = SimCluster::ec2(1);
        c.alloc(0, 100).unwrap();
        c.begin_round();
        c.charge_compute(0, 1.0);
        c.end_round();
        c.reset_time();
        assert_eq!(c.total_sim_seconds(), 0.0);
        assert_eq!(c.rounds(), 0);
        assert_eq!(c.resident(0), 100);
    }

    #[test]
    #[should_panic(expected = "outside round")]
    fn task_outside_round_panics() {
        let c = SimCluster::ec2(1);
        c.charge_compute(0, 1.0);
    }

    #[test]
    fn traced_round_records_both_clocks() {
        let (tracer, sink) = Tracer::recording();
        let c = SimCluster::ec2(2).with_tracer(tracer);
        c.begin_round();
        c.charge_compute(0, 2.0);
        c.end_round();
        let spans = sink.spans();
        assert!(
            spans.iter().any(|s| s.name == "sim-round-0" && s.cat == "sim"),
            "round span missing: {spans:?}"
        );
        assert_eq!(sink.counter("sim.rounds"), 1);
        // 2.0 simulated seconds = 2M micros (1 task, factor 1.0, no comm)
        assert_eq!(sink.counter("sim.micros"), 2_000_000);
    }

    #[test]
    fn executor_attach_and_parallel_run_task() {
        let c = SimCluster::ec2(4).with_executor(2);
        let pool = c.pool().expect("pool attached");
        assert_eq!(pool.threads(), 2);
        // concurrent run_task charges from pool workers all land
        c.begin_round();
        let outs = pool.run(8, |p| c.run_task(c.machine_of(p), || p * 2));
        assert_eq!(outs, (0..8).map(|p| p * 2).collect::<Vec<_>>());
        let stats = c.end_round();
        assert_eq!(stats.machine_tasks.iter().sum::<usize>(), 8);
        // default sizing caps at fleet size
        let c1 = SimCluster::ec2(1).with_executor(0);
        assert_eq!(c1.pool().unwrap().threads(), 1);
    }
}
