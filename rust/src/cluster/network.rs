//! Analytic network model: alpha-beta (latency + byte) costs, plus a disk
//! model for HDFS-style intermediate state (the Mahout baseline).

/// Alpha-beta network cost model.
///
/// A message of `s` bytes between two machines costs
/// `latency_s + s / bandwidth_bps`. Defaults model the paper's EC2
/// us-east placement: ~0.5 ms latency, 1 Gbit/s effective point-to-point
/// bandwidth (m2.4xlarge is "high" I/O: 1 GbE).
#[derive(Debug, Clone)]
pub struct NetworkModel {
    pub latency_s: f64,
    pub bandwidth_bps: f64,
    /// Disk bandwidth for HDFS-surrogate spills (Mahout baseline).
    /// ~100 MB/s sequential (2013-era spinning disks), and HDFS writes
    /// are 3x-replicated so effective write bandwidth divides by the
    /// replication pipeline.
    pub disk_bps: f64,
    pub hdfs_replication: u32,
    /// Fixed per-job startup overhead (Hadoop JVM spawn ~10s/job in 2013;
    /// the paper attributes much of Mahout's iteration cost to this).
    pub job_startup_s: f64,
}

impl NetworkModel {
    pub fn ec2_2013() -> NetworkModel {
        NetworkModel {
            latency_s: 0.5e-3,
            bandwidth_bps: 1e9 / 8.0, // 1 GbE in bytes/s
            disk_bps: 100e6,
            hdfs_replication: 3,
            job_startup_s: 10.0,
        }
    }

    /// Point-to-point message time.
    pub fn msg_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Message time over a degraded link: latency multiplied by
    /// `latency_x`, bandwidth divided by `bandwidth_div` (both >= 1 under
    /// a `cluster::netfault` degrade window; 1/1 reproduces
    /// [`NetworkModel::msg_time`] exactly).
    pub fn msg_time_scaled(&self, bytes: u64, latency_x: f64, bandwidth_div: f64) -> f64 {
        self.latency_s * latency_x + bytes as f64 * bandwidth_div / self.bandwidth_bps
    }

    /// Time to write `bytes` through the HDFS replication pipeline.
    pub fn hdfs_write_time(&self, bytes: u64) -> f64 {
        bytes as f64 * self.hdfs_replication as f64 / self.disk_bps
    }

    /// Time to read `bytes` from local disk (HDFS read hits one replica).
    pub fn hdfs_read_time(&self, bytes: u64) -> f64 {
        bytes as f64 / self.disk_bps
    }
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel::ec2_2013()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_time_alpha_beta() {
        let n = NetworkModel::ec2_2013();
        // latency-dominated small message
        let t_small = n.msg_time(1);
        assert!((t_small - 0.5e-3).abs() < 1e-4);
        // bandwidth-dominated big message: 125 MB at 125 MB/s ~ 1s
        let t_big = n.msg_time(125_000_000);
        assert!((t_big - 1.0).abs() < 0.01);
        // monotone in size
        assert!(n.msg_time(1000) < n.msg_time(1_000_000));
    }

    #[test]
    fn scaled_msg_time_degrades_and_reduces() {
        let n = NetworkModel::ec2_2013();
        // unit multipliers reproduce the healthy link bit-for-bit
        assert_eq!(n.msg_time_scaled(1 << 20, 1.0, 1.0), n.msg_time(1 << 20));
        // 4x latency on a tiny message ~ 2 ms
        assert!((n.msg_time_scaled(1, 4.0, 1.0) - 2.0e-3).abs() < 1e-5);
        // quartered bandwidth on a big message ~ 4x the transfer term
        let base = n.msg_time(125_000_000) - n.latency_s;
        let slow = n.msg_time_scaled(125_000_000, 1.0, 4.0) - n.latency_s;
        assert!((slow / base - 4.0).abs() < 1e-9);
    }

    #[test]
    fn hdfs_write_replicated() {
        let n = NetworkModel::ec2_2013();
        // write pays replication, read does not
        assert!((n.hdfs_write_time(100_000_000) - 3.0).abs() < 1e-9);
        assert!((n.hdfs_read_time(100_000_000) - 1.0).abs() < 1e-9);
    }
}
