//! Network-failure schedules for the simulated cluster.
//!
//! [`super::fault::FaultPlan`] models machines dying; a [`NetFaultPlan`]
//! models the *links between them* failing — the dominant failure and
//! straggler source in real data-center clusters. Four fault kinds are
//! scheduled as round-scoped windows, applied by the cluster at round
//! boundaries ([`super::SimCluster::begin_round`]) alongside the node
//! fault plan:
//!
//! * **Drop** — messages are lost with some probability per delivery
//!   attempt; the sender retries under its [`crate::engine::RetryPolicy`].
//! * **Duplicate** — delivered messages arrive twice; the receiver dedups,
//!   so only bandwidth (and a counter) is charged — math never changes.
//! * **Degrade** — a link runs at multiplied latency / divided bandwidth.
//! * **Partition** — a group of machines splits off; no message crosses
//!   the cut while the window is open.
//!
//! Determinism contract: per-message fault decisions come from
//! [`msg_roll`], a *pure hash* of (seed, round, message id, attempt) —
//! never a shared mutable RNG stream — so drop/duplicate outcomes are
//! identical for any host thread count and any interleaving of charge
//! calls. Whenever retries eventually succeed, trained models are
//! bitwise-identical to the failure-free baseline: faults move simulated
//! time and counters, never values or merge order.

use crate::util::lockdep::TrackedMutex;
use crate::util::rng::Rng;

/// What a scheduled network fault does to the fleet's links while active.
#[derive(Debug, Clone, PartialEq)]
pub enum NetFaultKind {
    /// Messages on links touching `machine` (every link when `None`) are
    /// dropped with probability `prob` per delivery attempt.
    Drop { machine: Option<usize>, prob: f64 },
    /// Delivered messages on links touching `machine` (every link when
    /// `None`) are duplicated with probability `prob`.
    Duplicate { machine: Option<usize>, prob: f64 },
    /// Links touching `machine` (every link when `None`) degrade: latency
    /// is multiplied by `latency_x`, bandwidth divided by `bandwidth_div`.
    Degrade {
        machine: Option<usize>,
        latency_x: f64,
        bandwidth_div: f64,
    },
    /// The listed machines split from the rest of the fleet; no message
    /// crosses the cut while the window is open. The "master side" is the
    /// side containing machine 0.
    Partition { minority: Vec<usize> },
}

impl NetFaultKind {
    /// Short label for spans and log lines.
    pub fn label(&self) -> &'static str {
        match self {
            NetFaultKind::Drop { .. } => "drop",
            NetFaultKind::Duplicate { .. } => "duplicate",
            NetFaultKind::Degrade { .. } => "degrade",
            NetFaultKind::Partition { .. } => "partition",
        }
    }
}

/// One scheduled network fault window.
#[derive(Debug, Clone, PartialEq)]
pub struct NetFaultEvent {
    /// Round (0-based, counted over `SimCluster::begin_round` calls) at
    /// which the window opens, before any work of that round runs.
    pub round: usize,
    /// Rounds the window stays open (0 is treated as 1).
    pub rounds: usize,
    pub kind: NetFaultKind,
}

/// What a sender does when the destination is on the other side of an
/// active partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PartitionPolicy {
    /// Block until the window closes: the message still succeeds, and the
    /// sender is charged `heal_in x` the per-message timeout of simulated
    /// wait time (the cut outlives every in-flight retry, so the wait is
    /// gated by rounds-to-heal, not attempts).
    #[default]
    WaitOut,
    /// Fail fast: cut-off machines are treated like dead ones by
    /// [`super::SimCluster::assign_machine`], so work re-places onto the
    /// master's side; a direct send across the cut is a typed
    /// `Error::NetFault`.
    Replace,
}

/// Message-level accounting across a run (see `SimCluster::net_stats`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Logical transfers attempted through the fault layer.
    pub sends: u64,
    /// Delivery attempts lost to an active drop window.
    pub drops: u64,
    /// Retry attempts (every drop that wasn't the last allowed attempt).
    pub retries: u64,
    /// Duplicate deliveries (deduped by the receiver; bandwidth only).
    pub dups: u64,
    /// Messages that waited out a partition window (`WaitOut`).
    pub partition_waits: u64,
    /// Placements re-routed off a cut-off machine (`Replace`).
    pub replacements: u64,
}

/// Tunables for [`NetFaultPlan::random`] chaos schedules.
#[derive(Debug, Clone)]
pub struct NetChaosConfig {
    /// Per-round probability that a one-round fleet-wide drop window opens.
    pub drop_windows: f64,
    /// Link drop probability inside a drop window.
    pub drop_prob: f64,
    /// Per-round probability that a one-round duplicate window opens.
    pub dup_windows: f64,
    /// Duplicate probability inside a duplicate window.
    pub dup_prob: f64,
    /// Per-round probability that a one-round single-machine degrade
    /// window opens (the degraded machine is drawn from the schedule RNG).
    pub degrade_windows: f64,
    /// Latency multiplier inside a degrade window.
    pub latency_x: f64,
    /// Bandwidth divisor inside a degrade window.
    pub bandwidth_div: f64,
    /// Round at which the one partition window opens (0 disables it; a
    /// value below 1 is pushed to 1 so round 0 stays fault-free).
    pub partition_round: usize,
    /// Rounds the partition window stays open.
    pub partition_rounds: usize,
}

impl Default for NetChaosConfig {
    fn default() -> Self {
        NetChaosConfig {
            drop_windows: 0.5,
            drop_prob: 0.25,
            dup_windows: 0.4,
            dup_prob: 0.2,
            degrade_windows: 0.3,
            latency_x: 4.0,
            bandwidth_div: 4.0,
            partition_round: 2,
            partition_rounds: 2,
        }
    }
}

/// A schedule of link-fault windows, applied by the cluster at round
/// boundaries. Shared (`Arc`) between the driver that authors it and the
/// cluster that drains it. The seed feeds every per-message [`msg_roll`].
pub struct NetFaultPlan {
    seed: u64,
    events: TrackedMutex<Vec<NetFaultEvent>>,
}

impl NetFaultPlan {
    pub fn new(seed: u64) -> NetFaultPlan {
        NetFaultPlan {
            seed,
            events: TrackedMutex::new("netfault.events", Vec::new()),
        }
    }

    /// The seed driving per-message fault decisions.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Schedule one fault window.
    pub fn schedule(&self, ev: NetFaultEvent) {
        self.events.lock().push(ev);
    }

    /// Sugar: a `kind` window open for `rounds` rounds starting at `round`.
    pub fn window(&self, round: usize, rounds: usize, kind: NetFaultKind) {
        self.schedule(NetFaultEvent { round, rounds, kind });
    }

    /// Seeded random chaos schedule mixing drop, duplicate, degrade, and
    /// one partition window over rounds `1..rounds` (round 0 is always
    /// spared so a job can land its initial broadcast). Identical seeds
    /// yield identical schedules; the same seed also drives the
    /// per-message rolls, so a whole chaos run replays bit-for-bit.
    pub fn random(
        seed: u64,
        machines: usize,
        rounds: usize,
        cfg: &NetChaosConfig,
    ) -> NetFaultPlan {
        let plan = NetFaultPlan::new(seed);
        let mut rng = Rng::new(seed).split(0x6e65_7466); // "netf"
        for round in 1..rounds {
            if cfg.drop_windows > 0.0 && rng.f64() < cfg.drop_windows {
                plan.window(
                    round,
                    1,
                    NetFaultKind::Drop { machine: None, prob: cfg.drop_prob },
                );
            }
            if cfg.dup_windows > 0.0 && rng.f64() < cfg.dup_windows {
                plan.window(
                    round,
                    1,
                    NetFaultKind::Duplicate { machine: None, prob: cfg.dup_prob },
                );
            }
            if cfg.degrade_windows > 0.0 && rng.f64() < cfg.degrade_windows {
                let machine = Some(rng.below(machines.max(1)));
                plan.window(
                    round,
                    1,
                    NetFaultKind::Degrade {
                        machine,
                        latency_x: cfg.latency_x,
                        bandwidth_div: cfg.bandwidth_div,
                    },
                );
            }
        }
        if cfg.partition_rounds > 0 && cfg.partition_round > 0 && machines > 1 {
            // cut off the top quarter of the fleet (at least one machine,
            // never machine 0 — the master side must stay the majority)
            let k = (machines / 4).max(1).min(machines - 1);
            let minority: Vec<usize> = (machines - k..machines).collect();
            plan.window(
                cfg.partition_round.max(1),
                cfg.partition_rounds,
                NetFaultKind::Partition { minority },
            );
        }
        plan
    }

    /// Drain and return every window opening at or before `round`, in
    /// schedule order. Called by the cluster once per `begin_round`.
    pub fn take_due(&self, round: usize) -> Vec<NetFaultEvent> {
        let mut events = self.events.lock();
        let mut due = Vec::new();
        let mut i = 0;
        while i < events.len() {
            if events[i].round <= round {
                due.push(events.remove(i));
            } else {
                i += 1;
            }
        }
        due
    }

    /// Windows not yet opened.
    pub fn remaining(&self) -> usize {
        self.events.lock().len()
    }
}

/// Pure per-message uniform draw in [0, 1): a hash of (seed, round,
/// message id, attempt, salt), not a shared RNG stream. Fresh randomness
/// per retry attempt means a dropped message can succeed on retry; the
/// hash form means the outcome is independent of host thread count and of
/// how charge calls interleave across subsystems.
pub fn msg_roll(seed: u64, round: usize, msg: u64, attempt: usize, salt: u64) -> f64 {
    let mut x = seed ^ 0x6e65_7466_6175_6c74; // "netfault"
    for v in [round as u64, msg, attempt as u64, salt] {
        x = (x ^ v).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        x ^= x >> 29;
    }
    Rng::new(x).f64()
}

/// Salt values separating the independent per-message draw families.
pub const ROLL_DROP: u64 = 1;
pub const ROLL_DUP: u64 = 2;

/// Effective quality of one link under the active fault windows.
#[derive(Debug, Clone, Copy)]
pub struct LinkQuality {
    pub drop_p: f64,
    pub dup_p: f64,
    pub latency_x: f64,
    pub bandwidth_div: f64,
}

/// Snapshot of the fleet's per-link fault state for one round, rebuilt by
/// the cluster at each round boundary from the open windows. Pure data —
/// cheap to clone out of the cluster's lock so the send path never holds
/// it across a charge.
#[derive(Debug, Clone)]
pub struct LinkState {
    pub round: usize,
    seed: u64,
    drop_all: f64,
    dup_all: f64,
    drop_m: Vec<f64>,
    dup_m: Vec<f64>,
    latency_x: Vec<f64>,
    bandwidth_div: Vec<f64>,
    minority: Vec<bool>,
    /// Rounds until the last open partition window closes (0 = none).
    pub heal_in: usize,
    active: bool,
}

/// Combine independent drop/duplicate probabilities: 1 - prod(1 - p_i).
fn combine_p(a: f64, b: f64) -> f64 {
    1.0 - (1.0 - a.clamp(0.0, 1.0)) * (1.0 - b.clamp(0.0, 1.0))
}

impl LinkState {
    /// A fault-free fleet (the state outside any window).
    pub fn inactive(machines: usize) -> LinkState {
        LinkState {
            round: 0,
            seed: 0,
            drop_all: 0.0,
            dup_all: 0.0,
            drop_m: vec![0.0; machines],
            dup_m: vec![0.0; machines],
            latency_x: vec![1.0; machines],
            bandwidth_div: vec![1.0; machines],
            minority: vec![false; machines],
            heal_in: 0,
            active: false,
        }
    }

    /// Fold the open windows (`(close_round_exclusive, kind)`) into one
    /// per-round snapshot. Overlapping drop/duplicate windows combine as
    /// independent losses; overlapping degrades take the worst multiplier.
    pub fn build(
        seed: u64,
        machines: usize,
        round: usize,
        windows: &[(usize, NetFaultKind)],
    ) -> LinkState {
        let mut ls = LinkState::inactive(machines);
        ls.round = round;
        ls.seed = seed;
        for (until, kind) in windows {
            ls.active = true;
            match kind {
                NetFaultKind::Drop { machine, prob } => match machine {
                    Some(m) if *m < machines => ls.drop_m[*m] = combine_p(ls.drop_m[*m], *prob),
                    Some(_) => {}
                    None => ls.drop_all = combine_p(ls.drop_all, *prob),
                },
                NetFaultKind::Duplicate { machine, prob } => match machine {
                    Some(m) if *m < machines => ls.dup_m[*m] = combine_p(ls.dup_m[*m], *prob),
                    Some(_) => {}
                    None => ls.dup_all = combine_p(ls.dup_all, *prob),
                },
                NetFaultKind::Degrade { machine, latency_x, bandwidth_div } => {
                    let lx = latency_x.max(1.0);
                    let bd = bandwidth_div.max(1.0);
                    match machine {
                        Some(m) if *m < machines => {
                            ls.latency_x[*m] = ls.latency_x[*m].max(lx);
                            ls.bandwidth_div[*m] = ls.bandwidth_div[*m].max(bd);
                        }
                        Some(_) => {}
                        None => {
                            for m in 0..machines {
                                ls.latency_x[m] = ls.latency_x[m].max(lx);
                                ls.bandwidth_div[m] = ls.bandwidth_div[m].max(bd);
                            }
                        }
                    }
                }
                NetFaultKind::Partition { minority } => {
                    for &m in minority {
                        if m < machines {
                            ls.minority[m] = true;
                        }
                    }
                    ls.heal_in = ls.heal_in.max(until.saturating_sub(round));
                }
            }
        }
        ls
    }

    /// Any window open this round?
    pub fn is_active(&self) -> bool {
        self.active
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Do `a` and `b` sit on opposite sides of an active cut?
    pub fn partitioned(&self, a: usize, b: usize) -> bool {
        self.minority[a] != self.minority[b]
    }

    /// Is `m` on the same side of the cut as machine 0 (the master)?
    pub fn same_side_as_master(&self, m: usize) -> bool {
        self.minority[m] == self.minority[0]
    }

    /// Effective quality of the `a`–`b` link: endpoint-scoped and
    /// fleet-wide drop/duplicate probabilities combine as independent
    /// losses; the slower endpoint gates latency and bandwidth.
    pub fn quality(&self, a: usize, b: usize) -> LinkQuality {
        LinkQuality {
            drop_p: combine_p(self.drop_all, combine_p(self.drop_m[a], self.drop_m[b])),
            dup_p: combine_p(self.dup_all, combine_p(self.dup_m[a], self.dup_m[b])),
            latency_x: self.latency_x[a].max(self.latency_x[b]),
            bandwidth_div: self.bandwidth_div[a].max(self.bandwidth_div[b]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_due_drains_in_schedule_order() {
        let p = NetFaultPlan::new(1);
        p.window(2, 1, NetFaultKind::Drop { machine: None, prob: 0.5 });
        p.window(1, 2, NetFaultKind::Partition { minority: vec![3] });
        p.window(1, 1, NetFaultKind::Duplicate { machine: Some(0), prob: 0.1 });
        assert_eq!(p.take_due(0), vec![]);
        let due = p.take_due(1);
        assert_eq!(due.len(), 2);
        assert_eq!(due[0].kind.label(), "partition");
        assert_eq!(due[1].kind.label(), "duplicate");
        assert_eq!(p.remaining(), 1);
        assert_eq!(p.take_due(9).len(), 1);
    }

    #[test]
    fn random_schedule_is_seed_deterministic_and_spares_round_zero() {
        let cfg = NetChaosConfig::default();
        let a = NetFaultPlan::random(7, 8, 10, &cfg).take_due(usize::MAX);
        let b = NetFaultPlan::random(7, 8, 10, &cfg).take_due(usize::MAX);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(a.iter().all(|e| e.round >= 1));
        let c = NetFaultPlan::random(8, 8, 10, &cfg).take_due(usize::MAX);
        assert_ne!(a, c);
        // exactly one partition window, never cutting machine 0
        let parts: Vec<_> = a
            .iter()
            .filter_map(|e| match &e.kind {
                NetFaultKind::Partition { minority } => Some(minority.clone()),
                _ => None,
            })
            .collect();
        assert_eq!(parts.len(), 1);
        assert!(!parts[0].contains(&0) && !parts[0].is_empty());
    }

    #[test]
    fn msg_roll_is_pure_and_uniform() {
        assert_eq!(msg_roll(7, 3, 42, 1, ROLL_DROP), msg_roll(7, 3, 42, 1, ROLL_DROP));
        assert_ne!(msg_roll(7, 3, 42, 1, ROLL_DROP), msg_roll(7, 3, 42, 2, ROLL_DROP));
        assert_ne!(msg_roll(7, 3, 42, 1, ROLL_DROP), msg_roll(7, 3, 43, 1, ROLL_DROP));
        assert_ne!(msg_roll(7, 3, 42, 1, ROLL_DROP), msg_roll(7, 3, 42, 1, ROLL_DUP));
        let mean: f64 =
            (0..4000).map(|i| msg_roll(1, 0, i, 0, ROLL_DROP)).sum::<f64>() / 4000.0;
        assert!((mean - 0.5).abs() < 0.03, "roll mean {mean}");
        assert!((0..1000).all(|i| {
            let r = msg_roll(9, i, i as u64, 0, ROLL_DUP);
            (0.0..1.0).contains(&r)
        }));
    }

    #[test]
    fn link_state_combines_windows() {
        let windows = vec![
            (5, NetFaultKind::Drop { machine: None, prob: 0.5 }),
            (5, NetFaultKind::Drop { machine: Some(1), prob: 0.5 }),
            (5, NetFaultKind::Degrade { machine: Some(2), latency_x: 4.0, bandwidth_div: 8.0 }),
            (6, NetFaultKind::Partition { minority: vec![3] }),
        ];
        let ls = LinkState::build(7, 4, 2, &windows);
        assert!(ls.is_active());
        // link 0-1: global 0.5 + endpoint 0.5 combine to 0.75
        let q = ls.quality(0, 1);
        assert!((q.drop_p - 0.75).abs() < 1e-12, "{}", q.drop_p);
        assert_eq!(q.latency_x, 1.0);
        // link 0-2: degraded endpoint gates
        let q2 = ls.quality(0, 2);
        assert_eq!(q2.latency_x, 4.0);
        assert_eq!(q2.bandwidth_div, 8.0);
        assert!((q2.drop_p - 0.5).abs() < 1e-12);
        // partition: 3 is cut off from the master side for 4 more rounds
        assert!(ls.partitioned(0, 3) && ls.partitioned(2, 3));
        assert!(!ls.partitioned(0, 2));
        assert!(ls.same_side_as_master(1) && !ls.same_side_as_master(3));
        assert_eq!(ls.heal_in, 4);
        // no windows -> inactive
        assert!(!LinkState::build(7, 4, 2, &[]).is_active());
    }
}
