//! Linear SVM via distributed SGD (hinge loss + L2) — the second entry in
//! the paper's "naturally extends to linear SVMs ..." list (§IV).

use std::sync::Arc;

use super::glm::{GlmData, GlmGradient, RustGlmStep};
use super::{Algorithm, Model};
use crate::cluster::SimCluster;
use crate::error::Result;
use crate::localmatrix::MLVector;
use crate::mltable::MLNumericTable;
use crate::optim::{Reg, SgdParams, SGD};

pub struct LinearSVM {
    pub sgd: SgdParams,
}

impl LinearSVM {
    /// Defaults include the SVM's L2 term (1/C regularization).
    pub fn new(mut sgd: SgdParams) -> LinearSVM {
        if matches!(sgd.reg, Reg::None) {
            sgd.reg = Reg::L2(1e-3);
        }
        LinearSVM { sgd }
    }
}

#[derive(Debug, Clone)]
pub struct SvmModel {
    pub weights: MLVector,
    pub loss_history: Vec<f64>,
}

impl Model for SvmModel {
    /// Signed margin (positive => class 1).
    fn predict(&self, x: &MLVector) -> Result<f64> {
        x.dot(&self.weights)
    }
}

impl Algorithm for LinearSVM {
    type Output = SvmModel;

    fn train(&self, data: &MLNumericTable, cluster: &SimCluster) -> Result<SvmModel> {
        let d = data.num_cols() - 1;
        let mut max_rows = 1;
        for p in 0..data.num_partitions() {
            max_rows = max_rows.max(data.dataset().partition(p)?.len());
        }
        let glm = Arc::new(GlmData::prepare(data, max_rows, d, 32.min(max_rows))?);
        let step = RustGlmStep::new(glm, GlmGradient::Hinge);
        let res = SGD::run(&step, cluster, &self.sgd)?;
        Ok(SvmModel {
            weights: MLVector::new(res.weights[..d].iter().map(|&x| x as f64).collect()),
            loss_history: res.loss_history,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;
    use crate::mltable::{MLRow, MLTable, Schema};
    use crate::util::rng::Rng;

    #[test]
    fn separates_linearly_separable_data() {
        let ctx = EngineContext::new();
        let mut rng = Rng::new(9);
        // separable with margin along x0 + x1
        let rows: Vec<MLRow> = (0..200)
            .map(|i| {
                let cls = i % 2;
                let shift = if cls == 1 { 1.5 } else { -1.5 };
                let x0 = shift + 0.3 * rng.normal();
                let x1 = shift + 0.3 * rng.normal();
                MLRow::from_scalars(&[cls as f64, x0, x1])
            })
            .collect();
        let t = MLTable::from_rows(&ctx, rows.clone(), Schema::numeric(3), 4)
            .unwrap()
            .to_numeric()
            .unwrap();
        let algo = LinearSVM::new(SgdParams {
            learning_rate: 0.01,
            iters: 30,
            ..Default::default()
        });
        let m = algo.train(&t, &SimCluster::ec2(4)).unwrap();
        let mut correct = 0;
        for r in &rows {
            let v = r.to_vector().unwrap();
            let pred = m.predict(&v.slice(1, 3)).unwrap();
            if (pred > 0.0) == (v[0] > 0.5) {
                correct += 1;
            }
        }
        assert!(correct as f64 / 200.0 > 0.95, "{correct}/200");
    }
}
