//! Logistic regression (paper §IV-A): SGD with local epochs + parameter
//! averaging, XLA-backed hot path, identical in structure to Fig. A4's
//! `LogisticRegressionAlgorithm`.


use super::{Algorithm, Model};
use crate::cluster::SimCluster;
use crate::error::Result;
use crate::localmatrix::MLVector;
use crate::mltable::MLNumericTable;
use crate::optim::{SgdParams, SgdResult, SGD};

/// Which compute backend executes the local epochs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Backend {
    /// AOT-compiled XLA artifacts via PJRT (the production path).
    /// The variant is chosen automatically from the manifest.
    Xla,
    /// Pure-rust fallback (differential-testing reference; also what the
    /// simulated comparison systems execute, scaled by compute_factor).
    Rust,
}

/// Hyper-parameters (paper: `LogisticRegressionParameters`).
#[derive(Debug, Clone)]
pub struct LogRegParams {
    pub sgd: SgdParams,
    pub backend: Backend,
}

impl Default for LogRegParams {
    fn default() -> Self {
        LogRegParams {
            sgd: SgdParams::default(),
            backend: Backend::Xla,
        }
    }
}

/// The trained model: a weight vector over the original feature dim.
#[derive(Debug, Clone)]
pub struct LogRegModel {
    pub weights: MLVector,
    pub loss_history: Vec<f64>,
    pub sim_seconds: f64,
}

impl Model for LogRegModel {
    /// Probability of class 1.
    fn predict(&self, x: &MLVector) -> Result<f64> {
        let margin = x.dot(&self.weights)?;
        Ok(1.0 / (1.0 + (-margin).exp()))
    }
}

/// The algorithm object (paper: `object LogisticRegressionAlgorithm
/// extends NumericAlgorithm`).
pub struct LogisticRegression {
    pub params: LogRegParams,
}

impl LogisticRegression {
    pub fn new(params: LogRegParams) -> LogisticRegression {
        LogisticRegression { params }
    }

    pub fn with_defaults() -> LogisticRegression {
        LogisticRegression::new(LogRegParams::default())
    }

    fn run_sgd(&self, data: &MLNumericTable, cluster: &SimCluster) -> Result<(SgdResult, usize)> {
        let d = data.num_cols() - 1;
        let provider =
            super::glm::make_logreg_provider(data, self.params.backend == Backend::Xla)?;
        Ok((SGD::run(provider.as_ref(), cluster, &self.params.sgd)?, d))
    }
}

impl Algorithm for LogisticRegression {
    type Output = LogRegModel;

    fn train(&self, data: &MLNumericTable, cluster: &SimCluster) -> Result<LogRegModel> {
        let (res, d) = self.run_sgd(data, cluster)?;
        // trim padding dims off the weight vector
        let weights = MLVector::new(res.weights[..d].iter().map(|&x| x as f64).collect());
        Ok(LogRegModel {
            weights,
            loss_history: res.loss_history,
            sim_seconds: res.sim_seconds,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dense_gen;
    use crate::engine::EngineContext;

    /// Shared check: train on planted data, expect good accuracy.
    fn train_and_check(backend: Backend) {
        let ctx = EngineContext::new();
        let data = dense_gen::generate(&ctx, 256, 16, 4, 11).unwrap();
        let cluster = SimCluster::ec2(4);
        let algo = LogisticRegression::new(LogRegParams {
            sgd: SgdParams {
                learning_rate: 0.05,
                iters: 12,
                track_loss: true,
                ..Default::default()
            },
            backend,
        });
        let model = algo.train(&data.table, &cluster).unwrap();
        assert_eq!(model.weights.len(), 16);
        // loss decreased
        let lh = &model.loss_history;
        assert!(lh.last().unwrap() < lh.first().unwrap(), "{lh:?}");
        // accuracy vs labels
        let rows = data.table.table().collect().unwrap();
        let mut correct = 0;
        for r in &rows {
            let v = r.to_vector().unwrap();
            let y = v[0];
            let x = v.slice(1, v.len());
            let p = model.predict(&x).unwrap();
            if (p > 0.5) == (y > 0.5) {
                correct += 1;
            }
        }
        let acc = correct as f64 / rows.len() as f64;
        assert!(acc > 0.8, "accuracy {acc}");
        assert!(model.sim_seconds > 0.0);
    }

    #[test]
    fn rust_backend_learns() {
        train_and_check(Backend::Rust);
    }

    #[test]
    fn xla_backend_learns() {
        if !crate::runtime::require_artifacts_or_skip("logreg::xla_backend_learns") {
            return;
        }
        // the small variant fits 256/4=64 rows, d=16
        train_and_check(Backend::Xla);
    }

    #[test]
    fn parallel_training_matches_serial() {
        // same data + params, cluster with and without an executor: the
        // trained weights must be bitwise-identical (exec determinism
        // contract), only wall-clock changes
        let ctx = EngineContext::new();
        let data = dense_gen::generate(&ctx, 256, 16, 8, 13).unwrap();
        let params = LogRegParams {
            sgd: SgdParams {
                learning_rate: 0.05,
                iters: 8,
                ..Default::default()
            },
            backend: Backend::Rust,
        };
        let serial = LogisticRegression::new(params.clone())
            .train(&data.table, &SimCluster::ec2(8))
            .unwrap();
        for threads in [2, 8] {
            let cluster = SimCluster::ec2(8).with_executor(threads);
            let par = LogisticRegression::new(params.clone())
                .train(&data.table, &cluster)
                .unwrap();
            for j in 0..16 {
                assert_eq!(
                    serial.weights[j], par.weights[j],
                    "dim {j} differs at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn xla_and_rust_agree() {
        if !crate::runtime::require_artifacts_or_skip("logreg::xla_and_rust_agree") {
            return;
        }
        // identical data, params -> near-identical weights (f32 round-off)
        let ctx = EngineContext::new();
        let data = dense_gen::generate(&ctx, 128, 8, 2, 5).unwrap();
        let params = |backend| LogRegParams {
            sgd: SgdParams {
                learning_rate: 0.05,
                iters: 5,
                ..Default::default()
            },
            backend,
        };
        let m_rust = LogisticRegression::new(params(Backend::Rust))
            .train(&data.table, &SimCluster::ec2(2))
            .unwrap();
        let m_xla = LogisticRegression::new(params(Backend::Xla))
            .train(&data.table, &SimCluster::ec2(2))
            .unwrap();
        for j in 0..8 {
            assert!(
                (m_rust.weights[j] - m_xla.weights[j]).abs() < 1e-3,
                "dim {j}: rust {} vs xla {}",
                m_rust.weights[j],
                m_xla.weights[j]
            );
        }
    }
}
