//! Generalized linear model machinery shared by logistic regression,
//! linear regression and linear SVM: partitioned (label | features) data
//! prepared once into padded f32 tensors, plus the two
//! [`LocalStepProvider`] backends — XLA (AOT artifacts on the PJRT
//! runtime, logistic only) and pure rust (any [`GlmGradient`]).

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::mltable::MLNumericTable;
use crate::optim::LocalStepProvider;
use crate::runtime::{Runtime, Tensor};

/// Which GLM loss a rust-backed provider optimizes. The paper's point —
/// "simply changing the expression of the gradient function" — is this
/// enum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GlmGradient {
    /// sigmoid(x.w) - y residual (negative log-likelihood).
    Logistic,
    /// x.w - y residual (squared loss / 2).
    Squared,
    /// Hinge: subgradient -y*x when y*(x.w) < 1, labels in {-1, +1}
    /// (converted from {0,1} at prep time).
    Hinge,
}

impl GlmGradient {
    /// Per-example residual factor r such that grad = r * x, plus loss.
    #[inline]
    pub fn residual_and_loss(&self, margin: f64, y: f64) -> (f64, f64) {
        match self {
            GlmGradient::Logistic => {
                let p = 1.0 / (1.0 + (-margin).exp());
                // stable softplus(margin) - y*margin
                let sp = if margin > 30.0 {
                    margin
                } else if margin < -30.0 {
                    0.0
                } else {
                    (1.0 + margin.exp()).ln()
                };
                (p - y, sp - y * margin)
            }
            GlmGradient::Squared => {
                let r = margin - y;
                (r, 0.5 * r * r)
            }
            GlmGradient::Hinge => {
                let ypm = if y > 0.5 { 1.0 } else { -1.0 };
                if ypm * margin < 1.0 {
                    (-ypm, 1.0 - ypm * margin)
                } else {
                    (0.0, 0.0)
                }
            }
        }
    }
}

/// One prepared partition: padded, split into features/labels, f32.
struct PreparedPartition {
    /// (n_pad * d_pad) row-major features.
    x: Vec<f32>,
    /// (n_pad) labels.
    y: Vec<f32>,
    rows: usize,
}

/// Data prepared for GLM training: label column 0 split off, features
/// zero-padded to (n_pad, d_pad). Built once; reused every round.
pub struct GlmData {
    parts: Vec<PreparedPartition>,
    pub d: usize,
    pub n_pad: usize,
    pub d_pad: usize,
    pub block_n: usize,
}

impl GlmData {
    /// Prepare from a numeric table (col 0 = label). `n_pad`/`d_pad` are
    /// the target tensor shape — for the XLA backend these must equal the
    /// artifact's input shape; the rust backend accepts any padding
    /// (including none).
    pub fn prepare(
        data: &MLNumericTable,
        n_pad: usize,
        d_pad: usize,
        block_n: usize,
    ) -> Result<GlmData> {
        let d = data
            .num_cols()
            .checked_sub(1)
            .ok_or_else(|| Error::Schema("GLM data needs >= 2 columns (label + features)".into()))?;
        if d > d_pad {
            return Err(Error::Shape(format!(
                "feature dim {d} exceeds padded dim {d_pad}"
            )));
        }
        let mut parts = Vec::with_capacity(data.num_partitions());
        for p in 0..data.num_partitions() {
            let m = data.partition_matrix(p)?;
            if m.rows > n_pad {
                return Err(Error::Shape(format!(
                    "partition {p} has {} rows, exceeds padded rows {n_pad}",
                    m.rows
                )));
            }
            let mut x = vec![0.0f32; n_pad * d_pad];
            let mut y = vec![0.0f32; n_pad];
            for r in 0..m.rows {
                y[r] = m.get(r, 0) as f32;
                for c in 0..d {
                    x[r * d_pad + c] = m.get(r, c + 1) as f32;
                }
            }
            parts.push(PreparedPartition { x, y, rows: m.rows });
        }
        Ok(GlmData { parts, d, n_pad, d_pad, block_n })
    }

    pub fn num_partitions(&self) -> usize {
        self.parts.len()
    }

    pub fn rows(&self, p: usize) -> usize {
        self.parts[p].rows
    }

    pub fn total_rows(&self) -> usize {
        self.parts.iter().map(|p| p.rows).sum()
    }
}

// ---------------------------------------------------------------------------
// XLA-backed provider (logistic regression; §IV-A hot path)
// ---------------------------------------------------------------------------

/// The production path: local SGD epochs and batch gradients execute as
/// AOT-compiled XLA programs (Pallas kernel inside, see
/// python/compile/model.py). One `Tensor` per partition is built at
/// construction; per-round marshalling is just the weight vector.
pub struct XlaLogregStep {
    data: Arc<GlmData>,
    rt: Arc<Runtime>,
    variant: String,
    /// Device-resident (x, y) buffers per partition: transferred once at
    /// construction, reused every round (zero per-round marshalling of
    /// the big tensors — EXPERIMENTS.md §Perf L3 iterations 4-5).
    buffers: Vec<(crate::runtime::DeviceTensor, crate::runtime::DeviceTensor)>,
}

impl XlaLogregStep {
    /// Build over prepared data; verifies the artifact shapes match.
    pub fn new(data: Arc<GlmData>, rt: Arc<Runtime>, variant: &str) -> Result<XlaLogregStep> {
        let spec = rt
            .manifest()
            .find("local_sgd_epoch", variant)
            .ok_or_else(|| Error::Runtime(format!("no local_sgd_epoch variant '{variant}'")))?;
        let (n_art, d_art) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
        if (data.n_pad, data.d_pad) != (n_art, d_art) {
            return Err(Error::Shape(format!(
                "GlmData padded to ({}, {}) but artifact '{variant}' expects ({n_art}, {d_art})",
                data.n_pad, data.d_pad
            )));
        }
        let epoch_exe = rt.executable("local_sgd_epoch", variant)?;
        let buffers = data
            .parts
            .iter()
            .map(|p| {
                let x = epoch_exe
                    .to_device(&Tensor::F32(p.x.clone(), vec![data.n_pad, data.d_pad]))?;
                let y = epoch_exe.to_device(&Tensor::F32(p.y.clone(), vec![data.n_pad]))?;
                Ok((x, y))
            })
            .collect::<Result<Vec<_>>>()?;
        // warm up NOW: XLA JIT compilation AND one untimed execution
        // (first-touch page faults, thread-pool spin-up) are one-time
        // setup costs that must not be charged to the first training
        // round's simulated compute
        rt.executable("logreg_grad_batch", variant)?;
        let step = XlaLogregStep {
            data,
            rt,
            variant: variant.to_string(),
            buffers,
        };
        if step.data.num_partitions() > 0 {
            let w0 = vec![0.0f32; step.data.d_pad];
            let _ = step.local_epoch(0, &w0, 0.0)?;
        }
        Ok(step)
    }

    /// Pick the smallest artifact variant that fits (n_part, d).
    pub fn pick_variant(rt: &Runtime, n_part: usize, d: usize) -> Result<(String, usize, usize)> {
        let mut best: Option<(usize, usize, String)> = None;
        for a in rt.manifest().variants("local_sgd_epoch") {
            let (n, dd) = (a.inputs[0].shape[0], a.inputs[0].shape[1]);
            if n >= n_part && dd >= d {
                let cost = n * dd;
                if best.as_ref().map(|(c, _, _)| cost < *c).unwrap_or(true) {
                    best = Some((cost, n, a.variant.clone()));
                }
            }
        }
        match best {
            Some((_, n, v)) => {
                let a = rt.manifest().find("local_sgd_epoch", &v).ok_or_else(|| {
                    Error::Runtime(format!("local_sgd_epoch variant '{v}' missing from manifest"))
                })?;
                Ok((v, n, a.inputs[0].shape[1]))
            }
            None => Err(Error::Runtime(format!(
                "no local_sgd_epoch artifact fits n={n_part}, d={d}"
            ))),
        }
    }
}

impl LocalStepProvider for XlaLogregStep {
    fn dim(&self) -> usize {
        self.data.d_pad
    }

    fn num_partitions(&self) -> usize {
        self.data.num_partitions()
    }

    fn partition_weight(&self, p: usize) -> f64 {
        self.data.rows(p) as f64
    }

    fn local_epoch(&self, p: usize, w: &[f32], lr: f32) -> Result<Vec<f32>> {
        let (x, y) = &self.buffers[p];
        let exe = self.rt.executable("local_sgd_epoch", &self.variant)?;
        self.rt.count_exec("local_sgd_epoch", &self.variant);
        let w_buf = exe.to_device(&Tensor::F32(w.to_vec(), vec![self.data.d_pad]))?;
        let lr_buf = exe.to_device(&Tensor::Scalar(lr))?;
        let out = exe.run_buffers(&[
            x.buffer(),
            y.buffer(),
            w_buf.buffer(),
            lr_buf.buffer(),
        ])?;
        out.into_iter()
            .next()
            .ok_or_else(|| Error::Runtime("local_sgd_epoch returned no outputs".into()))
    }

    fn local_grad(&self, p: usize, w: &[f32]) -> Result<(Vec<f32>, f64, f64)> {
        let (x, y) = &self.buffers[p];
        let exe = self.rt.executable("logreg_grad_batch", &self.variant)?;
        self.rt.count_exec("logreg_grad_batch", &self.variant);
        let w_buf = exe.to_device(&Tensor::F32(w.to_vec(), vec![self.data.d_pad]))?;
        let out = exe.run_buffers(&[x.buffer(), y.buffer(), w_buf.buffer()])?;
        let mut it = out.into_iter();
        let mut next_out = |what: &str| {
            it.next()
                .ok_or_else(|| Error::Runtime(format!("logreg_grad_batch missing {what} output")))
        };
        let grad = next_out("grad")?;
        let raw_loss = next_out("loss")?[0] as f64;
        // padding correction: each all-zero padding row contributes
        // softplus(0) = ln 2 to the summed NLL (margin 0, y 0); the
        // gradient needs no correction (x = 0).
        let pad_rows = (self.data.n_pad - self.data.rows(p)) as f64;
        let loss = raw_loss - pad_rows * std::f64::consts::LN_2;
        Ok((grad, loss, self.data.rows(p) as f64))
    }
}

/// Build a logistic-regression step provider over `data` with either
/// backend. All systems in the benches measure their compute through the
/// SAME provider so that cross-system gaps come only from topology +
/// compute factors (DESIGN.md §3), never from backend differences.
pub fn make_logreg_provider(
    data: &crate::mltable::MLNumericTable,
    xla: bool,
) -> Result<Box<dyn LocalStepProvider>> {
    let d = data.num_cols() - 1;
    let mut max_rows = 1;
    for p in 0..data.num_partitions() {
        max_rows = max_rows.max(data.dataset().partition(p)?.len());
    }
    if xla {
        let rt = Runtime::global()?;
        let (variant, n_pad, d_pad) = XlaLogregStep::pick_variant(&rt, max_rows, d)?;
        // the artifact's baked-in SGD block (manifest `block` field)
        let block = rt
            .manifest()
            .find("local_sgd_epoch", &variant)
            .and_then(|a| a.block)
            .unwrap_or(256);
        let glm = Arc::new(GlmData::prepare(data, n_pad, d_pad, block)?);
        Ok(Box::new(XlaLogregStep::new(glm, rt, &variant)?))
    } else {
        let glm = Arc::new(GlmData::prepare(data, max_rows, d, 256.min(max_rows))?);
        Ok(Box::new(RustGlmStep::new(glm, GlmGradient::Logistic)))
    }
}

// ---------------------------------------------------------------------------
// Pure-rust provider (any GLM gradient; also the no-artifact fallback)
// ---------------------------------------------------------------------------

/// Rust implementation of the same local-SGD contract. Used by
/// LinearRegression / LinearSVM (no XLA artifact for those gradients) and
/// as the reference in differential tests against the XLA path.
pub struct RustGlmStep {
    data: Arc<GlmData>,
    grad: GlmGradient,
}

impl RustGlmStep {
    pub fn new(data: Arc<GlmData>, grad: GlmGradient) -> RustGlmStep {
        RustGlmStep { data, grad }
    }
}

impl LocalStepProvider for RustGlmStep {
    fn dim(&self) -> usize {
        self.data.d_pad
    }

    fn num_partitions(&self) -> usize {
        self.data.num_partitions()
    }

    fn partition_weight(&self, p: usize) -> f64 {
        self.data.rows(p) as f64
    }

    fn local_epoch(&self, p: usize, w: &[f32], lr: f32) -> Result<Vec<f32>> {
        let part = &self.data.parts[p];
        let d_pad = self.data.d_pad;
        let block = self.data.block_n;
        let mut w = w.to_vec();
        let mut grad = vec![0.0f32; d_pad];
        let mut start = 0;
        // minibatch loop identical in structure to the L2 scan
        while start < part.rows {
            let end = (start + block).min(part.rows);
            for g in grad.iter_mut() {
                *g = 0.0;
            }
            for r in start..end {
                let xr = &part.x[r * d_pad..(r + 1) * d_pad];
                let mut margin = 0.0f64;
                for (xi, wi) in xr.iter().zip(&w) {
                    margin += (*xi as f64) * (*wi as f64);
                }
                let (resid, _) = self.grad.residual_and_loss(margin, part.y[r] as f64);
                let rf = resid as f32;
                for (g, &xi) in grad.iter_mut().zip(xr) {
                    *g += rf * xi;
                }
            }
            for (wi, &g) in w.iter_mut().zip(&grad) {
                *wi -= lr * g;
            }
            start = end;
        }
        Ok(w)
    }

    fn local_grad(&self, p: usize, w: &[f32]) -> Result<(Vec<f32>, f64, f64)> {
        let part = &self.data.parts[p];
        let d_pad = self.data.d_pad;
        let mut grad = vec![0.0f32; d_pad];
        let mut loss = 0.0f64;
        for r in 0..part.rows {
            let xr = &part.x[r * d_pad..(r + 1) * d_pad];
            let mut margin = 0.0f64;
            for (xi, wi) in xr.iter().zip(w) {
                margin += (*xi as f64) * (*wi as f64);
            }
            let (resid, l) = self.grad.residual_and_loss(margin, part.y[r] as f64);
            loss += l;
            let rf = resid as f32;
            for (g, &xi) in grad.iter_mut().zip(xr) {
                *g += rf * xi;
            }
        }
        Ok((grad, loss, part.rows as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;
    use crate::mltable::{MLRow, MLTable, Schema};

    fn table(rows: Vec<Vec<f64>>, parts: usize) -> MLNumericTable {
        let ctx = EngineContext::new();
        let d = rows[0].len();
        let rows: Vec<MLRow> = rows.iter().map(|r| MLRow::from_scalars(r)).collect();
        MLTable::from_rows(&ctx, rows, Schema::numeric(d), parts)
            .unwrap()
            .to_numeric()
            .unwrap()
    }

    #[test]
    fn prepare_splits_and_pads() {
        let t = table(
            vec![vec![1.0, 2.0, 3.0], vec![0.0, 4.0, 5.0], vec![1.0, 6.0, 7.0]],
            2,
        );
        let g = GlmData::prepare(&t, 4, 4, 2).unwrap();
        assert_eq!(g.d, 2);
        assert_eq!(g.num_partitions(), 2);
        assert_eq!(g.rows(0), 2);
        assert_eq!(g.total_rows(), 3);
        // partition 0: row 0 = label 1, features [2,3,0,0 pad]
        assert_eq!(g.parts[0].y[0], 1.0);
        assert_eq!(&g.parts[0].x[0..4], &[2.0, 3.0, 0.0, 0.0]);
        // padding rows zero
        assert_eq!(&g.parts[0].x[8..16], &[0.0; 8]);
        assert!(GlmData::prepare(&t, 1, 4, 1).is_err()); // rows too small
        assert!(GlmData::prepare(&t, 4, 1, 1).is_err()); // cols too small
    }

    #[test]
    fn gradients_logistic_squared_hinge() {
        // logistic at margin 0, y=1: resid -0.5, loss ln2
        let (r, l) = GlmGradient::Logistic.residual_and_loss(0.0, 1.0);
        assert!((r + 0.5).abs() < 1e-12);
        assert!((l - std::f64::consts::LN_2).abs() < 1e-12);
        // stable at extreme margins
        let (_, l) = GlmGradient::Logistic.residual_and_loss(1000.0, 1.0);
        assert!(l.abs() < 1e-9);
        // squared
        let (r, l) = GlmGradient::Squared.residual_and_loss(3.0, 1.0);
        assert_eq!((r, l), (2.0, 2.0));
        // hinge: y=0 -> -1; margin -2 -> violated
        let (r, l) = GlmGradient::Hinge.residual_and_loss(-2.0, 1.0);
        assert_eq!((r, l), (-1.0, 3.0));
        let (r, l) = GlmGradient::Hinge.residual_and_loss(2.0, 1.0);
        assert_eq!((r, l), (0.0, 0.0));
    }

    #[test]
    fn rust_epoch_decreases_loss() {
        // learnable toy data: y = 1 iff x0 > 0
        let rows: Vec<Vec<f64>> = (0..64)
            .map(|i| {
                let x0 = if i % 2 == 0 { 1.0 } else { -1.0 };
                vec![if x0 > 0.0 { 1.0 } else { 0.0 }, x0, 0.5]
            })
            .collect();
        let t = table(rows, 2);
        let g = Arc::new(GlmData::prepare(&t, 32, 2, 8).unwrap());
        let step = RustGlmStep::new(g, GlmGradient::Logistic);
        let w0 = vec![0.0f32; 2];
        let (_, l0, _) = step.local_grad(0, &w0).unwrap();
        let w1 = step.local_epoch(0, &w0, 0.1).unwrap();
        let (_, l1, _) = step.local_grad(0, &w1).unwrap();
        assert!(l1 < l0, "{l1} !< {l0}");
    }
}
