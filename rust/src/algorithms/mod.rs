//! Algorithms and Models (paper §III-C): "an algorithm implementing the
//! Algorithm interface is a class with a train() method that accepts data
//! and hyperparameters as input, and produces a Model. A Model is an
//! object which makes predictions."
//!
//! Implemented algorithms (paper §IV + the "naturally extend" list):
//! * [`logreg::LogisticRegression`] — SGD, XLA-backed hot path (§IV-A)
//! * [`linreg::LinearRegression`] — squared loss (same optimizer, new
//!   gradient)
//! * [`svm::LinearSVM`] — hinge loss
//! * [`als::ALS`] — alternating least squares matrix factorization (§IV-B)
//! * [`kmeans::KMeans`] — Lloyd iterations (the Fig. A2 pipeline learner)

pub mod als;
pub mod glm;
pub mod kmeans;
pub mod linreg;
pub mod logreg;
pub mod svm;

pub use als::{AlsModel, AlsParams, ALS};
pub use kmeans::{KMeans, KMeansModel, KMeansParams};
pub use linreg::LinearRegression;
pub use logreg::{LogisticRegression, LogRegModel, LogRegParams};
pub use svm::LinearSVM;

use crate::cluster::SimCluster;
use crate::error::Result;
use crate::localmatrix::MLVector;
use crate::mltable::MLNumericTable;

/// A trained model: makes predictions (paper §III-C).
pub trait Model {
    /// Predict for one feature vector.
    fn predict(&self, x: &MLVector) -> Result<f64>;

    /// Predict for every row of a numeric table (rows are feature
    /// vectors; no label column).
    fn predict_table(&self, data: &MLNumericTable) -> Result<Vec<f64>> {
        data.collect_vectors()?
            .iter()
            .map(|v| self.predict(v))
            .collect()
    }
}

/// A trainable algorithm: `train(data, hyperparameters) -> Model`.
/// Hyper-parameters live on the implementing struct (the builder
/// pattern replaces Scala's parameter case classes).
pub trait Algorithm {
    type Output: Model;

    /// Train on a numeric table distributed over `cluster`.
    fn train(&self, data: &MLNumericTable, cluster: &SimCluster) -> Result<Self::Output>;
}
