//! K-means (Lloyd) — the learner at the end of the Fig. A2 pipeline
//! (`KMeans(featurizedTable, k=50)`), with an XLA-backed assignment step.

use super::{Algorithm, Model};
use crate::cluster::{CommTopology, SimCluster};
use crate::error::{Error, Result};
use crate::localmatrix::{DenseMatrix, MLVector};
use crate::mltable::MLNumericTable;
use crate::runtime::{Runtime, Tensor};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct KMeansParams {
    pub k: usize,
    pub iters: usize,
    pub seed: u64,
    pub use_xla: bool,
    pub topology: CommTopology,
}

impl Default for KMeansParams {
    fn default() -> Self {
        KMeansParams {
            k: 8,
            iters: 10,
            seed: 0,
            use_xla: false,
            topology: CommTopology::StarGatherBroadcast,
        }
    }
}

#[derive(Debug, Clone)]
pub struct KMeansModel {
    /// k x d centroid matrix.
    pub centroids: DenseMatrix,
    /// Total within-cluster SSE per iteration.
    pub sse_history: Vec<f64>,
}

impl Model for KMeansModel {
    /// Predict the nearest-centroid index (as f64).
    fn predict(&self, x: &MLVector) -> Result<f64> {
        if x.len() != self.centroids.cols {
            return Err(Error::Shape(format!(
                "kmeans predict: dim {} != centroid dim {}",
                x.len(),
                self.centroids.cols
            )));
        }
        let mut best = (f64::INFINITY, 0usize);
        for c in 0..self.centroids.rows {
            let d2: f64 = self
                .centroids
                .row(c)
                .iter()
                .zip(x.as_slice())
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            if d2 < best.0 {
                best = (d2, c);
            }
        }
        Ok(best.1 as f64)
    }
}

pub struct KMeans {
    pub params: KMeansParams,
}

impl KMeans {
    pub fn new(params: KMeansParams) -> KMeans {
        KMeans { params }
    }

    /// k-means++-style seeding (greedy distant points, deterministic).
    fn init_centroids(&self, parts: &[DenseMatrix], d: usize) -> DenseMatrix {
        let mut rng = Rng::new(self.params.seed);
        let all_rows: usize = parts.iter().map(|m| m.rows).sum();
        let mut centroids = DenseMatrix::zeros(self.params.k, d);
        // first centroid: random point; others: farthest-point heuristic
        // over a sample for determinism and O(k * sample) cost.
        let sample: Vec<Vec<f64>> = (0..256.min(all_rows))
            .map(|_| {
                let mut idx = rng.below(all_rows);
                for m in parts {
                    if idx < m.rows {
                        return m.row(idx).to_vec();
                    }
                    idx -= m.rows;
                }
                unreachable!()
            })
            .collect();
        if sample.is_empty() {
            return centroids;
        }
        centroids.row_mut(0).copy_from_slice(&sample[0]);
        for c in 1..self.params.k {
            let mut best = (f64::NEG_INFINITY, 0usize);
            for (i, s) in sample.iter().enumerate() {
                // distance to the nearest already-chosen centroid
                let mut mind = f64::INFINITY;
                for cc in 0..c {
                    let d2: f64 = centroids
                        .row(cc)
                        .iter()
                        .zip(s)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    mind = mind.min(d2);
                }
                if mind > best.0 {
                    best = (mind, i);
                }
            }
            centroids.row_mut(c).copy_from_slice(&sample[best.1]);
        }
        centroids
    }

    /// Partition-local statistics via the XLA `kmeans_step` artifact,
    /// with driver-side padding correction: zero padding rows are
    /// assigned to the centroid nearest the origin, so that centroid's
    /// count (and the SSE) are corrected after the call.
    #[allow(clippy::too_many_arguments)]
    fn xla_partition_stats(
        rt: &Runtime,
        variant: &str,
        x: &Tensor,
        real_rows: usize,
        n_pad: usize,
        cents_padded: &[f32],
        c_art: usize,
        d_pad: usize,
        k: usize,
    ) -> Result<(Vec<f64>, Vec<f64>, f64)> {
        let out = rt.execute(
            "kmeans_step",
            variant,
            &[
                x.clone(),
                Tensor::F32(cents_padded.to_vec(), vec![c_art, d_pad]),
            ],
        )?;
        let mut it = out.into_iter();
        let mut next_out = |what: &str| {
            it.next()
                .ok_or_else(|| Error::Runtime(format!("kmeans_step missing {what} output")))
        };
        let sums_f: Vec<f32> = next_out("sums")?;
        let counts_f: Vec<f32> = next_out("counts")?;
        let sse_f: Vec<f32> = next_out("sse")?;
        // padding correction
        let pad = (n_pad - real_rows) as f64;
        let mut origin_best = (f64::INFINITY, 0usize);
        for c in 0..k {
            let norm2: f64 = (0..d_pad)
                .map(|j| (cents_padded[c * d_pad + j] as f64).powi(2))
                .sum();
            if norm2 < origin_best.0 {
                origin_best = (norm2, c);
            }
        }
        let mut sums = vec![0.0f64; k * d_pad];
        for c in 0..k {
            for j in 0..d_pad {
                sums[c * d_pad + j] = sums_f[c * d_pad + j] as f64;
            }
        }
        let mut counts: Vec<f64> = (0..k).map(|c| counts_f[c] as f64).collect();
        counts[origin_best.1] -= pad;
        let sse = sse_f[0] as f64 - pad * origin_best.0;
        Ok((sums, counts, sse))
    }

    fn rust_partition_stats(
        m: &DenseMatrix,
        centroids: &DenseMatrix,
    ) -> (Vec<f64>, Vec<f64>, f64) {
        let (k, d) = (centroids.rows, centroids.cols);
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0.0f64; k];
        let mut sse = 0.0;
        for r in 0..m.rows {
            let row = m.row(r);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..k {
                let d2: f64 = centroids
                    .row(c)
                    .iter()
                    .zip(row)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            counts[best.1] += 1.0;
            for (j, &x) in row.iter().enumerate() {
                sums[best.1 * d + j] += x;
            }
            sse += best.0;
        }
        (sums, counts, sse)
    }
}

impl Algorithm for KMeans {
    type Output = KMeansModel;

    /// Train on a numeric table whose rows are feature vectors (no label
    /// column).
    fn train(&self, data: &MLNumericTable, cluster: &SimCluster) -> Result<KMeansModel> {
        let d = data.num_cols();
        let k = self.params.k;
        let nparts = data.num_partitions();
        let parts: Vec<DenseMatrix> = (0..nparts)
            .map(|p| data.partition_matrix(p))
            .collect::<Result<_>>()?;
        let mut centroids = self.init_centroids(&parts, d);
        let mut sse_history = Vec::new();

        // XLA setup (artifact shapes + prebuilt partition tensors)
        let xla = if self.params.use_xla {
            let rt = Runtime::global()?;
            let max_rows = parts.iter().map(|m| m.rows).max().unwrap_or(0);
            let mut best: Option<(usize, String, usize, usize, usize)> = None;
            for a in rt.manifest().variants("kmeans_step") {
                let (n, dd) = (a.inputs[0].shape[0], a.inputs[0].shape[1]);
                let c_art = a.inputs[1].shape[0];
                if n >= max_rows && dd >= d && c_art >= k {
                    let cost = n * dd;
                    if best.as_ref().map(|(c, ..)| cost < *c).unwrap_or(true) {
                        best = Some((cost, a.variant.clone(), n, dd, c_art));
                    }
                }
            }
            let (_, variant, n_pad, d_pad, c_art) = best.ok_or_else(|| {
                Error::Runtime(format!("no kmeans_step artifact fits n<={max_rows}, d={d}, k={k}"))
            })?;
            let tensors: Vec<(Tensor, usize)> = parts
                .iter()
                .map(|m| {
                    let mut x = vec![0.0f32; n_pad * d_pad];
                    for r in 0..m.rows {
                        for c in 0..m.cols {
                            x[r * d_pad + c] = m.get(r, c) as f32;
                        }
                    }
                    (Tensor::F32(x, vec![n_pad, d_pad]), m.rows)
                })
                .collect();
            Some((rt, variant, n_pad, d_pad, c_art, tensors))
        } else {
            None
        };

        for _it in 0..self.params.iters {
            cluster.begin_round();
            // broadcast centroids through the network fault layer; close
            // the round before propagating a link failure
            if let Err(e) = cluster.net_broadcast(self.params.topology, (k * d * 4) as u64) {
                cluster.end_round();
                return Err(e);
            }
            let mut gsums = vec![0.0f64; k * d];
            let mut gcounts = vec![0.0f64; k];
            let mut gsse = 0.0f64;
            // pad centroids once per round: rows beyond k get far-away
            // sentinels so no real point selects them
            let cp: Vec<f32> = match &xla {
                Some((_, _, _, d_pad, c_art, _)) => {
                    let mut cp = vec![0.0f32; c_art * d_pad];
                    for c in 0..k {
                        for j in 0..d {
                            cp[c * d_pad + j] = centroids.get(c, j) as f32;
                        }
                    }
                    for c in k..*c_art {
                        cp[c * d_pad] = 1.0e15;
                    }
                    cp
                }
                None => Vec::new(),
            };
            // per-partition statistics in parallel (one task per
            // partition); sums folded below in partition index order so
            // centroid updates are identical for any thread count
            let stage = crate::exec::TaskSet::new("kmeans-stats", parts.len());
            let results = stage.run(cluster.pool().as_deref(), |p| {
                let machine = cluster.assign_machine(p)?;
                match &xla {
                    Some((rt, variant, n_pad, d_pad, c_art, tensors)) => {
                        let (x, rows) = &tensors[p];
                        let (s_full, counts, sse) = cluster.run_task(machine, || {
                            Self::xla_partition_stats(
                                rt, variant, x, *rows, *n_pad, &cp, *c_art, *d_pad, k,
                            )
                        })?;
                        // trim sums to (k, d)
                        let mut s = vec![0.0f64; k * d];
                        for c in 0..k {
                            for j in 0..d {
                                s[c * d + j] = s_full[c * d_pad + j];
                            }
                        }
                        Ok((s, counts, sse))
                    }
                    None => Ok(cluster.run_task(machine, || {
                        Self::rust_partition_stats(&parts[p], &centroids)
                    })),
                }
            });
            for r in results {
                let (sums, counts, sse) = r?;
                for (g, s) in gsums.iter_mut().zip(&sums) {
                    *g += s;
                }
                for (g, c) in gcounts.iter_mut().zip(&counts) {
                    *g += c;
                }
                gsse += sse;
            }
            // gather statistics at master: k*d sums + k counts per machine
            let sent = cluster.net_allreduce(self.params.topology, ((k * d + k) * 4) as u64);
            cluster.end_round();
            sent?;

            for c in 0..k {
                if gcounts[c] > 0.0 {
                    for j in 0..d {
                        centroids.set(c, j, gsums[c * d + j] / gcounts[c]);
                    }
                }
                // empty clusters keep their previous centroid
            }
            sse_history.push(gsse);
        }

        Ok(KMeansModel { centroids, sse_history })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;
    use crate::mltable::{MLRow, MLTable, Schema};

    fn blob_table(centers: &[[f64; 2]], per: usize, seed: u64) -> MLNumericTable {
        let ctx = EngineContext::new();
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        for c in centers {
            for _ in 0..per {
                rows.push(MLRow::from_scalars(&[
                    c[0] + 0.1 * rng.normal(),
                    c[1] + 0.1 * rng.normal(),
                ]));
            }
        }
        rng.shuffle(&mut rows);
        MLTable::from_rows(&ctx, rows, Schema::numeric(2), 4)
            .unwrap()
            .to_numeric()
            .unwrap()
    }

    fn check_recovers_blobs(use_xla: bool) {
        let centers = [[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let t = blob_table(&centers, 40, 1);
        let algo = KMeans::new(KMeansParams {
            k: 3,
            iters: 8,
            use_xla,
            ..Default::default()
        });
        let model = algo.train(&t, &SimCluster::ec2(4)).unwrap();
        // every true center has a centroid within 0.5
        for c in &centers {
            let nearest = (0..3)
                .map(|i| {
                    let row = model.centroids.row(i);
                    ((row[0] - c[0]).powi(2) + (row[1] - c[1]).powi(2)).sqrt()
                })
                .fold(f64::INFINITY, f64::min);
            assert!(nearest < 0.5, "center {c:?} unmatched ({nearest})");
        }
        // SSE non-increasing
        let h = &model.sse_history;
        assert!(
            h.windows(2).all(|w| w[1] <= w[0] + 1e-6),
            "SSE not monotone: {h:?}"
        );
        // predict maps points to their blob
        let p0 = model.predict(&MLVector::new(vec![0.1, -0.1])).unwrap();
        let p1 = model.predict(&MLVector::new(vec![9.8, 0.3])).unwrap();
        assert_ne!(p0 as usize, p1 as usize);
    }

    #[test]
    fn rust_backend_recovers_blobs() {
        check_recovers_blobs(false);
    }

    #[test]
    fn xla_backend_recovers_blobs() {
        if !crate::runtime::require_artifacts_or_skip("kmeans::xla_backend_recovers_blobs") {
            return;
        }
        check_recovers_blobs(true);
    }

    #[test]
    fn parallel_clustering_matches_serial() {
        let t = blob_table(&[[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]], 40, 6);
        let params = KMeansParams {
            k: 3,
            iters: 6,
            ..Default::default()
        };
        let serial = KMeans::new(params.clone())
            .train(&t, &SimCluster::ec2(4))
            .unwrap();
        let cluster = SimCluster::ec2(4).with_executor(4);
        let par = KMeans::new(params).train(&t, &cluster).unwrap();
        assert_eq!(serial.centroids.data, par.centroids.data);
        assert_eq!(serial.sse_history, par.sse_history);
    }

    #[test]
    fn xla_and_rust_agree() {
        if !crate::runtime::require_artifacts_or_skip("kmeans::xla_and_rust_agree") {
            return;
        }
        let t = blob_table(&[[0.0, 0.0], [5.0, 5.0]], 30, 2);
        let params = |use_xla| KMeansParams {
            k: 2,
            iters: 5,
            seed: 3,
            use_xla,
            ..Default::default()
        };
        let m_rust = KMeans::new(params(false)).train(&t, &SimCluster::ec2(2)).unwrap();
        let m_xla = KMeans::new(params(true)).train(&t, &SimCluster::ec2(2)).unwrap();
        for c in 0..2 {
            for j in 0..2 {
                assert!(
                    (m_rust.centroids.get(c, j) - m_xla.centroids.get(c, j)).abs() < 1e-3,
                    "centroid ({c},{j})"
                );
            }
        }
        // SSE histories match too
        for (a, b) in m_rust.sse_history.iter().zip(&m_xla.sse_history) {
            assert!((a - b).abs() < 1e-2 * a.max(1.0));
        }
    }

    #[test]
    fn predict_dimension_check() {
        let t = blob_table(&[[0.0, 0.0]], 10, 4);
        let m = KMeans::new(KMeansParams { k: 1, iters: 2, ..Default::default() })
            .train(&t, &SimCluster::ec2(1))
            .unwrap();
        assert!(m.predict(&MLVector::new(vec![1.0, 2.0, 3.0])).is_err());
    }
}
