//! Alternating least squares matrix factorization (paper §IV-B,
//! reference code Fig. A9 `BroadcastALS`).
//!
//! Each round alternates:
//!   1. broadcast V, update every user row of U in parallel across
//!      machines (each user solves `(Yq^T Yq + lambda I) u_q = Yq^T r_q`
//!      over its rated items' factors),
//!   2. broadcast U, update every item row of V symmetrically (using the
//!      transposed ratings, which — like the paper — we distribute
//!      alongside the original).
//!
//! The per-entity normal equations are assembled by the XLA `als_gram` /
//! `als_solve` artifacts (Pallas gram kernel inside): entities whose
//! rating count fits the artifact's gather width `m` use the fused
//! gram+solve; heavier entities are *chunked* into m-wide slots whose
//! grams are summed driver-side (grams are additive) and solved with the
//! in-tree Cholesky. A pure-rust backend provides the differential
//! reference.

use super::Model;
use crate::cluster::{CommTopology, SimCluster};
use crate::data::netflix::RatingsData;
use crate::error::{Error, Result};
use crate::localmatrix::{linalg, CsrMatrix, DenseMatrix, MLVector};
use crate::runtime::{Runtime, Tensor};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct AlsParams {
    /// Latent rank k (paper: 10).
    pub rank: usize,
    /// Alternation rounds (paper: 10).
    pub iters: usize,
    /// Ridge strength lambda (paper: 0.01).
    pub lambda: f64,
    pub seed: u64,
    pub use_xla: bool,
    pub topology: CommTopology,
    /// Record train RMSE after each round (untimed, like the paper).
    pub track_rmse: bool,
    /// Mahout-style execution: every half-round is a fresh MapReduce job
    /// that re-reads the ratings from HDFS and writes the updated factors
    /// back through the replication pipeline, plus a fixed job-startup
    /// cost. This is the mechanism the paper blames for Mahout's
    /// iteration overhead ("its reliance on HDFS to store and communicate
    /// intermediate state makes it poorly suited for iterative
    /// algorithms", §II).
    pub disk_spill: bool,
}

impl Default for AlsParams {
    fn default() -> Self {
        AlsParams {
            rank: 10,
            iters: 10,
            lambda: 0.01,
            seed: 0,
            use_xla: false,
            topology: CommTopology::StarGatherBroadcast,
            track_rmse: false,
            disk_spill: false,
        }
    }
}

/// Trained factorization: M ~ U V^T.
#[derive(Debug, Clone)]
pub struct AlsModel {
    /// users x k.
    pub u: DenseMatrix,
    /// items x k.
    pub v: DenseMatrix,
    pub rmse_history: Vec<f64>,
}

impl AlsModel {
    pub fn predict_rating(&self, user: usize, item: usize) -> f64 {
        self.u
            .row(user)
            .iter()
            .zip(self.v.row(item))
            .map(|(a, b)| a * b)
            .sum()
    }

    /// Train RMSE over the observed entries.
    pub fn rmse(&self, ratings: &CsrMatrix) -> f64 {
        let mut sse = 0.0;
        let mut n = 0usize;
        for user in 0..ratings.rows {
            for (item, r) in ratings.row_iter(user) {
                let e = self.predict_rating(user, item) - r;
                sse += e * e;
                n += 1;
            }
        }
        (sse / n.max(1) as f64).sqrt()
    }
}

impl Model for AlsModel {
    /// Predict from a [user_id, item_id] vector (collaborative-filtering
    /// models "make recommendations for an existing user", §III-C).
    fn predict(&self, x: &MLVector) -> Result<f64> {
        if x.len() != 2 {
            return Err(Error::Shape("ALS predict expects [user, item]".into()));
        }
        let (user, item) = (x[0] as usize, x[1] as usize);
        if user >= self.u.rows || item >= self.v.rows {
            return Err(Error::Shape(format!(
                "predict: (user {user}, item {item}) out of range"
            )));
        }
        Ok(self.predict_rating(user, item))
    }
}

pub struct ALS {
    pub params: AlsParams,
}

/// XLA artifact shapes for ALS, resolved once per training run.
struct XlaAls {
    rt: std::sync::Arc<Runtime>,
    variant: String,
    u_pad: usize,
    m: usize,
    k_art: usize,
}

impl ALS {
    pub fn new(params: AlsParams) -> ALS {
        ALS { params }
    }

    /// Train on a ratings matrix. `cluster` partitions users (and items,
    /// via the transpose) into contiguous ranges, one per machine.
    pub fn train_ratings(&self, data: &RatingsData, cluster: &SimCluster) -> Result<AlsModel> {
        let k = self.params.rank;
        let mut rng = Rng::new(self.params.seed);
        // paper init: LocalMatrix.rand — uniform [0,1) scaled keeps early
        // gram matrices well-conditioned
        let scale = 1.0 / (k as f64).sqrt();
        let mut u = DenseMatrix::rand(data.users, k, &mut rng).map(|x| x * scale);
        let mut v = DenseMatrix::rand(data.items, k, &mut rng).map(|x| x * scale);
        let transposed = data.ratings.transpose();
        let mut rmse_history = Vec::new();

        let xla = if self.params.use_xla {
            let rt = Runtime::global()?;
            let mut best: Option<(usize, String, usize, usize, usize)> = None;
            for a in rt.manifest().variants("als_gram_batch") {
                let (up, m, ka) = (
                    a.inputs[0].shape[0],
                    a.inputs[0].shape[1],
                    a.inputs[0].shape[2],
                );
                if ka >= k {
                    let cost = up * m * ka;
                    if best.as_ref().map(|(c, ..)| cost < *c).unwrap_or(true) {
                        best = Some((cost, a.variant.clone(), up, m, ka));
                    }
                }
            }
            let (_, variant, u_pad, m, k_art) = best.ok_or_else(|| {
                Error::Runtime(format!("no als_gram_batch artifact with k >= {k}"))
            })?;
            Some(XlaAls { rt, variant, u_pad, m, k_art })
        } else {
            None
        };

        let machines = cluster.num_machines();
        for _round in 0..self.params.iters {
            // half-round 1: broadcast V, update U
            u = self.update_side(&data.ratings, &v, cluster, machines, &xla)?;
            // half-round 2: broadcast U, update V
            v = self.update_side(&transposed, &u, cluster, machines, &xla)?;
            if self.params.track_rmse {
                let model = AlsModel {
                    u: u.clone(),
                    v: v.clone(),
                    rmse_history: vec![],
                };
                rmse_history.push(model.rmse(&data.ratings));
            }
        }

        Ok(AlsModel { u, v, rmse_history })
    }

    /// One half-round: update all rows of the `ratings.rows`-side factor
    /// given the fixed counterpart `fixed` (items x k or users x k).
    fn update_side(
        &self,
        ratings: &CsrMatrix,
        fixed: &DenseMatrix,
        cluster: &SimCluster,
        machines: usize,
        xla: &Option<XlaAls>,
    ) -> Result<DenseMatrix> {
        let k = self.params.rank;
        let n = ratings.rows;
        let mut out = DenseMatrix::zeros(n, k);
        let tracer = cluster.tracer();
        let half_t0 = tracer.start();
        cluster.begin_round();
        // Fig. A9: ctx.broadcast(fixedFactor) — through the network fault
        // layer; close the round before propagating a link failure
        if let Err(e) = cluster.net_broadcast(self.params.topology, (fixed.rows * k * 4) as u64)
        {
            cluster.end_round();
            return Err(e);
        }
        if self.params.disk_spill {
            // Mahout profile: fresh Hadoop job per half-round — JVM spawn,
            // re-read this machine's ratings shard from HDFS, and write
            // the updated factor slice back 3x-replicated.
            cluster.charge_job_startup();
            let ratings_bytes = (ratings.nnz() * 16 / machines.max(1)) as u64;
            let factor_bytes = (n * k * 4 / machines.max(1)) as u64;
            cluster.charge_hdfs_roundtrip(ratings_bytes + factor_bytes);
        }

        // contiguous range per machine; solves fan out across the exec
        // pool when one is attached, results copied back in machine index
        // order (each range writes a disjoint row span, so the updated
        // factor is identical for any thread count)
        let per = n.div_ceil(machines);
        let stage = crate::exec::TaskSet::new("als-solve", machines);
        let results = stage.try_run(cluster.pool().as_deref(), |machine| {
            let lo = machine * per;
            let hi = ((machine + 1) * per).min(n);
            if lo >= hi {
                return Ok(Vec::new());
            }
            // the row-range partitioning is fixed, but execution lands on
            // the next alive machine when this one is down
            let host = cluster.assign_machine(machine)?;
            cluster.run_task(host, || match xla {
                Some(x) => self.solve_range_xla(ratings, fixed, lo, hi, x),
                None => self.solve_range_rust(ratings, fixed, lo, hi),
            })
        })?;
        for (machine, rows) in results.into_iter().enumerate() {
            let lo = machine * per;
            for (i, row) in rows?.iter().enumerate() {
                out.row_mut(lo + i).copy_from_slice(row);
            }
        }

        // updated factor slices gather to master + broadcast next round
        let sent = cluster.net_allreduce(self.params.topology, (n * k * 4) as u64);
        cluster.end_round();
        sent?;
        if let Some(t0) = half_t0 {
            tracer.span("als-half-round", "optim", 0, t0, &[("rows", n as f64)]);
        }
        Ok(out)
    }

    /// Pure-rust reference: per entity, assemble the k x k normal
    /// equations from its rated counterpart factors and Cholesky-solve.
    fn solve_range_rust(
        &self,
        ratings: &CsrMatrix,
        fixed: &DenseMatrix,
        lo: usize,
        hi: usize,
    ) -> Result<Vec<Vec<f64>>> {
        let k = self.params.rank;
        let lam = self.params.lambda;
        let mut out = Vec::with_capacity(hi - lo);
        let mut a = DenseMatrix::zeros(k, k);
        let mut b = vec![0.0f64; k];
        for q in lo..hi {
            // reset normal equations
            for x in a.data.iter_mut() {
                *x = 0.0;
            }
            for x in b.iter_mut() {
                *x = 0.0;
            }
            for (j, r) in ratings.row_iter(q) {
                let y = fixed.row(j);
                for c in 0..k {
                    b[c] += y[c] * r;
                    for cc in c..k {
                        let v = y[c] * y[cc];
                        a.data[c * k + cc] += v;
                    }
                }
            }
            // symmetrize + ridge
            for c in 0..k {
                for cc in 0..c {
                    a.data[c * k + cc] = a.data[cc * k + c];
                }
                a.data[c * k + c] += lam;
            }
            out.push(linalg::spd_solve(&a, &b)?);
        }
        Ok(out)
    }

    /// XLA path: pack entities into (u_pad, m, k) gather tensors. Entities
    /// with nnz <= m occupy one slot; heavier entities span multiple slots
    /// whose grams are summed (grams are additive in the ratings).
    fn solve_range_xla(
        &self,
        ratings: &CsrMatrix,
        fixed: &DenseMatrix,
        lo: usize,
        hi: usize,
        xla: &XlaAls,
    ) -> Result<Vec<Vec<f64>>> {
        let k = self.params.rank;
        let (u_pad, m, k_art) = (xla.u_pad, xla.m, xla.k_art);
        let lam = self.params.lambda as f32;

        // slot list: (entity, rating-range within its row)
        let mut slots: Vec<(usize, usize, usize)> = Vec::new();
        for q in lo..hi {
            let nnz = ratings.row_nnz(q);
            let mut s = 0;
            loop {
                let e = (s + m).min(nnz);
                slots.push((q, s, e));
                s = e;
                if s >= nnz {
                    break;
                }
            }
        }

        // per-entity accumulated gram (k x k) + rhs (k); ordered map so
        // any iteration over it is deterministic (entity ids are Ord)
        let mut grams: std::collections::BTreeMap<usize, (Vec<f32>, Vec<f32>)> =
            std::collections::BTreeMap::new();

        for group in slots.chunks(u_pad) {
            let mut f = vec![0.0f32; u_pad * m * k_art];
            let mut r = vec![0.0f32; u_pad * m];
            let mut mask = vec![0.0f32; u_pad * m];
            for (slot, &(q, s, e)) in group.iter().enumerate() {
                let base_f = slot * m * k_art;
                let base_r = slot * m;
                for (j, (item, rating)) in ratings
                    .row_iter(q)
                    .skip(s)
                    .take(e - s)
                    .enumerate()
                {
                    let y = fixed.row(item);
                    for c in 0..k {
                        f[base_f + j * k_art + c] = y[c] as f32;
                    }
                    r[base_r + j] = rating as f32;
                    mask[base_r + j] = 1.0;
                }
            }
            let out = xla.rt.execute(
                "als_gram_batch",
                &xla.variant,
                &[
                    Tensor::F32(f, vec![u_pad, m, k_art]),
                    Tensor::F32(r, vec![u_pad, m]),
                    Tensor::F32(mask, vec![u_pad, m]),
                ],
            )?;
            let mut it = out.into_iter();
            let mut next_out = |what: &str| {
                it.next()
                    .ok_or_else(|| Error::Engine(format!("als_gram_batch missing {what} output")))
            };
            let g_all = next_out("gram")?; // (u_pad, k_art, k_art)
            let b_all = next_out("rhs")?; // (u_pad, k_art)
            for (slot, &(q, _, _)) in group.iter().enumerate() {
                let entry = grams
                    .entry(q)
                    .or_insert_with(|| (vec![0.0f32; k * k], vec![0.0f32; k]));
                for c in 0..k {
                    entry.1[c] += b_all[slot * k_art + c];
                    for cc in 0..k {
                        entry.0[c * k + cc] +=
                            g_all[slot * k_art * k_art + c * k_art + cc];
                    }
                }
            }
        }

        // tiny k x k solves (f64 Cholesky)
        let mut out = Vec::with_capacity(hi - lo);
        for q in lo..hi {
            let (g, b) = grams
                .get(&q)
                .ok_or_else(|| Error::Engine(format!("entity {q} missing gram")))?;
            let mut a = DenseMatrix::zeros(k, k);
            for c in 0..k {
                for cc in 0..k {
                    a.data[c * k + cc] = g[c * k + cc] as f64;
                }
                a.data[c * k + c] += lam as f64;
            }
            let bb: Vec<f64> = b.iter().map(|&x| x as f64).collect();
            out.push(linalg::spd_solve(&a, &bb)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::netflix::{self, NetflixConfig};

    fn small_data(seed: u64) -> RatingsData {
        netflix::generate(&NetflixConfig {
            users: 96,
            items: 40,
            rank: 4,
            mean_nnz_per_user: 10,
            max_nnz_per_user: 20,
            noise: 0.05,
            seed,
            ..Default::default()
        })
    }

    fn check_learns(use_xla: bool) {
        let data = small_data(1);
        let als = ALS::new(AlsParams {
            rank: 6,
            iters: 6,
            lambda: 0.05,
            use_xla,
            track_rmse: true,
            ..Default::default()
        });
        let cluster = SimCluster::ec2(4);
        let model = als.train_ratings(&data, &cluster).unwrap();
        let h = &model.rmse_history;
        assert!(
            h.last().unwrap() < h.first().unwrap(),
            "RMSE did not improve: {h:?}"
        );
        // low-noise planted data should factor well
        assert!(*h.last().unwrap() < 0.4, "final RMSE {}", h.last().unwrap());
        assert_eq!(model.u.rows, 96);
        assert_eq!(model.v.rows, 40);
        // comm was charged (broadcast + gather per half-round)
        assert!(cluster.total_comm_seconds() > 0.0);
        assert_eq!(cluster.rounds(), 12);
    }

    #[test]
    fn rust_backend_learns() {
        check_learns(false);
    }

    #[test]
    fn xla_backend_learns() {
        if !crate::runtime::require_artifacts_or_skip("als::xla_backend_learns") {
            return;
        }
        check_learns(true);
    }

    #[test]
    fn parallel_factors_match_serial() {
        // executor-attached cluster produces bitwise-identical factors
        let data = small_data(5);
        let params = AlsParams {
            rank: 4,
            iters: 4,
            lambda: 0.05,
            seed: 9,
            ..Default::default()
        };
        let serial = ALS::new(params.clone())
            .train_ratings(&data, &SimCluster::ec2(4))
            .unwrap();
        for threads in [2, 4] {
            let cluster = SimCluster::ec2(4).with_executor(threads);
            let par = ALS::new(params.clone())
                .train_ratings(&data, &cluster)
                .unwrap();
            assert_eq!(serial.u.data, par.u.data, "U differs at {threads} threads");
            assert_eq!(serial.v.data, par.v.data, "V differs at {threads} threads");
        }
    }

    #[test]
    fn xla_and_rust_agree() {
        if !crate::runtime::require_artifacts_or_skip("als::xla_and_rust_agree") {
            return;
        }
        let data = small_data(2);
        let params = |use_xla| AlsParams {
            rank: 5,
            iters: 3,
            lambda: 0.1,
            seed: 7,
            use_xla,
            ..Default::default()
        };
        let m_rust = ALS::new(params(false))
            .train_ratings(&data, &SimCluster::ec2(2))
            .unwrap();
        let m_xla = ALS::new(params(true))
            .train_ratings(&data, &SimCluster::ec2(2))
            .unwrap();
        // same seed, same math (modulo f32 gram) -> near-identical factors
        let mut max_diff = 0.0f64;
        for i in 0..m_rust.u.rows {
            for c in 0..5 {
                max_diff = max_diff.max((m_rust.u.get(i, c) - m_xla.u.get(i, c)).abs());
            }
        }
        assert!(max_diff < 1e-2, "U diverged by {max_diff}");
        let r_rust = m_rust.rmse(&data.ratings);
        let r_xla = m_xla.rmse(&data.ratings);
        assert!((r_rust - r_xla).abs() < 1e-3, "{r_rust} vs {r_xla}");
    }

    #[test]
    fn chunked_heavy_items_handled() {
        if !crate::runtime::require_artifacts_or_skip("als::chunked_heavy_items_handled") {
            return;
        }
        // items see ~users*mean/items ratings >> m(small artifact = 64):
        // forces the chunked gram path on the item side.
        let data = netflix::generate(&NetflixConfig {
            users: 600,
            items: 24,
            rank: 4,
            mean_nnz_per_user: 8,
            max_nnz_per_user: 16,
            noise: 0.05,
            seed: 3,
            ..Default::default()
        });
        // item degree ~ 600*10/24 = 250 > 64 -> chunking exercised
        let als = ALS::new(AlsParams {
            rank: 4,
            iters: 3,
            lambda: 0.05,
            use_xla: true,
            track_rmse: true,
            ..Default::default()
        });
        let model = als.train_ratings(&data, &SimCluster::ec2(3)).unwrap();
        assert!(model.rmse_history.last().unwrap() < &0.5);

        // differential check against rust on the same config
        let als_rust = ALS::new(AlsParams {
            rank: 4,
            iters: 3,
            lambda: 0.05,
            use_xla: false,
            track_rmse: true,
            ..Default::default()
        });
        let m2 = als_rust.train_ratings(&data, &SimCluster::ec2(3)).unwrap();
        assert!(
            (model.rmse_history.last().unwrap() - m2.rmse_history.last().unwrap()).abs() < 1e-2
        );
    }

    #[test]
    fn predict_bounds_checked() {
        let data = small_data(4);
        let model = ALS::new(AlsParams {
            rank: 3,
            iters: 1,
            ..Default::default()
        })
        .train_ratings(&data, &SimCluster::ec2(1))
        .unwrap();
        assert!(model.predict(&MLVector::new(vec![0.0, 0.0])).is_ok());
        assert!(model.predict(&MLVector::new(vec![1e9, 0.0])).is_err());
        assert!(model.predict(&MLVector::new(vec![0.0])).is_err());
    }
}
