//! Linear regression via distributed SGD — the paper's "naturally
//! extends ... simply by changing the expression of the gradient
//! function" (§IV): same optimizer, [`GlmGradient::Squared`] plugged in.

use std::sync::Arc;

use super::glm::{GlmData, GlmGradient, RustGlmStep};
use super::{Algorithm, Model};
use crate::cluster::SimCluster;
use crate::error::Result;
use crate::localmatrix::MLVector;
use crate::mltable::MLNumericTable;
use crate::optim::{SgdParams, SGD};

pub struct LinearRegression {
    pub sgd: SgdParams,
}

impl LinearRegression {
    pub fn new(sgd: SgdParams) -> LinearRegression {
        LinearRegression { sgd }
    }
}

#[derive(Debug, Clone)]
pub struct LinRegModel {
    pub weights: MLVector,
    pub loss_history: Vec<f64>,
}

impl Model for LinRegModel {
    fn predict(&self, x: &MLVector) -> Result<f64> {
        x.dot(&self.weights)
    }
}

impl Algorithm for LinearRegression {
    type Output = LinRegModel;

    fn train(&self, data: &MLNumericTable, cluster: &SimCluster) -> Result<LinRegModel> {
        let d = data.num_cols() - 1;
        let mut max_rows = 1;
        for p in 0..data.num_partitions() {
            max_rows = max_rows.max(data.dataset().partition(p)?.len());
        }
        let glm = Arc::new(GlmData::prepare(data, max_rows, d, 32.min(max_rows))?);
        let step = RustGlmStep::new(glm, GlmGradient::Squared);
        let res = SGD::run(&step, cluster, &self.sgd)?;
        Ok(LinRegModel {
            weights: MLVector::new(res.weights[..d].iter().map(|&x| x as f64).collect()),
            loss_history: res.loss_history,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;
    use crate::mltable::{MLRow, MLTable, Schema};
    use crate::util::rng::Rng;

    #[test]
    fn recovers_planted_linear_model() {
        let ctx = EngineContext::new();
        let mut rng = Rng::new(3);
        let w_true = [2.0, -1.0, 0.5];
        let rows: Vec<MLRow> = (0..300)
            .map(|_| {
                let x: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
                let y: f64 = x.iter().zip(&w_true).map(|(a, b)| a * b).sum::<f64>()
                    + 0.01 * rng.normal();
                let mut row = vec![y];
                row.extend(&x);
                MLRow::from_scalars(&row)
            })
            .collect();
        let t = MLTable::from_rows(&ctx, rows, Schema::numeric(4), 4)
            .unwrap()
            .to_numeric()
            .unwrap();
        let algo = LinearRegression::new(SgdParams {
            learning_rate: 0.01,
            iters: 40,
            track_loss: true,
            ..Default::default()
        });
        let m = algo.train(&t, &SimCluster::ec2(4)).unwrap();
        for j in 0..3 {
            assert!(
                (m.weights[j] - w_true[j]).abs() < 0.1,
                "dim {j}: {} vs {}",
                m.weights[j],
                w_true[j]
            );
        }
        assert!(m.loss_history.last().unwrap() < m.loss_history.first().unwrap());
    }
}
