//! Synthetic topic-clustered text corpus for the Fig. A2 pipeline
//! (nGrams -> tfIdf -> KMeans). Documents are drawn from `topics` latent
//! topics; each topic has a preferred vocabulary slice, so a correct
//! pipeline recovers the clusters.

use std::sync::Arc;

use crate::engine::EngineContext;
use crate::error::Result;
use crate::mltable::{text_from_str, MLTable};
use crate::util::rng::Rng;

/// Corpus generator parameters.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub docs: usize,
    pub topics: usize,
    pub vocab: usize,
    pub words_per_doc: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            docs: 200,
            topics: 4,
            vocab: 400,
            words_per_doc: 40,
            seed: 5,
        }
    }
}

/// A generated corpus: the text plus ground-truth topic labels.
pub struct Corpus {
    pub text: String,
    pub labels: Vec<usize>,
    pub cfg: CorpusConfig,
}

fn word(i: usize) -> String {
    // deterministic pseudo-words: w<i> is fine for tokenization tests
    format!("w{i}")
}

/// Generate a corpus. Each topic owns a contiguous vocabulary slice; a
/// document samples 80% of its words from its topic slice and 20% from
/// the shared background.
pub fn generate(cfg: &CorpusConfig) -> Corpus {
    let mut rng = Rng::new(cfg.seed);
    let slice = cfg.vocab / cfg.topics;
    let mut text = String::new();
    let mut labels = Vec::with_capacity(cfg.docs);
    for _ in 0..cfg.docs {
        let topic = rng.below(cfg.topics);
        labels.push(topic);
        let mut words = Vec::with_capacity(cfg.words_per_doc);
        for _ in 0..cfg.words_per_doc {
            let w = if rng.f64() < 0.8 {
                // topical word (zipf-ish within the slice)
                topic * slice + rng.powerlaw(slice, 1.1)
            } else {
                rng.below(cfg.vocab)
            };
            words.push(word(w));
        }
        text.push_str(&words.join(" "));
        text.push('\n');
    }
    Corpus {
        text,
        labels,
        cfg: cfg.clone(),
    }
}

/// Generate and load as an MLTable (one row per document).
pub fn generate_table(
    ctx: &Arc<EngineContext>,
    cfg: &CorpusConfig,
    partitions: usize,
) -> Result<(MLTable, Vec<usize>)> {
    let corpus = generate(cfg);
    let t = text_from_str(ctx, &corpus.text, partitions)?;
    Ok((t, corpus.labels))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;

    #[test]
    fn corpus_shape() {
        let c = generate(&CorpusConfig::default());
        assert_eq!(c.text.lines().count(), 200);
        assert_eq!(c.labels.len(), 200);
        let first = c.text.lines().next().unwrap();
        assert_eq!(first.split_whitespace().count(), 40);
        assert!(c.labels.iter().all(|&t| t < 4));
    }

    #[test]
    fn loads_as_table() {
        let ctx = EngineContext::new();
        let (t, labels) = generate_table(&ctx, &CorpusConfig::default(), 4).unwrap();
        assert_eq!(t.num_rows().unwrap(), labels.len());
        assert_eq!(t.num_partitions(), 4);
    }

    #[test]
    fn topics_use_distinct_vocabulary() {
        let c = generate(&CorpusConfig {
            docs: 100,
            topics: 2,
            vocab: 100,
            words_per_doc: 50,
            seed: 1,
        });
        // the most frequent words of each topic should be disjoint-ish
        // (supports overlap via the 20% background, but the heads differ)
        let mut freq0 = std::collections::HashMap::new();
        let mut freq1 = std::collections::HashMap::new();
        for (line, &label) in c.text.lines().zip(&c.labels) {
            for w in line.split_whitespace() {
                let f = if label == 0 { &mut freq0 } else { &mut freq1 };
                *f.entry(w.to_string()).or_insert(0usize) += 1;
            }
        }
        let top = |f: &std::collections::HashMap<String, usize>| {
            let mut v: Vec<(&String, &usize)> = f.iter().collect();
            v.sort_by(|a, b| b.1.cmp(a.1).then(a.0.cmp(b.0)));
            v.into_iter().take(5).map(|(w, _)| w.clone()).collect::<Vec<_>>()
        };
        let (t0, t1) = (top(&freq0), top(&freq1));
        let shared = t0.iter().filter(|w| t1.contains(w)).count();
        assert!(shared <= 2, "topic heads too similar: {t0:?} vs {t1:?}");
    }
}
