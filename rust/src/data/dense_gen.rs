//! Dense classification data: the ImageNet-surrogate for §IV-A.
//!
//! The paper trains logistic regression on featurized ImageNet (160K dense
//! features/image). SGD cost is O(n*d) per pass regardless of what the
//! features encode, so a planted logistic model with Gaussian features
//! exercises the identical code path at configurable scale: x ~ N(0, I),
//! y ~ Bernoulli(sigmoid(x . w*)) with a fixed planted w*.

use std::sync::Arc;

use crate::engine::EngineContext;
use crate::error::Result;
use crate::localmatrix::MLVector;
use crate::mltable::{MLNumericTable, MLRow, MLTable, Schema, Value};
use crate::util::rng::Rng;

/// A generated classification dataset. Column 0 is the {0,1} label, the
/// remaining `d` columns are features (the Fig. A4 convention:
/// `vec(0)` = label, `vec.slice(1, ...)` = features).
pub struct ClassificationData {
    pub table: MLNumericTable,
    /// The planted weight vector (for accuracy checks in tests).
    pub w_true: MLVector,
    pub n: usize,
    pub d: usize,
}

/// Generate `n` examples with `d` features over `partitions` partitions.
pub fn generate(
    ctx: &Arc<EngineContext>,
    n: usize,
    d: usize,
    partitions: usize,
    seed: u64,
) -> Result<ClassificationData> {
    let mut rng = Rng::new(seed);
    // planted model: strong enough signal that labels are learnable
    // (margin std ~4 => Bayes accuracy ~0.9), still stochastic labels
    let w_true = MLVector::new((0..d).map(|_| rng.normal() * (4.0 / (d as f64).sqrt())).collect());

    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        let mut vals = Vec::with_capacity(d + 1);
        vals.push(Value::Scalar(0.0)); // placeholder for label
        let mut margin = 0.0;
        for j in 0..d {
            let x = rng.normal();
            margin += x * w_true[j];
            vals.push(Value::Scalar(x));
        }
        let p = 1.0 / (1.0 + (-margin).exp());
        let y = if rng.f64() < p { 1.0 } else { 0.0 };
        vals[0] = Value::Scalar(y);
        rows.push(MLRow::new(vals));
    }

    let table = MLTable::from_dataset(
        ctx.parallelize(rows, partitions),
        Schema::numeric(d + 1),
    )
    .to_numeric()?
    .cache();
    Ok(ClassificationData { table, w_true, n, d })
}

/// Bytes one example occupies in the *simulated* systems' memory model
/// (f64 features + label, the dominant term at the paper's scale).
pub fn example_bytes(d: usize) -> u64 {
    ((d + 1) * 8) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;

    #[test]
    fn shapes_and_labels() {
        let ctx = EngineContext::new();
        let data = generate(&ctx, 200, 16, 4, 42).unwrap();
        assert_eq!(data.table.num_rows().unwrap(), 200);
        assert_eq!(data.table.num_cols(), 17);
        assert_eq!(data.table.num_partitions(), 4);
        // labels are {0,1} and both classes appear
        let mut seen = [false, false];
        for r in data.table.table().collect().unwrap() {
            let y = r[0].as_scalar().unwrap();
            assert!(y == 0.0 || y == 1.0);
            seen[y as usize] = true;
        }
        assert!(seen[0] && seen[1], "degenerate labels");
    }

    #[test]
    fn deterministic_per_seed() {
        let ctx = EngineContext::new();
        let a = generate(&ctx, 50, 8, 2, 7).unwrap();
        let b = generate(&ctx, 50, 8, 2, 7).unwrap();
        assert_eq!(
            a.table.collect_matrix().unwrap(),
            b.table.collect_matrix().unwrap()
        );
        let c = generate(&ctx, 50, 8, 2, 8).unwrap();
        assert_ne!(
            a.table.collect_matrix().unwrap(),
            c.table.collect_matrix().unwrap()
        );
    }

    #[test]
    fn labels_correlate_with_planted_margin() {
        let ctx = EngineContext::new();
        let data = generate(&ctx, 500, 12, 2, 3).unwrap();
        let mut agree = 0usize;
        let mut total = 0usize;
        for r in data.table.table().collect().unwrap() {
            let v = r.to_vector().unwrap();
            let y = v[0];
            let x = v.slice(1, v.len());
            let margin = x.dot(&data.w_true).unwrap();
            if (margin > 0.0) == (y > 0.5) {
                agree += 1;
            }
            total += 1;
        }
        // planted model should predict much better than chance
        assert!(agree as f64 / total as f64 > 0.7, "{agree}/{total}");
    }
}
