//! Synthetic dataset generators — the substitutes for the paper's
//! proprietary-scale inputs (DESIGN.md §3):
//!
//! * [`dense_gen`] — dense featurized classification data standing in for
//!   the 160K-feature ImageNet run (planted logistic model).
//! * [`netflix`] — a Netflix-shaped sparse ratings generator (power-law
//!   user activity, planted low-rank structure) plus the paper's exact
//!   tiling scale-up scheme.
//! * [`text_gen`] — a topic-clustered synthetic corpus for the Fig. A2
//!   nGrams -> tfIdf -> KMeans pipeline.

pub mod dense_gen;
pub mod netflix;
pub mod text_gen;

pub use dense_gen::ClassificationData;
pub use netflix::RatingsData;
