//! Netflix-surrogate ratings generator (+ the paper's tiling scale-up).
//!
//! The real Netflix dataset: 480,189 users x 17,770 movies, ~100M ratings
//! in {1..5}, heavily skewed user activity. We generate a shape-preserving
//! scaled version: power-law ratings-per-user, planted rank-k structure
//! plus noise, values clipped to [1, 5]. The paper scales it up by
//! "repeatedly tiling" — for `t^2`-fold size we tile a t x t grid
//! (machine counts in Fig. 3 are perfect squares: 1, 4, 9, 16, 25), which
//! keeps per-row/column sparsity identical to the original, exactly the
//! property the paper relies on.

use crate::localmatrix::CsrMatrix;
use crate::util::rng::Rng;

/// Scaled Netflix-shaped dataset.
pub struct RatingsData {
    /// users x items ratings (CSR).
    pub ratings: CsrMatrix,
    pub users: usize,
    pub items: usize,
    pub rank: usize,
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct NetflixConfig {
    pub users: usize,
    pub items: usize,
    /// planted latent rank
    pub rank: usize,
    /// mean ratings per user (power-law distributed, capped)
    pub mean_nnz_per_user: usize,
    /// hard cap on ratings per user — matches the XLA artifact's gather
    /// width m (users above the cap are truncated; the generator keeps
    /// the tail below it)
    pub max_nnz_per_user: usize,
    pub noise: f64,
    pub seed: u64,
}

impl Default for NetflixConfig {
    fn default() -> Self {
        // 1/128-ish scale of Netflix, same aspect ratio (27:1). The
        // per-user cap of 25 keeps nnz within the XLA gather width m=128
        // even after the paper's 5x5 tiling (25 * 5 = 125 <= 128).
        NetflixConfig {
            users: 3456,
            items: 128,
            rank: 10,
            mean_nnz_per_user: 12,
            max_nnz_per_user: 25,
            noise: 0.3,
            seed: 17,
        }
    }
}

/// Generate the base (untiled) dataset.
pub fn generate(cfg: &NetflixConfig) -> RatingsData {
    let mut rng = Rng::new(cfg.seed);
    let k = cfg.rank;
    // planted factors ~ N(0, 1/sqrt k) so products land in a ~unit range
    let scale = 1.0 / (k as f64).sqrt();
    let u: Vec<f64> = (0..cfg.users * k).map(|_| rng.normal() * scale).collect();
    let v: Vec<f64> = (0..cfg.items * k).map(|_| rng.normal() * scale).collect();

    let mut triplets = Vec::new();
    for user in 0..cfg.users {
        // power-law activity: most users rate few items, some rate many
        let raw = 1 + rng.powerlaw(cfg.max_nnz_per_user, 0.9);
        let nnz = raw
            .max(cfg.mean_nnz_per_user / 4)
            .min(cfg.max_nnz_per_user)
            .min(cfg.items);
        let items = rng.sample_indices(cfg.items, nnz);
        for item in items {
            let mut dot = 0.0;
            for f in 0..k {
                dot += u[user * k + f] * v[item * k + f];
            }
            // map latent score into the 1..5 star range
            let r = (3.0 + 2.0 * dot + cfg.noise * rng.normal()).clamp(1.0, 5.0);
            triplets.push((user, item, r));
        }
    }
    let ratings = CsrMatrix::from_triplets(cfg.users, cfg.items, triplets)
        .expect("generator produces in-bounds triplets");
    RatingsData {
        ratings,
        users: cfg.users,
        items: cfg.items,
        rank: k,
    }
}

/// The paper's scale-up: tile a t x t grid => t^2-fold data with identical
/// sparsity structure. `times` must be a perfect square (machine counts in
/// Fig. 3 are 1, 4, 9, 16, 25).
pub fn tile(base: &RatingsData, times: usize) -> RatingsData {
    let t = (times as f64).sqrt().round() as usize;
    assert_eq!(t * t, times, "tile factor {times} must be a perfect square");
    if t == 1 {
        return RatingsData {
            ratings: base.ratings.clone(),
            users: base.users,
            items: base.items,
            rank: base.rank,
        };
    }
    let tiled = base.ratings.tile_cols(t).tile_rows(t);
    RatingsData {
        ratings: tiled,
        users: base.users * t,
        items: base.items * t,
        rank: base.rank,
    }
}

/// Bytes of one rating in the simulated memory model (CSR entry: value +
/// column index).
pub fn rating_bytes() -> u64 {
    16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_range() {
        let d = generate(&NetflixConfig {
            users: 200,
            items: 64,
            ..Default::default()
        });
        assert_eq!(d.ratings.rows, 200);
        assert_eq!(d.ratings.cols, 64);
        assert!(d.ratings.nnz() > 200); // at least ~1/user
        for r in 0..200 {
            for (_, v) in d.ratings.row_iter(r) {
                assert!((1.0..=5.0).contains(&v));
            }
        }
    }

    #[test]
    fn per_user_cap_respected() {
        let cfg = NetflixConfig {
            users: 300,
            items: 64,
            max_nnz_per_user: 32,
            ..Default::default()
        };
        let d = generate(&cfg);
        for r in 0..300 {
            assert!(d.ratings.row_nnz(r) <= 32);
            assert!(d.ratings.row_nnz(r) >= 1);
        }
    }

    #[test]
    fn activity_is_skewed() {
        let d = generate(&NetflixConfig {
            users: 1000,
            items: 100,
            ..Default::default()
        });
        let mut counts: Vec<usize> = (0..1000).map(|r| d.ratings.row_nnz(r)).collect();
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let top_decile_mean = counts[..100].iter().sum::<usize>() as f64 / 100.0;
        let bottom_half_mean = counts[500..].iter().sum::<usize>() as f64 / 500.0;
        assert!(
            top_decile_mean > 2.0 * bottom_half_mean,
            "power-law head should out-rate the tail ({top_decile_mean} vs {bottom_half_mean})"
        );
    }

    #[test]
    fn tiling_squares_size_keeps_density() {
        let base = generate(&NetflixConfig {
            users: 100,
            items: 32,
            ..Default::default()
        });
        let t4 = tile(&base, 4);
        assert_eq!(t4.users, 200);
        assert_eq!(t4.items, 64);
        assert_eq!(t4.ratings.nnz(), base.ratings.nnz() * 4);
        // per-user nnz doubles (2 col-tiles) — same per-row density/col
        assert_eq!(t4.ratings.row_nnz(0), base.ratings.row_nnz(0) * 2);
        let t1 = tile(&base, 1);
        assert_eq!(t1.ratings.nnz(), base.ratings.nnz());
    }

    #[test]
    #[should_panic(expected = "perfect square")]
    fn tile_rejects_non_square() {
        let base = generate(&NetflixConfig {
            users: 10,
            items: 8,
            ..Default::default()
        });
        let _ = tile(&base, 8);
    }

    #[test]
    fn deterministic() {
        let cfg = NetflixConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.ratings, b.ratings);
    }
}
