//! Minimal JSON parser + writer (offline replacement for `serde_json`).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used to read `artifacts/manifest.json` written
//! by the AOT path and to emit machine-readable bench reports.

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{Error, Result};

/// A parsed JSON value. Object keys are kept in sorted order (BTreeMap) so
/// serialization is deterministic, which keeps bench-report diffs clean.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(Error::Parse(format!(
                "trailing garbage at byte {} of JSON input",
                p.pos
            )));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Parse(format!("expected object, got {self}"))),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Parse(format!("expected array, got {self}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Parse(format!("expected string, got {self}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(Error::Parse(format!("expected number, got {self}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            return Err(Error::Parse(format!("expected non-negative integer, got {f}")));
        }
        Ok(f as usize)
    }

    /// Object field lookup with a contextual error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Parse(format!("missing key '{key}'")))
    }

    // -- builders ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

impl fmt::Display for Json {
    /// Compact serialization (no whitespace), valid JSON.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| Error::Parse("unexpected end of JSON".into()))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            return Err(Error::Parse(format!(
                "expected '{}' at byte {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            )));
        }
        Ok(())
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        for &b in word.as_bytes() {
            self.expect(b)?;
        }
        Ok(val)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err(Error::Parse("unexpected end of JSON".into())),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => {
                    return Err(Error::Parse(format!(
                        "expected ',' or '}}' in object, got '{}'",
                        c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => {
                    return Err(Error::Parse(format!(
                        "expected ',' or ']' in array, got '{}'",
                        c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char)
                                    .to_digit(16)
                                    .ok_or_else(|| Error::Parse("bad \\u escape".into()))?;
                        }
                        // Surrogate pairs: JSON encodes astral chars as two
                        // \u escapes; combine when we see a high surrogate.
                        let c = if (0xD800..0xDC00).contains(&code) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let h = self.bump()?;
                                low = low * 16
                                    + (h as char)
                                        .to_digit(16)
                                        .ok_or_else(|| Error::Parse("bad \\u escape".into()))?;
                            }
                            0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                        } else {
                            code
                        };
                        s.push(
                            char::from_u32(c)
                                .ok_or_else(|| Error::Parse("invalid unicode escape".into()))?,
                        );
                    }
                    c => {
                        return Err(Error::Parse(format!(
                            "bad escape '\\{}'",
                            c as char
                        )))
                    }
                },
                _ => {
                    // multibyte UTF-8 passthrough: back up and take the char
                    self.pos -= 1;
                    let rest = &self.src[self.pos..];
                    let st = std::str::from_utf8(rest)
                        .map_err(|_| Error::Parse("invalid utf-8 in string".into()))?;
                    // mli-lint: allow(E001) rest is non-empty (bump saw a byte)
                    let c = st.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        // mli-lint: allow(E001) the matched bytes are ASCII, always valid UTF-8
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Parse(format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "d"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "d");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(*arr[2].get("b").unwrap(), Json::Null);
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn reads_real_manifest_shape() {
        let src = r#"{"format":"hlo-text","artifacts":[
            {"entry":"local_sgd_epoch","variant":"small",
             "file":"local_sgd_epoch__small.hlo.txt",
             "inputs":[{"shape":[256,64],"dtype":"float32"}],
             "outputs":[{"shape":[64],"dtype":"float32"}]}]}"#;
        let v = Json::parse(src).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("entry").unwrap().as_str().unwrap(), "local_sgd_epoch");
        let dims = a.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(dims, vec![256, 64]);
    }

    #[test]
    fn usize_accessor_validates() {
        assert!(Json::parse("3.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-2").unwrap().as_usize().is_err());
        assert_eq!(Json::parse("7").unwrap().as_usize().unwrap(), 7);
    }
}
