//! Tiny CLI argument parser (offline replacement for `clap`).
//!
//! Grammar: `mli <subcommand> [--key value]... [--flag]... [positional]...`
//! Typed accessors with defaults; unknown-flag detection; auto-generated
//! usage text from registered option descriptions.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. The first non-dashed token becomes the
    /// subcommand; `--key value` pairs become options; a trailing `--key`
    /// or `--key` followed by another `--...` is a boolean flag.
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    /// Comma-separated list of integers, e.g. `--machines 1,2,4,8`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse().map_err(|_| {
                        Error::Config(format!("--{name} expects ints, got '{s}'"))
                    })
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(&sv(&[
            "train", "pos1", "--algo", "logreg", "--iters", "10", "--verbose",
        ]));
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("algo"), Some("logreg"));
        assert_eq!(a.get_usize("iters", 0).unwrap(), 10);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn eq_form_and_lists() {
        let a = Args::parse(&sv(&["bench", "--machines=1,4,9", "--lam=0.01"]));
        assert_eq!(a.get_usize_list("machines", &[]).unwrap(), vec![1, 4, 9]);
        assert_eq!(a.get_f64("lam", 0.0).unwrap(), 0.01);
    }

    #[test]
    fn defaults_and_errors() {
        let a = Args::parse(&sv(&["x", "--n", "abc"]));
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&sv(&["run", "--fast"]));
        assert!(a.has_flag("fast"));
        assert!(a.get("fast").is_none());
    }
}
