//! Stopwatch + timing statistics helpers used by the bench harness and
//! the engine's per-task accounting.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Measure `f`, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let r = f();
    (r, sw.elapsed_secs())
}

/// Run `f` `warmup` times untimed then `iters` times timed; returns the
/// per-iteration timings in seconds. The bench harness's core primitive
/// (criterion surrogate).
pub fn sample<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    (0..iters)
        .map(|_| {
            let sw = Stopwatch::start();
            std::hint::black_box(f());
            sw.elapsed_secs()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_secs();
        let b = sw.elapsed_secs();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, s) = time_it(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn sample_counts() {
        let mut calls = 0;
        let t = sample(2, 5, || calls += 1);
        assert_eq!(t.len(), 5);
        assert_eq!(calls, 7);
    }
}
