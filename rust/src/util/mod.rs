//! Small self-contained utilities that replace unavailable crates in this
//! offline sandbox (DESIGN.md §3): a JSON parser (`serde_json` surrogate),
//! a seedable PRNG (`rand` surrogate), a CLI argument parser (`clap`
//! surrogate), a stopwatch, and a property-testing helper (`proptest`
//! surrogate used by `rust/tests/proptests.rs`).

pub mod cli;
pub mod json;
pub mod lockdep;
pub mod prop;
pub mod rng;
pub mod timer;

/// Lock a mutex, recovering from poisoning. Poisoning only means "some
/// task panicked while holding the guard"; every structure we guard
/// (deques, completion counts, metrics, caches) is valid at every point a
/// panic can unwind through, so the data is safe to reuse and recovery is
/// the correct policy — the panic itself is reported through the owning
/// layer's typed error (e.g. [`crate::exec::ExecError`]), not via lock
/// poisoning.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts; fine for metric summaries).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Format a byte count in human units (used by metric reports).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_stddev() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
