//! Property-testing helper (offline replacement for `proptest`).
//!
//! `check` runs a property over `n` randomized cases drawn from a seeded
//! [`Rng`]; on failure it re-runs the failing seed with shrunk "size"
//! parameters to report the smallest size at which the property fails.
//! Used by `rust/tests/proptests.rs` for engine/mltable/localmatrix
//! invariants.

use super::rng::Rng;

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropFailure {
    pub case: usize,
    pub seed: u64,
    pub size: usize,
    pub message: String,
}

impl std::fmt::Display for PropFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (seed={}, size={}): {}",
            self.case, self.seed, self.size, self.message
        )
    }
}

/// Run `prop(rng, size)` for `cases` randomized cases with sizes cycling
/// through 1..=max_size. `prop` returns Err(message) on violation. On
/// failure, retries smaller sizes with the same seed to shrink before
/// panicking with a reproducible report.
pub fn check<F>(name: &str, seed: u64, cases: usize, max_size: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut meta = Rng::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let size = 1 + (case % max_size);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // shrink: find the smallest size (same seed) that still fails
            let mut smallest = (size, msg.clone());
            for s in 1..size {
                let mut r2 = Rng::new(case_seed);
                if let Err(m2) = prop(&mut r2, s) {
                    smallest = (s, m2);
                    break;
                }
            }
            panic!(
                "{}",
                PropFailure {
                    case,
                    seed: case_seed,
                    size: smallest.0,
                    message: format!("[{name}] {}", smallest.1),
                }
            );
        }
    }
}

/// Assert-style helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality for property bodies.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0_f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add_commutes", 1, 50, 10, |rng, _| {
            let (a, b) = (rng.f64(), rng.f64());
            close(a + b, b + a, 1e-12)
        });
    }

    #[test]
    #[should_panic(expected = "always_fails")]
    fn failing_property_reports() {
        check("always_fails", 2, 10, 5, |_, _| ensure(false, "always_fails"));
    }

    #[test]
    fn close_scales_tolerance() {
        assert!(close(1e9, 1e9 + 1.0, 1e-6).is_ok());
        assert!(close(1.0, 1.1, 1e-6).is_err());
    }
}
