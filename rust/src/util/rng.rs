//! Seedable PRNG (offline replacement for the `rand` crate).
//!
//! SplitMix64 for seeding + xoshiro256** for the stream - the standard
//! combination with good statistical quality and sub-ns generation. All
//! data generators in [`crate::data`] take explicit seeds so every
//! experiment in EXPERIMENTS.md is bit-reproducible.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into 256 bits of state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Derive an independent stream (for per-partition generators).
    pub fn split(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Lemire's multiply-shift rejection-free-ish; bias negligible for
        // n << 2^64 which always holds here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller (uses one cached value).
    pub fn normal(&mut self) -> f64 {
        // Marsaglia polar method avoids trig.
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Sample from a zipf-like power law over [0, n): item i has weight
    /// (i+1)^(-alpha). Used for Netflix-surrogate user activity skew.
    pub fn powerlaw(&mut self, n: usize, alpha: f64) -> usize {
        // inverse-CDF on a precomputable grid is overkill; rejection via
        // the continuous pareto approximation is fine for generators.
        loop {
            let x = (1.0 - self.f64()).powf(-1.0 / alpha) - 1.0;
            let i = x as usize;
            if i < n {
                return i;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), unsorted.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher-Yates over an index map for small k, reservoir
        // otherwise; n is at most a few hundred thousand here.
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_bounds_and_moments() {
        let mut r = Rng::new(7);
        let n = 20000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "uniform mean {mean}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "normal mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "normal var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for &(n, k) in &[(100, 5), (100, 90), (10, 10)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn powerlaw_skew() {
        let mut r = Rng::new(13);
        let mut counts = [0usize; 100];
        for _ in 0..20000 {
            counts[r.powerlaw(100, 1.2)] += 1;
        }
        // head should dominate tail
        assert!(counts[0] > counts[50].max(1) * 5);
    }

    #[test]
    fn split_streams_differ() {
        let mut r = Rng::new(1);
        let mut a = r.split(0);
        let mut b = r.split(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
