//! Runtime lock-order cycle detection (debug builds only).
//!
//! [`TrackedMutex`] is a drop-in replacement for `std::sync::Mutex` used by
//! the concurrency-heavy subsystems (the `exec` pool and queues). Under
//! `debug_assertions` every acquisition is recorded in a process-wide
//! lock-order graph: an edge `a -> b` means "some thread acquired `b`
//! while holding `a`". At acquire time the tracker checks whether the new
//! edge would close a cycle — the static witness of a potential deadlock —
//! and panics with *both* acquisition chains (the recorded one and the
//! current thread's) so the inversion is diagnosable from the panic
//! message alone. Re-locking a mutex already held by the current thread
//! (guaranteed self-deadlock with std's non-reentrant mutex) panics too.
//!
//! In release builds the tracker compiles away entirely: `TrackedMutex` is
//! a newtype over `Mutex` and `lock()` is exactly
//! [`super::lock_unpoisoned`] (poison recovery, no bookkeeping).
//!
//! This is the dynamic half of the repo's concurrency checking; the static
//! half is `mli lint` (rules C001/C002 — see `docs/lint.md`). The
//! detector is exercised for free by the exec/fault integration suites,
//! which drive every pool lock through real contention.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// A named mutex whose acquisitions are order-checked in debug builds.
///
/// The name is a static label for panic messages ("exec.park", ...); it
/// does not need to be unique — cycle detection keys on the instance.
pub struct TrackedMutex<T> {
    inner: Mutex<T>,
    name: &'static str,
    #[cfg(debug_assertions)]
    id: u64,
}

/// Guard returned by [`TrackedMutex::lock`]. Dropping it releases the
/// mutex and (in debug builds) pops it from the thread's held-lock stack.
pub struct TrackedGuard<'a, T> {
    // Option so condvar waits can move the inner guard out without
    // tripping this type's Drop bookkeeping; None only transiently.
    guard: Option<MutexGuard<'a, T>>,
    #[cfg(debug_assertions)]
    id: u64,
}

impl<T> TrackedMutex<T> {
    pub fn new(name: &'static str, value: T) -> TrackedMutex<T> {
        TrackedMutex {
            inner: Mutex::new(value),
            name,
            #[cfg(debug_assertions)]
            id: dep::new_id(),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Acquire, recovering from poisoning (same policy as
    /// [`super::lock_unpoisoned`]). Debug builds record the acquisition in
    /// the global lock-order graph and panic if it closes a cycle.
    pub fn lock(&self) -> TrackedGuard<'_, T> {
        #[cfg(debug_assertions)]
        dep::acquire(self.id, self.name);
        let guard = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        TrackedGuard {
            guard: Some(guard),
            #[cfg(debug_assertions)]
            id: self.id,
        }
    }

    /// Condvar wait. The mutex is released for the duration of the wait
    /// and re-acquired (with a fresh order check) on wakeup, mirroring
    /// what `Condvar::wait` does to the underlying mutex.
    pub fn wait<'a>(&'a self, cv: &Condvar, mut g: TrackedGuard<'a, T>) -> TrackedGuard<'a, T> {
        let inner = g.take_inner();
        #[cfg(debug_assertions)]
        dep::release(self.id);
        drop(g);
        let inner = cv.wait(inner).unwrap_or_else(|e| e.into_inner());
        #[cfg(debug_assertions)]
        dep::acquire(self.id, self.name);
        TrackedGuard {
            guard: Some(inner),
            #[cfg(debug_assertions)]
            id: self.id,
        }
    }

    /// Condvar wait with a timeout; the bool is "timed out".
    pub fn wait_timeout<'a>(
        &'a self,
        cv: &Condvar,
        mut g: TrackedGuard<'a, T>,
        dur: Duration,
    ) -> (TrackedGuard<'a, T>, bool) {
        let inner = g.take_inner();
        #[cfg(debug_assertions)]
        dep::release(self.id);
        drop(g);
        let (inner, timeout) = cv
            .wait_timeout(inner, dur)
            .unwrap_or_else(|e| e.into_inner());
        #[cfg(debug_assertions)]
        dep::acquire(self.id, self.name);
        (
            TrackedGuard {
                guard: Some(inner),
                #[cfg(debug_assertions)]
                id: self.id,
            },
            timeout.timed_out(),
        )
    }
}

impl<T> Drop for TrackedMutex<T> {
    fn drop(&mut self) {
        // Purge this instance from the graph so a recycled address (or a
        // later test in the same process) can never inherit stale edges.
        #[cfg(debug_assertions)]
        dep::forget_lock(self.id);
    }
}

impl<'a, T> TrackedGuard<'a, T> {
    fn take_inner(&mut self) -> MutexGuard<'a, T> {
        match self.guard.take() {
            Some(g) => g,
            // Unreachable by construction: `guard` is None only inside the
            // wait methods, which consume `self`.
            None => panic!("lockdep: guard already consumed"),
        }
    }
}

impl<T> std::ops::Deref for TrackedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match &self.guard {
            Some(g) => g,
            None => panic!("lockdep: guard already consumed"),
        }
    }
}

impl<T> std::ops::DerefMut for TrackedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        match &mut self.guard {
            Some(g) => g,
            None => panic!("lockdep: guard already consumed"),
        }
    }
}

impl<T> Drop for TrackedGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        if self.guard.is_some() {
            dep::release(self.id);
        }
    }
}

/// The debug-only acquisition registry: a process-wide lock-order graph
/// plus a per-thread stack of currently held tracked locks.
#[cfg(debug_assertions)]
mod dep {
    use std::cell::RefCell;
    use std::collections::BTreeMap;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;

    static NEXT_ID: AtomicU64 = AtomicU64::new(1);

    /// `edges[a][b]` = description of the first time `b` was acquired
    /// while `a` was held.
    struct Graph {
        edges: BTreeMap<u64, BTreeMap<u64, String>>,
        names: BTreeMap<u64, &'static str>,
    }

    static GRAPH: Mutex<Option<Graph>> = Mutex::new(None);

    thread_local! {
        static HELD: RefCell<Vec<(u64, &'static str)>> = const { RefCell::new(Vec::new()) };
    }

    pub(super) fn new_id() -> u64 {
        NEXT_ID.fetch_add(1, Ordering::Relaxed)
    }

    fn with_graph<R>(f: impl FnOnce(&mut Graph) -> R) -> R {
        let mut g = GRAPH.lock().unwrap_or_else(|e| e.into_inner());
        let g = g.get_or_insert_with(|| Graph {
            edges: BTreeMap::new(),
            names: BTreeMap::new(),
        });
        f(g)
    }

    /// Shortest-path search (BFS) from `from` to `to` over recorded edges.
    /// Returns the edge list of the path when one exists.
    fn path(g: &Graph, from: u64, to: u64) -> Option<Vec<(u64, u64)>> {
        let mut prev: BTreeMap<u64, u64> = BTreeMap::new();
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(from);
        while let Some(n) = queue.pop_front() {
            if n == to {
                let mut chain = Vec::new();
                let mut cur = to;
                while cur != from {
                    let p = prev[&cur];
                    chain.push((p, cur));
                    cur = p;
                }
                chain.reverse();
                return Some(chain);
            }
            if let Some(succ) = g.edges.get(&n) {
                for &m in succ.keys() {
                    if m != from && !prev.contains_key(&m) {
                        prev.insert(m, n);
                        queue.push_back(m);
                    }
                }
            }
        }
        None
    }

    /// Record that the current thread is acquiring lock `id`; panics on a
    /// same-thread relock or when the new held->id edge closes a cycle.
    pub(super) fn acquire(id: u64, name: &'static str) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if held.iter().any(|&(i, _)| i == id) {
                panic!(
                    "lock-order: relocking '{name}' already held by this thread \
                     (self-deadlock); held: {:?}",
                    held.iter().map(|&(_, n)| n).collect::<Vec<_>>()
                );
            }
            if !held.is_empty() {
                let thread = std::thread::current();
                let tname = thread.name().unwrap_or("<unnamed>").to_string();
                with_graph(|g| {
                    g.names.insert(id, name);
                    for &(h_id, h_name) in held.iter() {
                        g.names.insert(h_id, h_name);
                        if g.edges.get(&h_id).is_some_and(|m| m.contains_key(&id)) {
                            continue;
                        }
                        // would h_id -> id close a cycle (a path id -> h_id)?
                        if let Some(chain) = path(g, id, h_id) {
                            let mut msg = format!(
                                "lock-order cycle detected: thread '{tname}' is acquiring \
                                 '{name}' while holding '{h_name}', but the reverse order \
                                 is already on record:\n"
                            );
                            for (a, b) in &chain {
                                let how = g
                                    .edges
                                    .get(a)
                                    .and_then(|m| m.get(b))
                                    .map(String::as_str)
                                    .unwrap_or("<edge>");
                                msg.push_str(&format!("  recorded: {how}\n"));
                            }
                            msg.push_str(&format!(
                                "  new:      '{name}' acquired while holding '{h_name}' \
                                 (thread '{tname}', held stack: {:?})",
                                held.iter().map(|&(_, n)| n).collect::<Vec<_>>()
                            ));
                            panic!("{msg}");
                        }
                        g.edges.entry(h_id).or_default().insert(
                            id,
                            format!(
                                "'{name}' acquired while holding '{h_name}' \
                                 (thread '{tname}')"
                            ),
                        );
                    }
                });
            }
            held.push((id, name));
        });
    }

    /// The current thread released lock `id`.
    pub(super) fn release(id: u64) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(pos) = held.iter().rposition(|&(i, _)| i == id) {
                held.remove(pos);
            }
        });
    }

    /// A tracked mutex was dropped: remove its node and every edge
    /// touching it so later allocations can't inherit stale ordering.
    pub(super) fn forget_lock(id: u64) {
        with_graph(|g| {
            g.edges.remove(&id);
            g.names.remove(&id);
            for m in g.edges.values_mut() {
                m.remove(&id);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_and_mutate() {
        let m = TrackedMutex::new("t.basic", 1u64);
        *m.lock() += 41;
        assert_eq!(*m.lock(), 42);
    }

    #[test]
    fn consistent_order_is_fine_across_threads() {
        // a -> b taken in the same order from two threads: no cycle.
        let a = Arc::new(TrackedMutex::new("t.order.a", ()));
        let b = Arc::new(TrackedMutex::new("t.order.b", ()));
        let (a2, b2) = (a.clone(), b.clone());
        let t = std::thread::spawn(move || {
            let ga = a2.lock();
            let gb = b2.lock();
            drop(gb);
            drop(ga);
        });
        t.join().unwrap();
        let ga = a.lock();
        let gb = b.lock();
        drop(gb);
        drop(ga);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "lockdep is debug-only")]
    #[should_panic(expected = "lock-order cycle detected")]
    fn inverted_order_panics_with_both_chains() {
        // Deliberate inversion: a -> b on the first pass, then b -> a.
        // Deterministic on one thread — the graph records a -> b, and the
        // second pass's b-held acquire of a closes the cycle.
        let a = TrackedMutex::new("t.cycle.a", ());
        let b = TrackedMutex::new("t.cycle.b", ());
        {
            let _ga = a.lock();
            let _gb = b.lock();
        }
        let _gb = b.lock();
        let _ga = a.lock(); // panics here
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "lockdep is debug-only")]
    #[should_panic(expected = "self-deadlock")]
    fn relock_same_mutex_panics() {
        let m = TrackedMutex::new("t.relock", ());
        let _g1 = m.lock();
        let _g2 = m.lock();
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "lockdep is debug-only")]
    #[should_panic(expected = "lock-order cycle detected")]
    fn transitive_cycle_detected() {
        // a -> b and b -> c recorded; then c-held acquire of a must close
        // the 3-node cycle through the recorded chain.
        let a = TrackedMutex::new("t.tri.a", ());
        let b = TrackedMutex::new("t.tri.b", ());
        let c = TrackedMutex::new("t.tri.c", ());
        {
            let _ga = a.lock();
            let _gb = b.lock();
        }
        {
            let _gb = b.lock();
            let _gc = c.lock();
        }
        let _gc = c.lock();
        let _ga = a.lock(); // panics here
    }

    #[test]
    fn drop_purges_edges_so_no_ghost_cycles() {
        // First pair records a -> b, then both mutexes are dropped. A
        // fresh pair acquired in the reverse order must NOT trip on the
        // dead pair's edge.
        {
            let a = TrackedMutex::new("t.ghost.a", ());
            let b = TrackedMutex::new("t.ghost.b", ());
            let _ga = a.lock();
            let _gb = b.lock();
        }
        let a = TrackedMutex::new("t.ghost.a2", ());
        let b = TrackedMutex::new("t.ghost.b2", ());
        let _gb = b.lock();
        let _ga = a.lock();
    }

    #[test]
    fn condvar_wait_timeout_releases_and_reacquires() {
        let m = TrackedMutex::new("t.cv", 0u32);
        let cv = Condvar::new();
        let g = m.lock();
        let (g, timed_out) = m.wait_timeout(&cv, g, Duration::from_millis(5));
        assert!(timed_out);
        drop(g);
        // the lock must be fully released/reusable afterwards
        *m.lock() += 1;
        assert_eq!(*m.lock(), 1);
    }

    #[test]
    fn condvar_wait_wakes_on_notify() {
        let pair = Arc::new((TrackedMutex::new("t.cv.notify", false), Condvar::new()));
        let pair2 = pair.clone();
        let t = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            *m.lock() = true;
            cv.notify_all();
        });
        let (m, cv) = &*pair;
        let mut g = m.lock();
        while !*g {
            let (ng, _) = m.wait_timeout(cv, g, Duration::from_millis(50));
            g = ng;
        }
        assert!(*g);
        drop(g);
        t.join().unwrap();
    }
}
