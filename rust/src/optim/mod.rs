//! Optimization (paper §III-C): "we treat optimization as a first class
//! citizen in our API, and the system is built to support new optimizers."
//!
//! The split mirrors Fig. A4: an *optimizer* ([`sgd::SGD`], [`gd::GD`])
//! owns the distributed loop (rounds, parameter averaging, communication
//! charging), while an *algorithm* supplies the partition-local compute as
//! a [`LocalStepProvider`] — logistic regression plugs in an XLA-backed
//! provider, linear regression / SVM plug in different gradients, which is
//! exactly the paper's "simply changing the expression of the gradient
//! function" claim.

pub mod gd;
pub mod prox;
pub mod sgd;

pub use gd::{GdParams, GD};
pub use prox::Reg;
pub use sgd::{SgdParams, SgdResult, SGD};

use crate::error::Result;

/// Partition-local compute for a distributed first-order optimizer.
///
/// Implementations hold their data already partitioned (and, for the XLA
/// path, already padded into `Tensor`s) so the per-round hot path does no
/// re-marshalling.
///
/// `Send + Sync` is a supertrait so optimizers can fan partition steps out
/// across the cluster's `exec` thread pool; per-partition calls must not
/// share unsynchronized mutable state (they only read `w` and their own
/// partition's data).
pub trait LocalStepProvider: Send + Sync {
    /// Model dimension (padded, for XLA-backed providers).
    fn dim(&self) -> usize;

    /// Number of data partitions.
    fn num_partitions(&self) -> usize;

    /// Weight of partition `p` in the parameter average (its real row
    /// count; padding rows contribute nothing).
    fn partition_weight(&self, p: usize) -> f64;

    /// One local SGD epoch over partition `p` starting from `w`
    /// (Fig. A4 `localSGD`). Returns the locally-updated weights.
    fn local_epoch(&self, p: usize, w: &[f32], lr: f32) -> Result<Vec<f32>>;

    /// Full-batch gradient + loss contribution of partition `p` at `w`
    /// (for GD and for loss curves). Returns (grad, loss, examples).
    fn local_grad(&self, p: usize, w: &[f32]) -> Result<(Vec<f32>, f64, f64)>;

    /// Serialized model size in bytes (what one allreduce moves).
    fn model_bytes(&self) -> u64 {
        (self.dim() * 4) as u64
    }
}

/// Weighted average of per-partition weight vectors — the master-side
/// combine of Fig. A4 (`.reduce(_ plus _) over data.partitions.length`,
/// generalized to weight by partition size for unbalanced partitions).
pub fn average_weights(locals: &[(Vec<f32>, f64)]) -> Vec<f32> {
    assert!(!locals.is_empty());
    let d = locals[0].0.len();
    let total: f64 = locals.iter().map(|(_, w)| w).sum();
    let mut out = vec![0.0f32; d];
    for (vec, wt) in locals {
        let f = (wt / total) as f32;
        for (o, &x) in out.iter_mut().zip(vec) {
            *o += f * x;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_weights_weighted() {
        let a = (vec![1.0f32, 0.0], 1.0);
        let b = (vec![0.0f32, 2.0], 3.0);
        let avg = average_weights(&[a, b]);
        assert!((avg[0] - 0.25).abs() < 1e-6);
        assert!((avg[1] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn average_single() {
        let avg = average_weights(&[(vec![5.0f32], 2.0)]);
        assert_eq!(avg, vec![5.0]);
    }
}
