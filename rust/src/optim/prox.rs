//! Regularization: L2 shrinkage and proximal operators for L1 / elastic
//! net — the paper's "(L1, L2, elastic net)-regularized variants ... by
//! adding a proximal operator in the case of L1-regularization" (§IV).

/// Regularization spec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Reg {
    None,
    /// L2 ridge with strength lambda (applied as multiplicative shrinkage
    /// inside the gradient step).
    L2(f64),
    /// L1 lasso with strength lambda (applied as a prox / soft-threshold
    /// after each averaging round).
    L1(f64),
    /// Elastic net: (l1, l2).
    Elastic(f64, f64),
}

impl Reg {
    /// The L2 component (0 if none).
    pub fn l2(&self) -> f64 {
        match self {
            Reg::L2(l) => *l,
            Reg::Elastic(_, l2) => *l2,
            _ => 0.0,
        }
    }

    /// The L1 component (0 if none).
    pub fn l1(&self) -> f64 {
        match self {
            Reg::L1(l) => *l,
            Reg::Elastic(l1, _) => *l1,
            _ => 0.0,
        }
    }

    /// Apply the proximal step for the non-smooth (L1) part and the
    /// shrinkage for the L2 part, at step size `eta`, in place.
    pub fn apply_prox(&self, w: &mut [f32], eta: f64) {
        let l1 = self.l1();
        let l2 = self.l2();
        if l1 == 0.0 && l2 == 0.0 {
            return;
        }
        let shrink = (1.0 / (1.0 + eta * l2)) as f32;
        let thresh = (eta * l1) as f32;
        for x in w.iter_mut() {
            let mut v = *x * shrink;
            if thresh > 0.0 {
                v = soft_threshold(v, thresh);
            }
            *x = v;
        }
    }

    /// Regularization term's contribution to the objective at `w`.
    pub fn penalty(&self, w: &[f32]) -> f64 {
        let l1: f64 = w.iter().map(|&x| x.abs() as f64).sum();
        let l2: f64 = w.iter().map(|&x| (x as f64) * (x as f64)).sum();
        self.l1() * l1 + 0.5 * self.l2() * l2
    }
}

/// Soft-thresholding operator: prox of `t * |.|`.
pub fn soft_threshold(x: f32, t: f32) -> f32 {
    if x > t {
        x - t
    } else if x < -t {
        x + t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
    }

    #[test]
    fn l1_prox_sparsifies() {
        let mut w = vec![0.05f32, -0.5, 2.0];
        Reg::L1(1.0).apply_prox(&mut w, 0.1);
        assert_eq!(w[0], 0.0);
        assert!((w[1] + 0.4).abs() < 1e-6);
        assert!((w[2] - 1.9).abs() < 1e-6);
    }

    #[test]
    fn l2_shrinks_multiplicatively() {
        let mut w = vec![1.0f32, -2.0];
        Reg::L2(1.0).apply_prox(&mut w, 1.0);
        assert!((w[0] - 0.5).abs() < 1e-6);
        assert!((w[1] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn elastic_combines() {
        let mut w = vec![1.0f32];
        Reg::Elastic(0.1, 1.0).apply_prox(&mut w, 1.0);
        // first shrink to 0.5, then soft-threshold by 0.1 -> 0.4
        assert!((w[0] - 0.4).abs() < 1e-6);
        assert_eq!(Reg::None.l1(), 0.0);
        assert!(Reg::Elastic(0.1, 1.0).penalty(&[1.0]) > 0.0);
    }

    #[test]
    fn none_is_identity() {
        let mut w = vec![1.5f32, -2.5];
        let orig = w.clone();
        Reg::None.apply_prox(&mut w, 0.5);
        assert_eq!(w, orig);
        assert_eq!(Reg::None.penalty(&w), 0.0);
    }
}
