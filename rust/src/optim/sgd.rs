//! Distributed SGD via local epochs + parameter averaging — the paper's
//! `StochasticGradientDescent` (Fig. A4), which "approximates the
//! algorithm used in Vowpal Wabbit: run SGD locally on each partition
//! before averaging parameters globally."
//!
//! The same optimizer serves both MLI (star gather/broadcast) and the VW
//! baseline (AllReduce tree): the only differences are the topology
//! charged to the simulated cluster and the machine compute factor —
//! precisely the delta the paper identifies between the two systems.

use super::{average_weights, LocalStepProvider, Reg};
use crate::cluster::{CommTopology, SimCluster};
use crate::error::Result;
use crate::exec::TaskSet;

/// SGD hyper-parameters (Fig. A4 `StochasticGradientDescentParameters`).
#[derive(Debug, Clone)]
pub struct SgdParams {
    pub learning_rate: f64,
    pub iters: usize,
    /// lr decay: eta_t = learning_rate / (1 + decay * t).
    pub decay: f64,
    pub reg: Reg,
    pub topology: CommTopology,
    /// Record the loss after each round (extra untimed pass, like the
    /// paper which excludes error computation from timing).
    pub track_loss: bool,
    /// Evaluate the loss every N rounds when `track_loss` (1 = every
    /// round; long e2e runs use sparser logging).
    pub loss_every: usize,
}

impl Default for SgdParams {
    fn default() -> Self {
        SgdParams {
            learning_rate: 0.05,
            iters: 10,
            decay: 0.0,
            reg: Reg::None,
            topology: CommTopology::StarGatherBroadcast,
            track_loss: false,
            loss_every: 1,
        }
    }
}

/// Output of a distributed SGD run.
#[derive(Debug, Clone)]
pub struct SgdResult {
    pub weights: Vec<f32>,
    /// Loss after each round (empty unless `track_loss`).
    pub loss_history: Vec<f64>,
    /// Simulated walltime attributable to this run.
    pub sim_seconds: f64,
}

/// The optimizer object (paper: `object StochasticGradientDescent extends
/// MLOpt`).
pub struct SGD;

impl SGD {
    /// Run distributed SGD. The provider owns the partitioned data; the
    /// cluster is charged measured compute + modelled communication.
    ///
    /// When the cluster has an executor attached
    /// ([`SimCluster::with_executor`]), every round's local epochs run in
    /// parallel on the pool — one task per partition, results merged in
    /// partition index order, so the trained weights are bitwise-identical
    /// to the serial path for any thread count.
    pub fn run(
        provider: &dyn LocalStepProvider,
        cluster: &SimCluster,
        params: &SgdParams,
    ) -> Result<SgdResult> {
        let d = provider.dim();
        let parts = provider.num_partitions();
        let pool = cluster.pool();
        let mut w = vec![0.0f32; d];
        let mut loss_history = Vec::new();
        let t0 = cluster.total_sim_seconds();

        // initial model broadcast (small: zeros, but the real systems
        // ship it); routed through the network fault layer so a lossy or
        // partitioned round 0 retries / waits / fails typed
        cluster.begin_round();
        let sent = cluster.net_broadcast(params.topology, provider.model_bytes());
        cluster.end_round();
        sent?;

        let tracer = cluster.tracer();
        for it in 0..params.iters {
            let eta = params.learning_rate / (1.0 + params.decay * it as f64);
            let round_t0 = tracer.start();
            cluster.begin_round();
            let stage = TaskSet::new(format!("sgd-epoch-{it}"), parts);
            // try_run: a panicking epoch task fails this training run with
            // a typed error instead of unwinding through the round loop.
            // Placement is failure-aware: `assign_machine` falls back to
            // the next alive machine (typed error when none is).
            let results = match (pool.as_deref(), cluster.speculation()) {
                (Some(pl), Some(k)) => {
                    stage.try_run_speculative(Some(pl), k, |p, attempt| {
                        if attempt == 0 {
                            let machine = cluster.assign_machine(p)?;
                            cluster.run_task(machine, || {
                                provider.local_epoch(p, &w, eta as f32)
                            })
                        } else {
                            // backup copy: same math, but never charged to
                            // the sim clock — the analytic speculation model
                            // in `end_round` accounts for backup cost, and
                            // double-charging would skew the ledger
                            provider.local_epoch(p, &w, eta as f32)
                        }
                    })?
                }
                (pl, _) => stage.try_run(pl, |p| {
                    let machine = cluster.assign_machine(p)?;
                    cluster.run_task(machine, || provider.local_epoch(p, &w, eta as f32))
                })?,
            };
            let merge_t0 = tracer.start();
            let mut locals: Vec<(Vec<f32>, f64)> = Vec::with_capacity(parts);
            for (p, lw) in results.into_iter().enumerate() {
                locals.push((lw?, provider.partition_weight(p)));
            }
            w = average_weights(&locals);
            params.reg.apply_prox(&mut w, eta);
            if let Some(t0) = merge_t0 {
                tracer.span(format!("sgd-merge-{it}"), "optim", 0, t0, &[]);
            }
            // model merge travels the fault-aware path: the round is
            // closed before a network failure propagates, so the ledger
            // never wedges in an open round
            let sent = cluster.net_allreduce(params.topology, provider.model_bytes());
            cluster.end_round();
            sent?;
            if let Some(t0) = round_t0 {
                tracer.span(format!("sgd-round-{it}"), "optim", 0, t0, &[]);
            }

            if params.track_loss && it % params.loss_every.max(1) == 0 {
                loss_history.push(Self::loss(provider, &w)?);
            }
        }

        Ok(SgdResult {
            weights: w,
            loss_history,
            sim_seconds: cluster.total_sim_seconds() - t0,
        })
    }

    /// Untimed full-data loss at `w` (mean per example + reg penalty).
    pub fn loss(provider: &dyn LocalStepProvider, w: &[f32]) -> Result<f64> {
        let mut total = 0.0;
        let mut examples = 0.0;
        for p in 0..provider.num_partitions() {
            let (_, l, n) = provider.local_grad(p, w)?;
            total += l;
            examples += n;
        }
        Ok(total / examples.max(1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Quadratic toy problem: minimize 0.5*||w - target||^2 per partition
    /// (closed form lets us verify convergence exactly).
    struct Quadratic {
        targets: Vec<Vec<f32>>, // per-partition optimum
        n_per_part: f64,
    }

    impl LocalStepProvider for Quadratic {
        fn dim(&self) -> usize {
            self.targets[0].len()
        }
        fn num_partitions(&self) -> usize {
            self.targets.len()
        }
        fn partition_weight(&self, _p: usize) -> f64 {
            self.n_per_part
        }
        fn local_epoch(&self, p: usize, w: &[f32], lr: f32) -> Result<Vec<f32>> {
            // one gradient step on 0.5||w-t||^2: w - lr*(w-t)
            Ok(w.iter()
                .zip(&self.targets[p])
                .map(|(&wi, &ti)| wi - lr * (wi - ti))
                .collect())
        }
        fn local_grad(&self, p: usize, w: &[f32]) -> Result<(Vec<f32>, f64, f64)> {
            let g: Vec<f32> = w
                .iter()
                .zip(&self.targets[p])
                .map(|(&wi, &ti)| wi - ti)
                .collect();
            let l: f64 = g.iter().map(|&x| 0.5 * (x as f64) * (x as f64)).sum();
            Ok((g, l * self.n_per_part, self.n_per_part))
        }
    }

    fn quad(parts: usize, d: usize, seed: u64) -> Quadratic {
        let mut rng = Rng::new(seed);
        Quadratic {
            targets: (0..parts)
                .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
                .collect(),
            n_per_part: 10.0,
        }
    }

    #[test]
    fn converges_to_mean_of_targets() {
        let q = quad(4, 3, 0);
        let cluster = SimCluster::ec2(4);
        let res = SGD::run(
            &q,
            &cluster,
            &SgdParams {
                learning_rate: 0.5,
                iters: 60,
                track_loss: true,
                ..Default::default()
            },
        )
        .unwrap();
        // optimum of the averaged objective = mean of targets
        for j in 0..3 {
            let mean: f32 =
                q.targets.iter().map(|t| t[j]).sum::<f32>() / q.targets.len() as f32;
            assert!(
                (res.weights[j] - mean).abs() < 1e-3,
                "dim {j}: {} vs {}",
                res.weights[j],
                mean
            );
        }
        // loss decreases
        let lh = &res.loss_history;
        assert!(lh.last().unwrap() < lh.first().unwrap());
        assert!(res.sim_seconds > 0.0);
        assert_eq!(cluster.rounds(), 61); // 60 + initial broadcast
    }

    #[test]
    fn l1_prox_yields_exact_zeros() {
        let mut q = quad(2, 4, 1);
        // near-zero targets in some dims
        for t in &mut q.targets {
            t[0] = 0.01;
            t[1] = -0.01;
        }
        let cluster = SimCluster::ec2(2);
        let res = SGD::run(
            &q,
            &cluster,
            &SgdParams {
                learning_rate: 0.3,
                iters: 50,
                reg: Reg::L1(0.5),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(res.weights[0], 0.0);
        assert_eq!(res.weights[1], 0.0);
    }

    #[test]
    fn topology_changes_sim_time_not_result() {
        let q = quad(8, 16, 2);
        let star = SimCluster::ec2(8);
        let tree = SimCluster::ec2(8);
        let mut p = SgdParams {
            iters: 5,
            ..Default::default()
        };
        let r1 = SGD::run(&q, &star, &p).unwrap();
        p.topology = CommTopology::AllReduceTree;
        let r2 = SGD::run(&q, &tree, &p).unwrap();
        // identical math
        assert_eq!(r1.weights, r2.weights);
        // different comm accounting
        assert_ne!(star.total_comm_seconds(), tree.total_comm_seconds());
    }

    #[test]
    fn parallel_epochs_bitwise_match_serial() {
        let q = quad(8, 16, 7);
        let p = SgdParams {
            iters: 12,
            ..Default::default()
        };
        let serial = SGD::run(&q, &SimCluster::ec2(8), &p).unwrap();
        for threads in [1, 2, 8] {
            let c = SimCluster::ec2(8).with_executor(threads);
            let par = SGD::run(&q, &c, &p).unwrap();
            assert_eq!(par.weights, serial.weights, "threads={threads}");
            assert_eq!(c.rounds(), 13); // 12 + initial broadcast
        }
    }

    #[test]
    fn faults_and_speculation_leave_weights_bitwise_identical() {
        use crate::cluster::{FaultKind, FaultPlan};
        use std::sync::Arc;
        let q = quad(8, 16, 9);
        let p = SgdParams {
            iters: 6,
            ..Default::default()
        };
        let base = SGD::run(&q, &SimCluster::ec2(8), &p).unwrap();
        // kill machine 2 at round 3 (crash, back after 2 rounds): placement
        // shifts to survivors but the merged math must not move
        let plan = Arc::new(FaultPlan::new());
        plan.kill_at(3, 2, FaultKind::Crash { restart_after: 2 });
        let c = SimCluster::ec2(8)
            .with_executor(4)
            .with_speculation(2.0)
            .with_faults(plan);
        let faulted = SGD::run(&q, &c, &p).unwrap();
        assert_eq!(faulted.weights, base.weights);
        assert_eq!(c.fault_stats().0, 1, "one kill applied");
    }

    #[test]
    fn decay_reduces_step_size() {
        let q = quad(1, 2, 3);
        let c = SimCluster::ec2(1);
        let res = SGD::run(
            &q,
            &c,
            &SgdParams {
                learning_rate: 1.0,
                decay: 100.0,
                iters: 3,
                ..Default::default()
            },
        )
        .unwrap();
        // huge decay => nearly frozen after first step
        let first_step = q.targets[0][0];
        assert!((res.weights[0] - first_step).abs() < 0.2);
    }
}
