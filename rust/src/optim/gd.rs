//! Distributed full-batch gradient descent: per-partition gradients
//! summed at the master, one step per round. This is the MATLAB
//! reference algorithm of §IV-A ("In MATLAB, we implement gradient
//! descent instead of SGD") and the loss-evaluation workhorse.

use super::{LocalStepProvider, Reg};
use crate::cluster::{CommTopology, SimCluster};
use crate::error::Result;
use crate::exec::TaskSet;

#[derive(Debug, Clone)]
pub struct GdParams {
    pub learning_rate: f64,
    pub iters: usize,
    pub reg: Reg,
    pub topology: CommTopology,
    pub track_loss: bool,
}

impl Default for GdParams {
    fn default() -> Self {
        GdParams {
            learning_rate: 0.5,
            iters: 20,
            reg: Reg::None,
            topology: CommTopology::StarGatherBroadcast,
            track_loss: false,
        }
    }
}

pub struct GD;

impl GD {
    pub fn run(
        provider: &dyn LocalStepProvider,
        cluster: &SimCluster,
        params: &GdParams,
    ) -> Result<super::SgdResult> {
        let d = provider.dim();
        let parts = provider.num_partitions();
        let pool = cluster.pool();
        let mut w = vec![0.0f32; d];
        let mut loss_history = Vec::new();
        let t0 = cluster.total_sim_seconds();

        let tracer = cluster.tracer();
        for it in 0..params.iters {
            let round_t0 = tracer.start();
            cluster.begin_round();
            let mut grad = vec![0.0f64; d];
            let mut loss = 0.0;
            let mut examples = 0.0;
            // gradients computed in parallel (one task per partition), but
            // accumulated below in partition index order — deterministic
            // for any thread count despite f64 addition being non-associative
            let stage = TaskSet::new(format!("gd-grad-{it}"), parts);
            let results = stage.try_run(pool.as_deref(), |p| {
                let machine = cluster.assign_machine(p)?;
                cluster.run_task(machine, || provider.local_grad(p, &w))
            })?;
            let merge_t0 = tracer.start();
            for r in results {
                let (g, l, n) = r?;
                for (acc, &x) in grad.iter_mut().zip(&g) {
                    *acc += x as f64;
                }
                loss += l;
                examples += n;
            }
            // normalized step: eta * mean gradient
            let eta = params.learning_rate / examples.max(1.0);
            for (wi, g) in w.iter_mut().zip(&grad) {
                *wi -= (eta * g) as f32;
            }
            params.reg.apply_prox(&mut w, eta);
            if let Some(t0) = merge_t0 {
                tracer.span(format!("gd-merge-{it}"), "optim", 0, t0, &[]);
            }
            // gradient merge travels the fault-aware path; close the
            // round before propagating a network failure
            let sent = cluster.net_allreduce(params.topology, provider.model_bytes());
            cluster.end_round();
            sent?;
            if let Some(t0) = round_t0 {
                tracer.span(format!("gd-round-{it}"), "optim", 0, t0, &[]);
            }
            if params.track_loss {
                loss_history.push(loss / examples.max(1.0));
            }
        }

        Ok(super::SgdResult {
            weights: w,
            loss_history,
            sim_seconds: cluster.total_sim_seconds() - t0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::LocalStepProvider;

    /// 1-D least squares: f(w) = 0.5*sum_i (w - x_i)^2.
    struct Mean1D {
        xs: Vec<Vec<f32>>,
    }

    impl LocalStepProvider for Mean1D {
        fn dim(&self) -> usize {
            1
        }
        fn num_partitions(&self) -> usize {
            self.xs.len()
        }
        fn partition_weight(&self, p: usize) -> f64 {
            self.xs[p].len() as f64
        }
        fn local_epoch(&self, _p: usize, w: &[f32], _lr: f32) -> Result<Vec<f32>> {
            Ok(w.to_vec())
        }
        fn local_grad(&self, p: usize, w: &[f32]) -> Result<(Vec<f32>, f64, f64)> {
            let g: f32 = self.xs[p].iter().map(|x| w[0] - x).sum();
            let l: f64 = self.xs[p]
                .iter()
                .map(|x| 0.5 * ((w[0] - x) as f64).powi(2))
                .sum();
            Ok((vec![g], l, self.xs[p].len() as f64))
        }
    }

    #[test]
    fn gd_converges_to_global_mean() {
        let m = Mean1D {
            xs: vec![vec![1.0, 2.0], vec![3.0], vec![6.0]],
        };
        let cluster = SimCluster::ec2(3);
        let res = GD::run(
            &m,
            &cluster,
            &GdParams {
                learning_rate: 1.0,
                iters: 50,
                track_loss: true,
                ..Default::default()
            },
        )
        .unwrap();
        assert!((res.weights[0] - 3.0).abs() < 1e-3, "{}", res.weights[0]);
        let lh = &res.loss_history;
        assert!(lh.windows(2).all(|w| w[1] <= w[0] + 1e-9), "monotone loss");
    }
}
