//! Offline stand-in for the `xla` (PJRT bindings) crate.
//!
//! This sandbox image does not ship the XLA/PJRT shared libraries, so the
//! crate is built without them. This module mirrors the small slice of the
//! `xla` crate API that `runtime/` consumes, with two behaviours:
//!
//! * **Host-side types are functional.** [`Literal`] really stores data and
//!   dims and validates reshapes, so shape checking (and its tests) work
//!   without any native library.
//! * **Device-side entry points are gated.** [`HloModuleProto::from_text_file`]
//!   always returns an error, which makes `Runtime::executable` fail exactly
//!   the way it fails when AOT artifacts are missing — every XLA-backed code
//!   path degrades to its pure-rust fallback (`Backend::Rust`,
//!   `use_xla: false`), and artifact-dependent tests auto-skip via
//!   `runtime::require_artifacts_or_skip` when no artifacts are present.
//!
//! All types here are plain data (`Send + Sync`), which is what lets the
//! `exec` thread pool share `Runtime` handles across workers.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (Display-able, convertible into
/// [`crate::error::Error::Xla`]).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unsupported(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT native libraries are not available in this build \
         (pure-rust backends remain fully functional)"
    ))
}

/// Host-side tensor literal: data + dims, row-major f32.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal {
            data: v.to_vec(),
            dims: vec![v.len() as i64],
        }
    }

    /// Reshape, validating that the element count is preserved.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements cannot take shape {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Unpack a tuple literal into its leaves.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unsupported("Literal::to_tuple"))
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl From<f32> for Literal {
    fn from(v: f32) -> Literal {
        Literal {
            data: vec![v],
            dims: vec![],
        }
    }
}

/// Element types a [`Literal`] can be copied out as.
pub trait NativeType: Copy {
    fn from_f32(v: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// PJRT device handle (placeholder — the CPU client has one device).
#[derive(Debug, Clone)]
pub struct PjRtDevice;

/// PJRT client. Construction succeeds (it allocates nothing); only
/// compilation/execution entry points are gated.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unsupported("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer {
            literal: literal.clone(),
        })
    }
}

/// Device-resident buffer (host-backed in this stand-in).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Marker for argument types accepted by
/// [`PjRtLoadedExecutable::execute_b`].
pub trait BufferArgument {}

impl BufferArgument for &PjRtBuffer {}

/// A compiled executable. Never constructible in this build
/// ([`PjRtClient::compile`] always errors), so `execute_b` is unreachable
/// but keeps callers type-checking.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: BufferArgument>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unsupported("PjRtLoadedExecutable::execute_b"))
    }
}

/// Parsed HLO module. The text parser requires the native library, so
/// loading always errors — which is what gates every AOT-artifact path.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        Err(Error(format!(
            "cannot parse HLO text {}: XLA/PJRT native libraries are not \
             available in this build (pure-rust backends remain functional)",
            path.display()
        )))
    }
}

/// An XLA computation ready to compile.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(Literal::from(0.5f32).dims(), &[] as &[i64]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn device_paths_are_gated() {
        let client = PjRtClient::cpu().unwrap();
        assert!(HloModuleProto::from_text_file(Path::new("x.hlo.txt")).is_err());
        let lit = Literal::vec1(&[1.0]);
        let buf = client.buffer_from_host_literal(None, &lit).unwrap();
        assert_eq!(buf.to_literal_sync().unwrap().element_count(), 1);
    }
}
