//! Data loading: CSV with schema inference and raw-text loading — the
//! paper's "load data in an unstructured or semi-structured format"
//! entry point (`mc.textFile(...)` in Fig. A2).

use std::path::Path;
use std::sync::Arc;

use super::row::MLRow;
use super::schema::{Column, Schema};
use super::table::MLTable;
use super::value::{ColumnType, Value};
use crate::engine::EngineContext;
use crate::error::{Error, Result};

/// Load a CSV string into an MLTable. `header=true` uses the first line
/// as column names. Types are inferred per column over all rows with the
/// widening order Int -> Scalar -> Str (Bool only if every value parses
/// as bool); columns with any Empty stay at the inferred non-empty type.
pub fn csv_from_str(
    ctx: &Arc<EngineContext>,
    text: &str,
    header: bool,
    partitions: usize,
) -> Result<MLTable> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let names: Option<Vec<String>> = if header {
        let h = lines
            .next()
            .ok_or_else(|| Error::Parse("csv: empty input with header=true".into()))?;
        Some(split_csv_line(h).into_iter().map(|s| s.trim().to_string()).collect())
    } else {
        None
    };

    let mut raw_rows: Vec<Vec<Value>> = Vec::new();
    let mut width = names.as_ref().map(|n| n.len());
    for (i, line) in lines.enumerate() {
        let cells: Vec<Value> = split_csv_line(line)
            .into_iter()
            .map(|tok| Value::parse_infer(&tok))
            .collect();
        match width {
            None => width = Some(cells.len()),
            Some(w) if w != cells.len() => {
                return Err(Error::Parse(format!(
                    "csv: line {} has {} fields, expected {w}",
                    i + 1 + usize::from(header),
                    cells.len()
                )));
            }
            _ => {}
        }
        raw_rows.push(cells);
    }
    let width = width.unwrap_or(0);

    // per-column type widening
    let mut types: Vec<Option<ColumnType>> = vec![None; width];
    for row in &raw_rows {
        for (j, v) in row.iter().enumerate() {
            let t = match v.column_type() {
                None => continue, // Empty
                Some(t) => t,
            };
            types[j] = Some(match (types[j], t) {
                (None, t) => t,
                (Some(a), b) if a == b => a,
                // numeric widening
                (Some(ColumnType::Int), ColumnType::Scalar)
                | (Some(ColumnType::Scalar), ColumnType::Int) => ColumnType::Scalar,
                // anything else widens to Str
                _ => ColumnType::Str,
            });
        }
    }

    // coerce cells to the widened column types
    let schema = Schema::new(
        (0..width)
            .map(|j| Column {
                name: names.as_ref().map(|n| n[j].clone()),
                ctype: types[j].unwrap_or(ColumnType::Str),
            })
            .collect(),
    );
    let rows: Vec<MLRow> = raw_rows
        .into_iter()
        .map(|cells| {
            MLRow::new(
                cells
                    .into_iter()
                    .enumerate()
                    .map(|(j, v)| coerce(v, types[j].unwrap_or(ColumnType::Str)))
                    .collect(),
            )
        })
        .collect();

    MLTable::from_rows(ctx, rows, schema, partitions.max(1))
}

fn coerce(v: Value, t: ColumnType) -> Value {
    match (&v, t) {
        (Value::Empty, _) => Value::Empty,
        (Value::Int(i), ColumnType::Scalar) => Value::Scalar(*i as f64),
        (Value::Int(i), ColumnType::Str) => Value::Str(i.to_string()),
        (Value::Scalar(x), ColumnType::Str) => Value::Str(x.to_string()),
        (Value::Bool(b), ColumnType::Str) => Value::Str(b.to_string()),
        _ => v,
    }
}

/// Minimal CSV field splitter with double-quote support.
fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

/// Load a CSV file.
pub fn csv_from_file(
    ctx: &Arc<EngineContext>,
    path: impl AsRef<Path>,
    header: bool,
    partitions: usize,
) -> Result<MLTable> {
    let text = std::fs::read_to_string(path)?;
    csv_from_str(ctx, &text, header, partitions)
}

/// Load raw text: one row per line, single Str column named "text"
/// (Fig. A2 `mc.textFile(args(0))`).
pub fn text_from_str(ctx: &Arc<EngineContext>, text: &str, partitions: usize) -> Result<MLTable> {
    let rows: Vec<MLRow> = text
        .lines()
        .map(|l| MLRow::new(vec![Value::Str(l.to_string())]))
        .collect();
    MLTable::from_rows(
        ctx,
        rows,
        Schema::new(vec![Column::named("text", ColumnType::Str)]),
        partitions.max(1),
    )
}

pub fn text_from_file(
    ctx: &Arc<EngineContext>,
    path: impl AsRef<Path>,
    partitions: usize,
) -> Result<MLTable> {
    let text = std::fs::read_to_string(path)?;
    text_from_str(ctx, &text, partitions)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Arc<EngineContext> {
        EngineContext::new()
    }

    #[test]
    fn csv_with_header_and_inference() {
        let t = csv_from_str(
            &ctx(),
            "id,name,score,flag\n1,ann,0.5,true\n2,bob,1.5,false\n3,cat,,true\n",
            true,
            2,
        )
        .unwrap();
        assert_eq!(t.num_cols(), 4);
        assert_eq!(t.num_rows().unwrap(), 3);
        assert_eq!(t.schema().index_of("score").unwrap(), 2);
        assert_eq!(t.schema().columns[0].ctype, ColumnType::Int);
        assert_eq!(t.schema().columns[1].ctype, ColumnType::Str);
        assert_eq!(t.schema().columns[2].ctype, ColumnType::Scalar);
        assert_eq!(t.schema().columns[3].ctype, ColumnType::Bool);
        // the empty cell survived as Empty
        let rows = t.collect().unwrap();
        assert!(rows[2][2].is_empty());
    }

    #[test]
    fn csv_widens_int_to_scalar_and_to_str() {
        let t = csv_from_str(&ctx(), "1,7\n2.5,x\n3,9\n", false, 1).unwrap();
        assert_eq!(t.schema().columns[0].ctype, ColumnType::Scalar);
        assert_eq!(t.schema().columns[1].ctype, ColumnType::Str);
        let rows = t.collect().unwrap();
        // int cells coerced to the widened types
        assert_eq!(rows[0][0], Value::Scalar(1.0));
        assert_eq!(rows[0][1], Value::Str("7".into()));
    }

    #[test]
    fn csv_rejects_ragged() {
        assert!(csv_from_str(&ctx(), "1,2\n3\n", false, 1).is_err());
    }

    #[test]
    fn quoted_fields() {
        let t = csv_from_str(&ctx(), "\"a,b\",2\n\"say \"\"hi\"\"\",3\n", false, 1).unwrap();
        let rows = t.collect().unwrap();
        assert_eq!(rows[0][0], Value::Str("a,b".into()));
        assert_eq!(rows[1][0], Value::Str("say \"hi\"".into()));
    }

    #[test]
    fn text_loader() {
        let t = text_from_str(&ctx(), "hello world\nsecond line\n", 2).unwrap();
        assert_eq!(t.num_rows().unwrap(), 2);
        assert_eq!(t.schema().columns[0].name.as_deref(), Some("text"));
        assert_eq!(
            t.collect().unwrap()[1][0],
            Value::Str("second line".into())
        );
    }
}
