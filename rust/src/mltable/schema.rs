//! Column schema for MLTable: each column has an optional name and a
//! basic type (paper §III-A).

use super::value::{ColumnType, Value};
use crate::error::{Error, Result};

/// One column: optional name + type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Column {
    pub name: Option<String>,
    pub ctype: ColumnType,
}

impl Column {
    pub fn named(name: &str, ctype: ColumnType) -> Column {
        Column { name: Some(name.to_string()), ctype }
    }

    pub fn anon(ctype: ColumnType) -> Column {
        Column { name: None, ctype }
    }
}

/// Table schema: an ordered list of columns.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Schema {
    pub columns: Vec<Column>,
}

impl Schema {
    pub fn new(columns: Vec<Column>) -> Schema {
        Schema { columns }
    }

    /// All-Scalar schema of width `d` (featurized data).
    pub fn numeric(d: usize) -> Schema {
        Schema {
            columns: (0..d).map(|_| Column::anon(ColumnType::Scalar)).collect(),
        }
    }

    /// Named numeric schema.
    pub fn numeric_named(names: &[&str]) -> Schema {
        Schema {
            columns: names
                .iter()
                .map(|n| Column::named(n, ColumnType::Scalar))
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.columns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.columns.is_empty()
    }

    /// Column index by name.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.columns
            .iter()
            .position(|c| c.name.as_deref() == Some(name))
            .ok_or_else(|| Error::Schema(format!("no column named '{name}'")))
    }

    /// True if every column is numeric (Int/Scalar/Bool — castable to
    /// MLNumericTable).
    pub fn is_numeric(&self) -> bool {
        self.columns
            .iter()
            .all(|c| matches!(c.ctype, ColumnType::Int | ColumnType::Scalar | ColumnType::Bool))
    }

    /// Validate a row against this schema (Empty matches any type).
    pub fn check_row(&self, values: &[Value]) -> Result<()> {
        if values.len() != self.len() {
            return Err(Error::Schema(format!(
                "row width {} != schema width {}",
                values.len(),
                self.len()
            )));
        }
        for (i, (v, c)) in values.iter().zip(&self.columns).enumerate() {
            if let Some(t) = v.column_type() {
                if t != c.ctype {
                    return Err(Error::Schema(format!(
                        "column {i}: value {v:?} does not match type {:?}",
                        c.ctype
                    )));
                }
            }
        }
        Ok(())
    }

    /// Union compatibility: identical types; names must match where both
    /// sides have them.
    pub fn union_compatible(&self, other: &Schema) -> Result<()> {
        if self.len() != other.len() {
            return Err(Error::Schema(format!(
                "union: widths differ ({} vs {})",
                self.len(),
                other.len()
            )));
        }
        for (i, (a, b)) in self.columns.iter().zip(&other.columns).enumerate() {
            if a.ctype != b.ctype {
                return Err(Error::Schema(format!(
                    "union: column {i} types differ ({:?} vs {:?})",
                    a.ctype, b.ctype
                )));
            }
            if let (Some(na), Some(nb)) = (&a.name, &b.name) {
                if na != nb {
                    return Err(Error::Schema(format!(
                        "union: column {i} names differ ('{na}' vs '{nb}')"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Schema of a projection.
    pub fn project(&self, idxs: &[usize]) -> Result<Schema> {
        let mut cols = Vec::with_capacity(idxs.len());
        for &i in idxs {
            let c = self
                .columns
                .get(i)
                .ok_or_else(|| Error::Schema(format!("project: column {i} out of range")))?;
            cols.push(c.clone());
        }
        Ok(Schema::new(cols))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn abc() -> Schema {
        Schema::new(vec![
            Column::named("a", ColumnType::Int),
            Column::named("b", ColumnType::Str),
            Column::anon(ColumnType::Scalar),
        ])
    }

    #[test]
    fn index_and_project() {
        let s = abc();
        assert_eq!(s.index_of("b").unwrap(), 1);
        assert!(s.index_of("zz").is_err());
        let p = s.project(&[2, 0]).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p.columns[1].name.as_deref(), Some("a"));
        assert!(s.project(&[5]).is_err());
    }

    #[test]
    fn row_validation() {
        let s = abc();
        assert!(s
            .check_row(&[Value::Int(1), Value::Str("x".into()), Value::Scalar(0.5)])
            .is_ok());
        // Empty matches anything
        assert!(s.check_row(&[Value::Empty, Value::Empty, Value::Empty]).is_ok());
        // wrong type
        assert!(s
            .check_row(&[Value::Str("no".into()), Value::Str("x".into()), Value::Scalar(0.5)])
            .is_err());
        // wrong width
        assert!(s.check_row(&[Value::Int(1)]).is_err());
    }

    #[test]
    fn union_compat() {
        let s = abc();
        assert!(s.union_compatible(&abc()).is_ok());
        let mut named_differently = abc();
        named_differently.columns[0].name = Some("z".into());
        assert!(s.union_compatible(&named_differently).is_err());
        let mut anon_ok = abc();
        anon_ok.columns[0].name = None; // one side anonymous: compatible
        assert!(s.union_compatible(&anon_ok).is_ok());
        assert!(s.union_compatible(&Schema::numeric(3)).is_err());
        assert!(s.union_compatible(&Schema::numeric(2)).is_err());
    }

    #[test]
    fn numeric_detection() {
        assert!(Schema::numeric(4).is_numeric());
        assert!(!abc().is_numeric());
        assert_eq!(Schema::numeric_named(&["x", "y"]).index_of("y").unwrap(), 1);
    }
}
