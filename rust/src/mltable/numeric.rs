//! MLNumericTable: an MLTable guaranteed all-numeric; each row is treated
//! as one feature vector (paper §III-A). This is the input type of every
//! Algorithm and the bridge to the XLA runtime (padded f32 partitions).

use super::table::{rows_to_matrix, MLTable};
use crate::engine::Dataset;
use crate::error::{Error, Result};
use crate::localmatrix::{DenseMatrix, LocalMatrix, MLVector};
use crate::mltable::row::MLRow;

/// A numeric table. Construction verifies the schema is numeric; row
/// contents were validated when the underlying table was built.
#[derive(Clone)]
pub struct MLNumericTable {
    table: MLTable,
}

impl MLNumericTable {
    pub fn new(table: MLTable) -> Result<MLNumericTable> {
        if !table.schema().is_numeric() {
            return Err(Error::Schema(format!(
                "MLNumericTable requires numeric columns, got {:?}",
                table
                    .schema()
                    .columns
                    .iter()
                    .map(|c| c.ctype)
                    .collect::<Vec<_>>()
            )));
        }
        Ok(MLNumericTable { table })
    }

    pub fn table(&self) -> &MLTable {
        &self.table
    }

    pub fn to_mltable(&self) -> MLTable {
        self.table.clone()
    }

    pub fn num_rows(&self) -> Result<usize> {
        self.table.num_rows()
    }

    pub fn num_cols(&self) -> usize {
        self.table.num_cols()
    }

    pub fn num_partitions(&self) -> usize {
        self.table.num_partitions()
    }

    pub fn dataset(&self) -> &Dataset<MLRow> {
        self.table.dataset()
    }

    pub fn cache(self) -> MLNumericTable {
        MLNumericTable { table: self.table.cache() }
    }

    /// Partition `p` as a dense matrix (rows = feature vectors).
    pub fn partition_matrix(&self, p: usize) -> Result<DenseMatrix> {
        rows_to_matrix(&self.table.dataset().partition(p)?)
    }

    /// Whole table as one dense matrix (driver-side; small data only).
    pub fn collect_matrix(&self) -> Result<DenseMatrix> {
        let rows = self.table.collect()?;
        rows_to_matrix(&rows)
    }

    /// Rows as MLVectors (Fig. A4 `data.toMLVectors` pattern).
    pub fn collect_vectors(&self) -> Result<Vec<MLVector>> {
        self.table
            .collect()?
            .iter()
            .map(|r| r.to_vector())
            .collect()
    }

    /// Per-partition matrix map (delegates to the MLTable op).
    pub fn matrix_batch_map(
        &self,
        f: impl Fn(usize, &LocalMatrix) -> Result<LocalMatrix> + Send + Sync + 'static,
    ) -> Result<MLNumericTable> {
        self.table.matrix_batch_map(f)
    }

    /// Partition `p` flattened to f32 row-major, **zero-padded** to
    /// `(pad_rows, pad_cols)` — the XLA artifacts are shape-specialized,
    /// so partitions are padded up to the artifact's (n, d). Padding rows
    /// are all-zero; for logistic regression a zero row contributes
    /// sigmoid(0)-0 = 0.5 residual times a zero feature vector = zero
    /// gradient, so padding is exact (and tested).
    pub fn partition_f32_padded(
        &self,
        p: usize,
        pad_rows: usize,
        pad_cols: usize,
    ) -> Result<(Vec<f32>, usize)> {
        let m = self.partition_matrix(p)?;
        if m.rows > pad_rows || m.cols > pad_cols {
            return Err(Error::Shape(format!(
                "partition {p} is {}x{}, larger than artifact shape {pad_rows}x{pad_cols}",
                m.rows, m.cols
            )));
        }
        let mut out = vec![0.0f32; pad_rows * pad_cols];
        for r in 0..m.rows {
            for c in 0..m.cols {
                out[r * pad_cols + c] = m.get(r, c) as f32;
            }
        }
        Ok((out, m.rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineContext;
    use crate::mltable::schema::{Column, Schema};
    use crate::mltable::value::ColumnType;

    #[test]
    fn rejects_string_schema() {
        let ctx = EngineContext::new();
        let t = MLTable::from_rows(
            &ctx,
            vec![MLRow::new(vec!["x".into()])],
            Schema::new(vec![Column::anon(ColumnType::Str)]),
            1,
        )
        .unwrap();
        assert!(MLNumericTable::new(t).is_err());
    }

    #[test]
    fn partition_matrix_and_padding() {
        let ctx = EngineContext::new();
        let rows: Vec<MLRow> = (0..5).map(|i| MLRow::from_scalars(&[i as f64, 2.0 * i as f64])).collect();
        let t = MLTable::from_rows(&ctx, rows, Schema::numeric(2), 2).unwrap();
        let nt = t.to_numeric().unwrap();
        assert_eq!(nt.num_cols(), 2);

        let m0 = nt.partition_matrix(0).unwrap();
        assert_eq!(m0.rows, 3); // balanced split: 3 + 2

        let (padded, real) = nt.partition_f32_padded(0, 8, 4).unwrap();
        assert_eq!(real, 3);
        assert_eq!(padded.len(), 32);
        assert_eq!(padded[1 * 4 + 1], 2.0); // row 1, col 1 = 2*1
        assert_eq!(padded[3 * 4], 0.0); // padding row
        assert!(nt.partition_f32_padded(0, 2, 2).is_err()); // too small

        let full = nt.collect_matrix().unwrap();
        assert_eq!(full.rows, 5);
        let vecs = nt.collect_vectors().unwrap();
        assert_eq!(vecs[4].as_slice(), &[4.0, 8.0]);
    }
}
