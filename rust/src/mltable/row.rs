//! MLRow: one record of an MLTable.

use super::value::Value;
use crate::error::{Error, Result};
use crate::localmatrix::MLVector;

/// One table row. Cheap to clone (the engine moves rows between
/// transformations by value).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MLRow {
    values: Vec<Value>,
}

impl MLRow {
    pub fn new(values: Vec<Value>) -> MLRow {
        MLRow { values }
    }

    /// All-scalar row from f64s (featurized data).
    pub fn from_scalars(xs: &[f64]) -> MLRow {
        MLRow {
            values: xs.iter().map(|&x| Value::Scalar(x)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn get(&self, i: usize) -> Option<&Value> {
        self.values.get(i)
    }

    pub fn values(&self) -> &[Value] {
        &self.values
    }

    pub fn into_values(self) -> Vec<Value> {
        self.values
    }

    /// Numeric view of the whole row (fails on any Str cell).
    pub fn to_vector(&self) -> Result<MLVector> {
        let mut out = Vec::with_capacity(self.values.len());
        for (i, v) in self.values.iter().enumerate() {
            out.push(v.as_scalar().ok_or_else(|| {
                Error::Schema(format!("cell {i} ({v:?}) is not numeric"))
            })?);
        }
        Ok(MLVector::new(out))
    }

    /// Project to a subset of columns.
    pub fn project(&self, idxs: &[usize]) -> Result<MLRow> {
        let mut vals = Vec::with_capacity(idxs.len());
        for &i in idxs {
            vals.push(
                self.values
                    .get(i)
                    .cloned()
                    .ok_or_else(|| Error::Schema(format!("project: column {i} out of range")))?,
            );
        }
        Ok(MLRow::new(vals))
    }

    /// Count of Empty cells.
    pub fn empties(&self) -> usize {
        self.values.iter().filter(|v| v.is_empty()).count()
    }
}

impl From<Vec<Value>> for MLRow {
    fn from(values: Vec<Value>) -> MLRow {
        MLRow { values }
    }
}

impl std::ops::Index<usize> for MLRow {
    type Output = Value;
    fn index(&self, i: usize) -> &Value {
        &self.values[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let r = MLRow::new(vec![Value::Int(1), Value::Str("x".into()), Value::Empty]);
        assert_eq!(r.len(), 3);
        assert_eq!(r[0], Value::Int(1));
        assert_eq!(r.get(5), None);
        assert_eq!(r.empties(), 1);
    }

    #[test]
    fn to_vector_coerces_or_fails() {
        let ok = MLRow::new(vec![Value::Int(2), Value::Scalar(0.5), Value::Bool(true), Value::Empty]);
        assert_eq!(ok.to_vector().unwrap().as_slice(), &[2.0, 0.5, 1.0, 0.0]);
        let bad = MLRow::new(vec![Value::Str("nope".into())]);
        assert!(bad.to_vector().is_err());
    }

    #[test]
    fn project_row() {
        let r = MLRow::from_scalars(&[1.0, 2.0, 3.0]);
        let p = r.project(&[2, 0]).unwrap();
        assert_eq!(p.values(), &[Value::Scalar(3.0), Value::Scalar(1.0)]);
        assert!(r.project(&[9]).is_err());
    }
}
