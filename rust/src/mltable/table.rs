//! MLTable: the paper's table abstraction (Fig. A1 API), backed by the
//! dataflow engine's `Dataset<MLRow>`.

use std::hash::{Hash, Hasher};
use std::sync::Arc;

use super::numeric::MLNumericTable;
use super::row::MLRow;
use super::schema::Schema;
use super::value::Value;
use crate::engine::{Dataset, EngineContext};
use crate::error::{Error, Result};
use crate::localmatrix::{DenseMatrix, LocalMatrix};

/// Hashable key wrapper so rows can be keyed by any cell value
/// (Scalar keys hash by bit pattern; NaN keys are rejected upstream).
#[derive(Debug, Clone, PartialEq)]
pub struct KeyValue(pub Value);

impl Eq for KeyValue {}

impl Hash for KeyValue {
    fn hash<H: Hasher>(&self, state: &mut H) {
        match &self.0 {
            Value::Str(s) => {
                0u8.hash(state);
                s.hash(state);
            }
            Value::Int(i) => {
                1u8.hash(state);
                i.hash(state);
            }
            Value::Bool(b) => {
                2u8.hash(state);
                b.hash(state);
            }
            Value::Scalar(x) => {
                3u8.hash(state);
                x.to_bits().hash(state);
            }
            Value::Empty => 4u8.hash(state),
        }
    }
}

/// The paper's MLTable: a schema'd, partitioned collection of rows.
#[derive(Clone)]
pub struct MLTable {
    pub(crate) data: Dataset<MLRow>,
    pub(crate) schema: Schema,
}

impl MLTable {
    /// Build from rows (validates against the schema).
    pub fn from_rows(
        ctx: &Arc<EngineContext>,
        rows: Vec<MLRow>,
        schema: Schema,
        partitions: usize,
    ) -> Result<MLTable> {
        for (i, r) in rows.iter().enumerate() {
            schema.check_row(r.values()).map_err(|e| {
                Error::Schema(format!("row {i}: {e}"))
            })?;
        }
        Ok(MLTable {
            data: ctx.parallelize(rows, partitions),
            schema,
        })
    }

    /// Wrap an existing dataset (caller guarantees schema conformance —
    /// used by transformation outputs).
    pub fn from_dataset(data: Dataset<MLRow>, schema: Schema) -> MLTable {
        MLTable { data, schema }
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn dataset(&self) -> &Dataset<MLRow> {
        &self.data
    }

    pub fn context(&self) -> Arc<EngineContext> {
        self.data.context()
    }

    pub fn num_partitions(&self) -> usize {
        self.data.num_partitions()
    }

    // ---- Fig. A1 operations -------------------------------------------

    /// `numRows` — row count (an action).
    pub fn num_rows(&self) -> Result<usize> {
        self.data.count()
    }

    /// `numCols` — schema width.
    pub fn num_cols(&self) -> usize {
        self.schema.len()
    }

    /// `project(Seq[Index])` — select a subset of columns.
    pub fn project(&self, idxs: &[usize]) -> Result<MLTable> {
        let schema = self.schema.project(idxs)?;
        let idxs = idxs.to_vec();
        let data = self.data.map(move |r| {
            // idxs were validated by schema.project above; this per-row
            // closure runs lazily and has no Result channel to propagate
            // mli-lint: allow(E001) validated by schema.project; lazy closure
            r.project(&idxs).expect("validated projection")
        });
        Ok(MLTable { data, schema })
    }

    /// Project by column names.
    pub fn project_named(&self, names: &[&str]) -> Result<MLTable> {
        let idxs = names
            .iter()
            .map(|n| self.schema.index_of(n))
            .collect::<Result<Vec<_>>>()?;
        self.project(&idxs)
    }

    /// `union(MLTable)` — concatenate tables with identical schemas.
    pub fn union(&self, other: &MLTable) -> Result<MLTable> {
        self.schema.union_compatible(&other.schema)?;
        Ok(MLTable {
            data: self.data.union(&other.data),
            schema: self.schema.clone(),
        })
    }

    /// `filter(MLRow => Bool)`.
    pub fn filter(&self, f: impl Fn(&MLRow) -> bool + Send + Sync + 'static) -> MLTable {
        MLTable {
            data: self.data.filter(f),
            schema: self.schema.clone(),
        }
    }

    /// `map(MLRow => MLRow)` — caller supplies the output schema.
    pub fn map(
        &self,
        schema: Schema,
        f: impl Fn(&MLRow) -> MLRow + Send + Sync + 'static,
    ) -> MLTable {
        MLTable {
            data: self.data.map(f),
            schema,
        }
    }

    /// `flatMap(MLRow => TraversableOnce[MLRow])`.
    pub fn flat_map(
        &self,
        schema: Schema,
        f: impl Fn(&MLRow) -> Vec<MLRow> + Send + Sync + 'static,
    ) -> MLTable {
        MLTable {
            data: self.data.flat_map(f),
            schema,
        }
    }

    /// `reduce(Seq[MLRow] => MLRow)` — associative+commutative combine of
    /// all rows down to one.
    pub fn reduce(&self, f: impl Fn(&MLRow, &MLRow) -> MLRow) -> Result<Option<MLRow>> {
        self.data.reduce(|a, b| f(&a, &b))
    }

    /// `reduceByKey(keyCol, combine)` — combine rows per distinct value of
    /// a key column. Returns a table with the same schema.
    pub fn reduce_by_key(
        &self,
        key_col: usize,
        f: impl Fn(&MLRow, &MLRow) -> MLRow + Send + Sync + 'static,
    ) -> Result<MLTable> {
        if key_col >= self.schema.len() {
            return Err(Error::Schema(format!("reduceByKey: column {key_col} out of range")));
        }
        let keyed = self.data.map(move |r| {
            (KeyValue(r[key_col].clone()), r.clone())
        });
        let reduced = keyed.reduce_by_key(move |a, b| f(&a, &b));
        Ok(MLTable {
            data: reduced.map(|(_, r)| r.clone()),
            schema: self.schema.clone(),
        })
    }

    /// `join(other, Seq[Index])` — inner equi-join on shared columns
    /// (indices interpreted in both schemas). Output schema: self's
    /// columns followed by other's non-key columns.
    pub fn join(&self, other: &MLTable, key_cols: &[usize]) -> Result<MLTable> {
        for &k in key_cols {
            if k >= self.schema.len() || k >= other.schema.len() {
                return Err(Error::Schema(format!("join: key column {k} out of range")));
            }
        }
        let kc: Vec<usize> = key_cols.to_vec();
        let kc2 = kc.clone();
        let keyed_a = self.data.map(move |r| {
            let key: Vec<KeyValue> = kc.iter().map(|&i| KeyValue(r[i].clone())).collect();
            (KeyHash(key), r.clone())
        });
        let keyed_b = other.data.map(move |r| {
            let key: Vec<KeyValue> = kc2.iter().map(|&i| KeyValue(r[i].clone())).collect();
            (KeyHash(key), r.clone())
        });
        let other_nonkey: Vec<usize> = (0..other.schema.len())
            .filter(|i| !key_cols.contains(i))
            .collect();
        let ok2 = other_nonkey.clone();
        let joined = keyed_a.join(&keyed_b).map(move |(_, (ra, rb))| {
            let mut vals = ra.values().to_vec();
            for &i in &ok2 {
                vals.push(rb[i].clone());
            }
            MLRow::new(vals)
        });
        let mut cols = self.schema.columns.clone();
        for &i in &other_nonkey {
            cols.push(other.schema.columns[i].clone());
        }
        Ok(MLTable {
            data: joined,
            schema: Schema::new(cols),
        })
    }

    /// `matrixBatchMap(LocalMatrix => LocalMatrix)` — run a batch function
    /// on each partition's rows as a matrix; outputs concatenate into an
    /// MLNumericTable (Fig. A1). The core primitive of the SGD optimizer
    /// (Fig. A4 `data.matrixBatchMap(localSGD(...))`).
    pub fn matrix_batch_map(
        &self,
        f: impl Fn(usize, &LocalMatrix) -> Result<LocalMatrix> + Send + Sync + 'static,
    ) -> Result<MLNumericTable> {
        if !self.schema.is_numeric() {
            return Err(Error::Schema(
                "matrixBatchMap requires an all-numeric table; cast via to_numeric()".into(),
            ));
        }
        let mapped = self.data.map_partitions(move |p, rows| {
            let m = rows_to_matrix(rows)?;
            let out = f(p, &LocalMatrix::Dense(m))?;
            matrix_to_rows(&out)
        });
        // width of output is data-dependent; peek partition 0
        let d = mapped.partition(0)?.first().map(|r| r.len()).unwrap_or(0);
        MLNumericTable::new(MLTable {
            data: mapped,
            schema: Schema::numeric(d),
        })
    }

    /// Cast to MLNumericTable (paper §III-A: "once data is featurized, it
    /// can be cast into an MLNumericTable").
    pub fn to_numeric(&self) -> Result<MLNumericTable> {
        MLNumericTable::new(self.clone())
    }

    // ---- actions / utilities -----------------------------------------

    pub fn collect(&self) -> Result<Vec<MLRow>> {
        self.data.collect()
    }

    /// Deterministic Bernoulli sample of rows (fraction in [0, 1]).
    pub fn sample(&self, fraction: f64, seed: u64) -> MLTable {
        // fresh RNG per partition evaluation, seeded by (seed, p): the
        // sample is a pure function of the inputs, stable across
        // recomputation (lineage recovery) and executor thread counts
        let data = self.data.map_partitions(move |p, rows| {
            let mut rng = crate::util::rng::Rng::new(seed ^ ((p as u64) << 17));
            Ok(rows
                .iter()
                .filter(|_| rng.f64() < fraction)
                .cloned()
                .collect())
        });
        MLTable {
            data,
            schema: self.schema.clone(),
        }
    }

    /// Distinct rows (driver-side dedup keyed on all cells; preserves
    /// first occurrence order).
    pub fn distinct(&self) -> Result<MLTable> {
        let rows = self.data.collect()?;
        let mut seen: std::collections::HashSet<Vec<KeyValue>> = std::collections::HashSet::new();
        let mut out = Vec::new();
        for r in rows {
            let key: Vec<KeyValue> = r.values().iter().cloned().map(KeyValue).collect();
            if seen.insert(key) {
                out.push(r);
            }
        }
        let parts = self.num_partitions();
        Ok(MLTable {
            data: self.context().parallelize(out, parts),
            schema: self.schema.clone(),
        })
    }

    /// First `n` rows (in partition order).
    pub fn take(&self, n: usize) -> Result<Vec<MLRow>> {
        let mut out = Vec::with_capacity(n);
        for p in 0..self.num_partitions() {
            if out.len() >= n {
                break;
            }
            for r in self.data.partition(p)?.iter() {
                if out.len() >= n {
                    break;
                }
                out.push(r.clone());
            }
        }
        Ok(out)
    }

    /// Sort by a column (driver-side; Scalars/Ints compare numerically,
    /// Strs lexicographically, Empty sorts first).
    pub fn sort_by(&self, col: usize, descending: bool) -> Result<MLTable> {
        if col >= self.schema.len() {
            return Err(Error::Schema(format!("sortBy: column {col} out of range")));
        }
        let mut rows = self.data.collect()?;
        let key = |r: &MLRow| -> (u8, f64, String) {
            match &r[col] {
                Value::Empty => (0, 0.0, String::new()),
                v => match v.as_scalar() {
                    Some(x) => (1, x, String::new()),
                    None => (2, 0.0, v.to_string()),
                },
            }
        };
        rows.sort_by(|a, b| {
            let (ka, kb) = (key(a), key(b));
            let ord = ka
                .0
                .cmp(&kb.0)
                .then(ka.1.partial_cmp(&kb.1).unwrap_or(std::cmp::Ordering::Equal))
                .then(ka.2.cmp(&kb.2));
            if descending {
                ord.reverse()
            } else {
                ord
            }
        });
        let parts = self.num_partitions();
        Ok(MLTable {
            data: self.context().parallelize(rows, parts),
            schema: self.schema.clone(),
        })
    }

    pub fn cache(self) -> MLTable {
        MLTable {
            data: self.data.cache(),
            schema: self.schema,
        }
    }
}

/// Composite join key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct KeyHash(pub Vec<KeyValue>);

/// Partition rows -> dense matrix (numeric cells only).
pub(crate) fn rows_to_matrix(rows: &[MLRow]) -> Result<DenseMatrix> {
    let r = rows.len();
    let c = rows.first().map(|x| x.len()).unwrap_or(0);
    let mut data = Vec::with_capacity(r * c);
    for (i, row) in rows.iter().enumerate() {
        if row.len() != c {
            return Err(Error::Schema(format!(
                "ragged partition: row {i} has {} cells, expected {c}",
                row.len()
            )));
        }
        for (j, v) in row.values().iter().enumerate() {
            data.push(v.as_scalar().ok_or_else(|| {
                Error::Schema(format!("non-numeric cell at ({i},{j}): {v:?}"))
            })?);
        }
    }
    DenseMatrix::new(r, c, data)
}

/// Matrix -> rows of Scalars.
pub(crate) fn matrix_to_rows(m: &LocalMatrix) -> Result<Vec<MLRow>> {
    let d = m.to_dense();
    Ok((0..d.rows)
        .map(|r| MLRow::from_scalars(d.row(r)))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::super::schema::Column;
    use super::super::value::ColumnType;
    use super::*;

    fn ctx() -> Arc<EngineContext> {
        EngineContext::new()
    }

    fn people(ctx: &Arc<EngineContext>) -> MLTable {
        let schema = Schema::new(vec![
            Column::named("id", ColumnType::Int),
            Column::named("name", ColumnType::Str),
            Column::named("score", ColumnType::Scalar),
        ]);
        let rows = vec![
            MLRow::new(vec![1i64.into(), "ann".into(), 0.5.into()]),
            MLRow::new(vec![2i64.into(), "bob".into(), 1.5.into()]),
            MLRow::new(vec![3i64.into(), "cat".into(), 2.5.into()]),
            MLRow::new(vec![1i64.into(), "ann2".into(), 3.5.into()]),
        ];
        MLTable::from_rows(ctx, rows, schema, 2).unwrap()
    }

    #[test]
    fn schema_validated_on_construction() {
        let c = ctx();
        let schema = Schema::new(vec![Column::named("x", ColumnType::Int)]);
        let bad = vec![MLRow::new(vec!["oops".into()])];
        assert!(MLTable::from_rows(&c, bad, schema, 1).is_err());
    }

    #[test]
    fn num_rows_cols_project() {
        let c = ctx();
        let t = people(&c);
        assert_eq!(t.num_rows().unwrap(), 4);
        assert_eq!(t.num_cols(), 3);
        let p = t.project_named(&["score", "id"]).unwrap();
        assert_eq!(p.num_cols(), 2);
        let rows = p.collect().unwrap();
        assert_eq!(rows[0].values()[0], Value::Scalar(0.5));
        assert_eq!(rows[0].values()[1], Value::Int(1));
    }

    #[test]
    fn filter_map_flatmap() {
        let c = ctx();
        let t = people(&c);
        let f = t.filter(|r| r[2].as_scalar().unwrap() > 1.0);
        assert_eq!(f.num_rows().unwrap(), 3);

        let doubled = t.map(Schema::numeric(1), |r| {
            MLRow::from_scalars(&[r[2].as_scalar().unwrap() * 2.0])
        });
        let vals: Vec<f64> = doubled
            .collect()
            .unwrap()
            .iter()
            .map(|r| r[0].as_scalar().unwrap())
            .collect();
        assert_eq!(vals, vec![1.0, 3.0, 5.0, 7.0]);

        let fm = t.flat_map(Schema::numeric(1), |r| {
            vec![
                MLRow::from_scalars(&[r[0].as_int().unwrap() as f64]),
                MLRow::from_scalars(&[0.0]),
            ]
        });
        assert_eq!(fm.num_rows().unwrap(), 8);
    }

    #[test]
    fn union_requires_compatible_schema() {
        let c = ctx();
        let t = people(&c);
        let u = t.union(&people(&c)).unwrap();
        assert_eq!(u.num_rows().unwrap(), 8);
        let other = MLTable::from_rows(
            &c,
            vec![MLRow::from_scalars(&[1.0])],
            Schema::numeric(1),
            1,
        )
        .unwrap();
        assert!(t.union(&other).is_err());
    }

    #[test]
    fn reduce_by_key_combines_per_key() {
        let c = ctx();
        let t = people(&c);
        let r = t
            .reduce_by_key(0, |a, b| {
                MLRow::new(vec![
                    a[0].clone(),
                    a[1].clone(),
                    Value::Scalar(a[2].as_scalar().unwrap() + b[2].as_scalar().unwrap()),
                ])
            })
            .unwrap();
        let mut rows = r.collect().unwrap();
        rows.sort_by_key(|r| r[0].as_int().unwrap());
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0][2].as_scalar().unwrap(), 4.0); // ids 1: 0.5+3.5
        assert!(t.reduce_by_key(9, |a, _| a.clone()).is_err());
    }

    #[test]
    fn join_on_key_column() {
        let c = ctx();
        let t = people(&c);
        let extra = MLTable::from_rows(
            &c,
            vec![
                MLRow::new(vec![1i64.into(), Value::Scalar(10.0)]),
                MLRow::new(vec![3i64.into(), Value::Scalar(30.0)]),
            ],
            Schema::new(vec![
                Column::named("id", ColumnType::Int),
                Column::named("bonus", ColumnType::Scalar),
            ]),
            1,
        )
        .unwrap();
        let j = t.join(&extra, &[0]).unwrap();
        assert_eq!(j.num_cols(), 4); // id, name, score, bonus
        let mut rows = j.collect().unwrap();
        rows.sort_by_key(|r| (r[0].as_int().unwrap(), r[1].as_str().unwrap().to_string()));
        assert_eq!(rows.len(), 3); // ids 1 (x2 rows), 3
        assert_eq!(rows[0][3].as_scalar().unwrap(), 10.0);
    }

    #[test]
    fn matrix_batch_map_runs_per_partition() {
        let c = ctx();
        let rows: Vec<MLRow> = (0..6).map(|i| MLRow::from_scalars(&[i as f64, 1.0])).collect();
        let t = MLTable::from_rows(&c, rows, Schema::numeric(2), 3).unwrap();
        // per-partition column sums -> one row per partition
        let nt = t
            .matrix_batch_map(|_, m| {
                let d = m.to_dense();
                let mut sums = vec![0.0; d.cols];
                for r in 0..d.rows {
                    for (j, s) in sums.iter_mut().enumerate() {
                        *s += d.get(r, j);
                    }
                }
                LocalMatrix::dense(1, d.cols, sums)
            })
            .unwrap();
        assert_eq!(nt.num_rows().unwrap(), 3);
        let m = nt.collect_matrix().unwrap();
        assert_eq!(m.get(0, 1), 2.0); // partition 0 had 2 rows
        let total: f64 = (0..3).map(|p| m.get(p, 0)).sum();
        assert_eq!(total, 15.0);
    }

    #[test]
    fn matrix_batch_map_rejects_non_numeric() {
        let c = ctx();
        let t = people(&c);
        assert!(t.matrix_batch_map(|_, m| Ok(m.clone())).is_err());
    }

    #[test]
    fn sample_deterministic_and_bounded() {
        let c = ctx();
        let rows: Vec<MLRow> = (0..1000).map(|i| MLRow::from_scalars(&[i as f64])).collect();
        let t = MLTable::from_rows(&c, rows, Schema::numeric(1), 4).unwrap();
        let s1 = t.sample(0.3, 7).num_rows().unwrap();
        let s2 = t.sample(0.3, 7).num_rows().unwrap();
        assert_eq!(s1, s2, "same seed, same sample");
        assert!(s1 > 200 && s1 < 400, "fraction off: {s1}");
        assert_eq!(t.sample(0.0, 1).num_rows().unwrap(), 0);
        assert_eq!(t.sample(1.0, 1).num_rows().unwrap(), 1000);
    }

    #[test]
    fn distinct_and_take() {
        let c = ctx();
        let rows = vec![
            MLRow::from_scalars(&[1.0]),
            MLRow::from_scalars(&[2.0]),
            MLRow::from_scalars(&[1.0]),
        ];
        let t = MLTable::from_rows(&c, rows, Schema::numeric(1), 2).unwrap();
        let d = t.distinct().unwrap();
        assert_eq!(d.num_rows().unwrap(), 2);
        let first = t.take(2).unwrap();
        assert_eq!(first.len(), 2);
        assert_eq!(first[0][0].as_scalar().unwrap(), 1.0);
        assert_eq!(t.take(100).unwrap().len(), 3);
    }

    #[test]
    fn sort_by_column() {
        let c = ctx();
        let t = people(&c);
        let sorted = t.sort_by(2, false).unwrap();
        let scores: Vec<f64> = sorted
            .collect()
            .unwrap()
            .iter()
            .map(|r| r[2].as_scalar().unwrap())
            .collect();
        assert_eq!(scores, vec![0.5, 1.5, 2.5, 3.5]);
        let desc = t.sort_by(1, true).unwrap();
        assert_eq!(desc.collect().unwrap()[0][1].as_str().unwrap(), "cat");
        assert!(t.sort_by(9, false).is_err());
    }
}
