//! MLTable — the paper's data-loading/feature-extraction abstraction
//! (§III-A, API in Fig. A1): a schema'd, partitioned table with
//! relational (project/union/filter/join) and MapReduce
//! (map/flatMap/reduce/reduceByKey) operators plus the batch primitive
//! `matrixBatchMap` that bridges to LocalMatrix compute.

pub mod load;
pub mod numeric;
pub mod row;
pub mod schema;
pub mod table;
pub mod value;

pub use load::{csv_from_file, csv_from_str, text_from_file, text_from_str};
pub use numeric::MLNumericTable;
pub use row::MLRow;
pub use schema::{Column, Schema};
pub use table::MLTable;
pub use value::{ColumnType, Value};
