//! Cell values for MLTable (paper §III-A): String, Integer, Boolean,
//! Scalar, and the special "Empty" value any cell may hold.

use std::fmt;

/// One table cell.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Bool(bool),
    Scalar(f64),
    /// Missing data — first-class per the paper ("any cell in the table
    /// can be 'Empty'").
    Empty,
}

/// Column type tags (the schema side of [`Value`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnType {
    Str,
    Int,
    Bool,
    Scalar,
}

impl Value {
    pub fn column_type(&self) -> Option<ColumnType> {
        match self {
            Value::Str(_) => Some(ColumnType::Str),
            Value::Int(_) => Some(ColumnType::Int),
            Value::Bool(_) => Some(ColumnType::Bool),
            Value::Scalar(_) => Some(ColumnType::Scalar),
            Value::Empty => None, // Empty fits any column
        }
    }

    pub fn is_empty(&self) -> bool {
        matches!(self, Value::Empty)
    }

    /// Numeric view: Int/Scalar/Bool coerce; Empty maps to 0.0 (the
    /// MATLAB-style convention MLNumericTable uses); Str fails.
    pub fn as_scalar(&self) -> Option<f64> {
        match self {
            Value::Scalar(x) => Some(*x),
            Value::Int(i) => Some(*i as f64),
            Value::Bool(b) => Some(if *b { 1.0 } else { 0.0 }),
            Value::Empty => Some(0.0),
            Value::Str(_) => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Bool(b) => Some(i64::from(*b)),
            _ => None,
        }
    }

    /// Parse a raw CSV token with type inference priority
    /// Int > Scalar > Bool > Str; empty string -> Empty.
    pub fn parse_infer(tok: &str) -> Value {
        let t = tok.trim();
        if t.is_empty() {
            return Value::Empty;
        }
        if let Ok(i) = t.parse::<i64>() {
            return Value::Int(i);
        }
        if let Ok(f) = t.parse::<f64>() {
            return Value::Scalar(f);
        }
        match t.to_ascii_lowercase().as_str() {
            "true" => Value::Bool(true),
            "false" => Value::Bool(false),
            _ => Value::Str(t.to_string()),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Scalar(x) => write!(f, "{x}"),
            Value::Empty => write!(f, ""),
        }
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Value {
        Value::Scalar(x)
    }
}
impl From<i64> for Value {
    fn from(x: i64) -> Value {
        Value::Int(x)
    }
}
impl From<bool> for Value {
    fn from(x: bool) -> Value {
        Value::Bool(x)
    }
}
impl From<&str> for Value {
    fn from(x: &str) -> Value {
        Value::Str(x.to_string())
    }
}
impl From<String> for Value {
    fn from(x: String) -> Value {
        Value::Str(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inference_priority() {
        assert_eq!(Value::parse_infer("42"), Value::Int(42));
        assert_eq!(Value::parse_infer("4.5"), Value::Scalar(4.5));
        assert_eq!(Value::parse_infer("-1e3"), Value::Scalar(-1000.0));
        assert_eq!(Value::parse_infer("true"), Value::Bool(true));
        assert_eq!(Value::parse_infer("FALSE"), Value::Bool(false));
        assert_eq!(Value::parse_infer("cat"), Value::Str("cat".into()));
        assert_eq!(Value::parse_infer("  "), Value::Empty);
    }

    #[test]
    fn scalar_coercion() {
        assert_eq!(Value::Int(3).as_scalar(), Some(3.0));
        assert_eq!(Value::Bool(true).as_scalar(), Some(1.0));
        assert_eq!(Value::Empty.as_scalar(), Some(0.0));
        assert_eq!(Value::Str("x".into()).as_scalar(), None);
        assert_eq!(Value::Scalar(2.5).as_scalar(), Some(2.5));
    }

    #[test]
    fn type_tags() {
        assert_eq!(Value::Int(1).column_type(), Some(ColumnType::Int));
        assert_eq!(Value::Empty.column_type(), None);
        assert!(Value::Empty.is_empty());
    }

    #[test]
    fn display_roundtrip_for_numerics() {
        assert_eq!(Value::Int(7).to_string(), "7");
        assert_eq!(Value::Scalar(1.5).to_string(), "1.5");
        assert_eq!(Value::Empty.to_string(), "");
    }
}
