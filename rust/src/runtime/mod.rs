//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! This is the only place the crate touches XLA. The flow (adapted from
//! /opt/xla-example/load_hlo) is:
//!
//! ```text
//! PjRtClient::cpu()
//!   -> HloModuleProto::from_text_file("artifacts/<entry>__<variant>.hlo.txt")
//!   -> XlaComputation::from_proto
//!   -> client.compile(&comp)           (once, cached)
//!   -> exe.execute(&[Literal...])      (hot path)
//! ```
//!
//! HLO *text* is the interchange format because jax >= 0.5 emits protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py).
//!
//! Executables are compiled lazily on first use and cached per
//! (entry, variant). All L2 entry points return tuples (aot.py lowers
//! with `return_tuple=True`), so execution always unwraps a tuple.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};
use crate::util::lock_unpoisoned;
use crate::xla;
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

/// A typed input tensor handed to [`Runtime::execute`].
#[derive(Debug, Clone)]
pub enum Tensor {
    /// Dense f32 tensor with explicit dims (row-major).
    F32(Vec<f32>, Vec<usize>),
    /// Scalar f32 (rank-0) — learning rates, lambda, etc.
    Scalar(f32),
}

impl Tensor {
    pub fn dims(&self) -> Vec<usize> {
        match self {
            Tensor::F32(_, d) => d.clone(),
            Tensor::Scalar(_) => vec![],
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v, _) => v.len(),
            Tensor::Scalar(_) => 1,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Build the XLA literal for this tensor (copies the data once).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Tensor::Scalar(x) => Ok(xla::Literal::from(*x)),
            Tensor::F32(v, dims) => {
                let n: usize = dims.iter().product();
                if n != v.len() {
                    return Err(Error::Runtime(format!(
                        "tensor data len {} != product of dims {:?}",
                        v.len(),
                        dims
                    )));
                }
                let lit = xla::Literal::vec1(v.as_slice());
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                Ok(lit.reshape(&dims_i64)?)
            }
        }
    }
}

/// A device-resident input: the PJRT buffer plus the host literal it was
/// (asynchronously) transferred from. The literal MUST be kept alive for
/// the buffer's lifetime — see [`Executable::to_device`].
pub struct DeviceTensor {
    _literal: xla::Literal,
    buffer: xla::PjRtBuffer,
}

impl DeviceTensor {
    pub fn buffer(&self) -> &xla::PjRtBuffer {
        &self.buffer
    }
}

/// One compiled entry point, ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    spec: ArtifactSpec,
}

impl Executable {
    /// Execute with shape-checked inputs; returns one `Vec<f32>` per
    /// output leaf, in the order listed in the manifest.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        self.check_inputs(inputs)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        self.run_literals(&refs)
    }

    /// Execute with pre-built literals. Inputs are transferred to device
    /// buffers we own and drop — NOT via `PjRtLoadedExecutable::execute`,
    /// whose internal literal->buffer conversion leaks the input buffers
    /// (xla 0.1.6 bug, ~input-size bytes per call; measured and fixed in
    /// EXPERIMENTS.md §Perf L3 iteration 5).
    pub fn run_literals(&self, lits: &[&xla::Literal]) -> Result<Vec<Vec<f32>>> {
        let bufs = lits
            .iter()
            .map(|l| Ok(self.client.buffer_from_host_literal(None, l)?))
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.run_buffers(&refs)
    }

    /// Execute with device buffers (the zero-copy hot path: callers keep
    /// big inputs device-resident across rounds, transferring only the
    /// weight vector per call).
    pub fn run_buffers(&self, bufs: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<f32>>> {
        if bufs.len() != self.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: got {} inputs, expected {}",
                self.spec.key(),
                bufs.len(),
                self.spec.inputs.len()
            )));
        }
        let result = self.exe.execute_b::<&xla::PjRtBuffer>(bufs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        let mut out_vecs = Vec::with_capacity(outs.len());
        for o in outs {
            out_vecs.push(o.to_vec::<f32>()?);
        }
        if out_vecs.len() != self.spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "{}: got {} outputs, manifest says {}",
                self.spec.key(),
                out_vecs.len(),
                self.spec.outputs.len()
            )));
        }
        Ok(out_vecs)
    }

    /// Transfer a tensor to a device buffer (for cross-round caching).
    ///
    /// Returns a [`DeviceTensor`] that keeps the source literal alive:
    /// `buffer_from_host_literal` on the CPU client transfers
    /// asynchronously, so the literal must outlive the buffer (dropping
    /// it early is a use-after-free).
    pub fn to_device(&self, t: &Tensor) -> Result<DeviceTensor> {
        let literal = t.to_literal()?;
        let buffer = self.client.buffer_from_host_literal(None, &literal)?;
        Ok(DeviceTensor { _literal: literal, buffer })
    }

    fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: got {} inputs, expected {}",
                self.spec.key(),
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        for (i, (t, s)) in inputs.iter().zip(&self.spec.inputs).enumerate() {
            if t.dims() != s.shape {
                return Err(Error::Runtime(format!(
                    "{} input {}: shape {:?} != manifest {:?}",
                    self.spec.key(),
                    i,
                    t.dims(),
                    s.shape
                )));
            }
        }
        Ok(())
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Raw access to the underlying PJRT executable (buffer-level
    /// execution paths).
    pub fn raw(&self) -> &xla::PjRtLoadedExecutable {
        &self.exe
    }
}

/// The PJRT runtime: client + artifact registry + executable cache.
///
/// `Send + Sync`: the cache and counters sit behind mutexes so the `exec`
/// thread pool can share one `Runtime` across workers. One `Runtime` is
/// shared per process via [`Runtime::global`].
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// execution counters for the metrics report
    pub exec_count: Mutex<HashMap<String, u64>>,
}

impl Runtime {
    /// Create a runtime over an artifact directory (must contain
    /// `manifest.json` produced by `make artifacts`).
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
            exec_count: Mutex::new(HashMap::new()),
        })
    }

    /// Default artifact directory: `$MLI_ARTIFACTS` or `./artifacts`.
    pub fn artifact_dir() -> PathBuf {
        std::env::var("MLI_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Process-wide runtime, shared across all worker threads.
    pub fn global() -> Result<Arc<Runtime>> {
        static GLOBAL: Mutex<Option<Arc<Runtime>>> = Mutex::new(None);
        let mut g = lock_unpoisoned(&GLOBAL);
        if let Some(rt) = g.as_ref() {
            return Ok(rt.clone());
        }
        let rt = Arc::new(Runtime::new(Runtime::artifact_dir())?);
        *g = Some(rt.clone());
        Ok(rt)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The underlying PJRT client (device buffer management).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Fetch (compiling + caching on first use) an executable.
    pub fn executable(&self, entry: &str, variant: &str) -> Result<Arc<Executable>> {
        let key = format!("{entry}__{variant}");
        if let Some(e) = lock_unpoisoned(&self.cache).get(&key) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .find(entry, variant)
            .ok_or_else(|| {
                Error::Runtime(format!(
                    "artifact {key} not in manifest (run `make artifacts`)"
                ))
            })?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let e = Arc::new(Executable {
            exe,
            client: self.client.clone(),
            spec,
        });
        lock_unpoisoned(&self.cache).insert(key, e.clone());
        Ok(e)
    }

    /// Convenience: execute an entry point end-to-end.
    pub fn execute(&self, entry: &str, variant: &str, inputs: &[Tensor]) -> Result<Vec<Vec<f32>>> {
        let exe = self.executable(entry, variant)?;
        self.count_exec(entry, variant);
        exe.run(inputs)
    }

    /// Record one execution in the metrics counter (callers on the raw
    /// buffer path count themselves).
    pub fn count_exec(&self, entry: &str, variant: &str) {
        *lock_unpoisoned(&self.exec_count)
            .entry(format!("{entry}__{variant}"))
            .or_insert(0) += 1;
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        lock_unpoisoned(&self.cache).len()
    }
}

/// True when the AOT artifact directory holds a `manifest.json`.
pub fn artifacts_available() -> bool {
    Runtime::artifact_dir().join("manifest.json").exists()
}

/// Guard for artifact-dependent tests: returns `true` when the AOT
/// artifacts are present. When absent, prints a skip note and returns
/// `false` so the caller can early-return — unless `MLI_REQUIRE_ARTIFACTS=1`
/// (set by the dedicated CI job that builds the artifacts first), in which
/// case silently skipping would mask a broken pipeline, so we panic.
pub fn require_artifacts_or_skip(test: &str) -> bool {
    if artifacts_available() {
        return true;
    }
    if std::env::var("MLI_REQUIRE_ARTIFACTS").as_deref() == Ok("1") {
        panic!(
            "{test}: MLI_REQUIRE_ARTIFACTS=1 but no artifacts at {} (run `make artifacts`)",
            Runtime::artifact_dir().display()
        );
    }
    eprintln!(
        "skipping {test}: AOT artifacts not found at {} (run `make artifacts` to enable)",
        Runtime::artifact_dir().display()
    );
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        let t = Tensor::F32(vec![1.0, 2.0, 3.0], vec![2, 2]);
        assert!(t.to_literal().is_err());
        let ok = Tensor::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert!(ok.to_literal().is_ok());
        assert_eq!(ok.dims(), vec![2, 2]);
        assert_eq!(Tensor::Scalar(0.5).dims(), Vec::<usize>::new());
    }
}
