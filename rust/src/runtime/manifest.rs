//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed from `artifacts/manifest.json`.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Shape+dtype of one tensor at the XLA boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Size in bytes (f32 only for now — all our artifacts are f32).
    pub fn byte_size(&self) -> usize {
        self.numel() * 4
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.get("dtype")?.as_str()?.to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub entry: String,
    pub variant: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// SGD minibatch block baked into `local_sgd_epoch` variants — the
    /// rust fallback must use the same block for bit-compatible results.
    pub block: Option<usize>,
}

impl ArtifactSpec {
    pub fn key(&self) -> String {
        format!("{}__{}", self.entry, self.variant)
    }
}

/// The full artifact registry.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let format = j.get("format")?.as_str()?;
        if format != "hlo-text" {
            return Err(Error::Runtime(format!(
                "unsupported artifact format '{format}' (expected hlo-text)"
            )));
        }
        let artifacts = j
            .get("artifacts")?
            .as_arr()?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    entry: a.get("entry")?.as_str()?.to_string(),
                    variant: a.get("variant")?.as_str()?.to_string(),
                    file: a.get("file")?.as_str()?.to_string(),
                    block: a
                        .get("block")
                        .ok()
                        .map(|b| b.as_usize())
                        .transpose()?,
                    inputs: a
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: a
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { artifacts })
    }

    pub fn find(&self, entry: &str, variant: &str) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.entry == entry && a.variant == variant)
    }

    /// All variants available for an entry point.
    pub fn variants(&self, entry: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.entry == entry).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text",
      "artifacts": [
        {"entry": "local_sgd_epoch", "variant": "small",
         "file": "local_sgd_epoch__small.hlo.txt",
         "inputs": [{"shape": [256, 64], "dtype": "float32"},
                    {"shape": [256], "dtype": "float32"},
                    {"shape": [64], "dtype": "float32"},
                    {"shape": [], "dtype": "float32"}],
         "outputs": [{"shape": [64], "dtype": "float32"}]}
      ]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("local_sgd_epoch", "small").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[0].shape, vec![256, 64]);
        assert_eq!(a.inputs[3].shape, Vec::<usize>::new());
        assert_eq!(a.outputs[0].numel(), 64);
        assert_eq!(a.inputs[0].byte_size(), 256 * 64 * 4);
        assert_eq!(a.key(), "local_sgd_epoch__small");
        assert_eq!(a.block, None);
    }

    #[test]
    fn block_field_parses_when_present() {
        let src = SAMPLE.replacen("{\"entry\"", "{\"block\": 64, \"entry\"", 1);
        let m = Manifest::parse(&src).unwrap();
        assert_eq!(m.artifacts[0].block, Some(64));
    }

    #[test]
    fn find_miss_and_variants() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find("nope", "small").is_none());
        assert!(m.find("local_sgd_epoch", "bench").is_none());
        assert_eq!(m.variants("local_sgd_epoch").len(), 1);
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format":"protobuf","artifacts":[]}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
