//! Fig. 2b + 2c: logistic regression **weak scaling** — execution time and
//! relative walltime for MLI vs VW vs MATLAB as data grows with machines
//! (paper: n ∝ machines, d = 160K, ~200 GB at 32 nodes; here n_part=2048,
//! d=512 per DESIGN.md §3 scaling).
//!
//! Expected shape (paper §IV-A): VW ~0.65-1x of MLI, never 2x faster;
//! MATLAB beaten at moderate scale and DNF (OOM) at the largest point.

use mli::algorithms::logreg::Backend;
use mli::bench_harness::{logreg_scaling, LogregBenchConfig, ScalingMode};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick {
        LogregBenchConfig {
            machines: vec![1, 2, 4],
            rows: 512,
            d: 64,
            iters: 3,
            backend: Backend::Xla,
            seed: 42,
            reps: 1,
            threads: 0,
        }
    } else {
        LogregBenchConfig {
            machines: vec![1, 2, 4, 8, 16, 32],
            rows: 2048,
            d: 512,
            iters: 10,
            backend: Backend::Xla,
            seed: 42,
            reps: 3,
            threads: 0,
        }
    };
    let table = logreg_scaling(&cfg, ScalingMode::Weak).expect("fig2 bench failed");
    println!("{}", table.to_markdown());
    table.save("fig2bc_logreg_weak").expect("save results");
    println!("saved results/fig2bc_logreg_weak.{{md,csv}}");
}
