//! Thread-scaling of the `exec` work-stealing pool on real training
//! workloads (issue acceptance: >= 1.8x real wall-clock speedup at 4
//! threads vs 1 on a multi-core host).
//!
//! Two stages are measured:
//!
//! 1. **raw pool** — a pure compute `ThreadPool::run` fan-out, the upper
//!    bound on what the executor can deliver;
//! 2. **logreg epochs** — end-to-end `LogisticRegression::train` (Rust
//!    backend, no AOT artifacts needed) with the pool attached to the
//!    `SimCluster`, i.e. the path `mli train --threads T` takes.
//!
//! Results are asserted bitwise-identical across thread counts before any
//! timing is reported. Simulated cluster time is also printed to show the
//! two-clock split: host threads shrink wall-clock only.

use std::time::Instant;

use mli::algorithms::logreg::{Backend, LogRegParams};
use mli::algorithms::{Algorithm, LogisticRegression};
use mli::cluster::SimCluster;
use mli::engine::EngineContext;
use mli::exec::ThreadPool;
use mli::metrics::Table;
use mli::optim::SgdParams;

/// Deterministic compute kernel: ~1e6 flops of f64 mixing per task.
fn crunch(seed: u64, rounds: usize) -> f64 {
    let mut x = seed as f64 + 1.0;
    for i in 0..rounds {
        x = (x * 1.000_000_19 + (i % 7) as f64).sqrt() * 1.000_41 + 0.5;
    }
    x
}

fn raw_pool_point(threads: usize, tasks: usize, rounds: usize) -> (f64, Vec<f64>) {
    let pool = ThreadPool::new(threads);
    let start = Instant::now();
    let out = pool.run(tasks, |i| crunch(i as u64, rounds));
    (start.elapsed().as_secs_f64() * 1e3, out)
}

fn logreg_point(threads: usize, parts: usize, iters: usize) -> (f64, mli::localmatrix::MLVector, f64) {
    let ctx = EngineContext::new();
    let data = mli::data::dense_gen::generate(&ctx, 8192, 64, parts, 7).expect("gen");
    let cluster = SimCluster::ec2(parts).with_executor(threads);
    let algo = LogisticRegression::new(LogRegParams {
        sgd: SgdParams {
            iters,
            ..Default::default()
        },
        backend: Backend::Rust,
    });
    let start = Instant::now();
    let model = algo.train(&data.table, &cluster).expect("train");
    (
        start.elapsed().as_secs_f64() * 1e3,
        model.weights,
        cluster.total_sim_seconds(),
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let thread_counts = [1usize, 2, 4, 8];
    let (tasks, rounds) = if quick { (32, 200_000) } else { (64, 2_000_000) };
    let (parts, iters) = if quick { (8, 4) } else { (16, 12) };
    let reps = if quick { 1 } else { 3 };

    // --- stage 1: raw pool fan-out ---------------------------------------
    let mut raw = Table::new(
        "exec scaling: raw pool fan-out",
        &["threads", "wall_ms", "speedup"],
    );
    let mut base_out: Option<Vec<f64>> = None;
    let mut base_ms: Option<f64> = None;
    let mut raw_speedup_at_4 = 0.0;
    for &t in &thread_counts {
        let times: Vec<f64> = (0..reps)
            .map(|_| {
                let (ms, out) = raw_pool_point(t, tasks, rounds);
                match &base_out {
                    None => base_out = Some(out),
                    Some(b) => assert_eq!(b, &out, "raw results diverged at {t} threads"),
                }
                ms
            })
            .collect();
        let ms = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let base = *base_ms.get_or_insert(ms);
        let speedup = base / ms;
        if t == 4 {
            raw_speedup_at_4 = speedup;
        }
        raw.row(vec![t.to_string(), format!("{ms:.1}"), format!("{speedup:.2}x")]);
    }
    println!("{}", raw.to_markdown());

    // --- stage 2: end-to-end logreg training ------------------------------
    let mut e2e = Table::new(
        "exec scaling: logreg train (Rust backend)",
        &["threads", "wall_ms", "speedup", "sim_s"],
    );
    let mut base_w: Option<mli::localmatrix::MLVector> = None;
    let mut base_sim: Option<f64> = None;
    let mut e2e_base_ms: Option<f64> = None;
    for &t in &thread_counts {
        let times: Vec<f64> = (0..reps)
            .map(|_| {
                let (ms, w, sim) = logreg_point(t, parts, iters);
                match &base_w {
                    None => base_w = Some(w),
                    Some(b) => assert_eq!(b, &w, "weights diverged at {t} threads"),
                }
                match base_sim {
                    None => base_sim = Some(sim),
                    Some(b) => assert_eq!(b, sim, "simulated time changed with threads"),
                }
                ms
            })
            .collect();
        let ms = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let base = *e2e_base_ms.get_or_insert(ms);
        e2e.row(vec![
            t.to_string(),
            format!("{ms:.1}"),
            format!("{:.2}x", base / ms),
            format!("{:.3}", base_sim.unwrap()),
        ]);
    }
    println!("{}", e2e.to_markdown());
    println!("(results bitwise-identical and simulated time constant across thread counts)");

    e2e.save("exec_scaling").expect("save results");
    println!("saved results/exec_scaling.{{md,csv}}");

    // acceptance gate from the issue: >= 1.8x at 4 threads on the raw
    // fan-out (the e2e number additionally includes serial driver work, so
    // the raw stage is the honest capability measurement). Only enforced
    // on hosts that actually have >= 4 cores.
    if !quick && ThreadPool::default_threads() >= 4 {
        assert!(
            raw_speedup_at_4 >= 1.8,
            "expected >=1.8x at 4 threads, measured {raw_speedup_at_4:.2}x"
        );
    }
}
