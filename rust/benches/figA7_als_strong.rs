//! Fig. A7 + A8: ALS **strong scaling** — fixed 9x-Netflix dataset,
//! machines 1..25.
//!
//! Expected shape (paper §IV-B): "MATLAB running out of memory before
//! completing on the 9x Netflix dataset, and GraphLab outperforming MLI
//! by less than a factor of 4x."

use mli::bench_harness::{als_scaling, AlsBenchConfig, ScalingMode};
use mli::data::netflix::NetflixConfig;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick {
        AlsBenchConfig {
            machines: vec![1, 4],
            strong_tile: 4,
            base: NetflixConfig {
                users: 256,
                items: 32,
                mean_nnz_per_user: 8,
                max_nnz_per_user: 20,
                ..Default::default()
            },
            iters: 2,
            use_xla: true,
            reps: 1,
            ..Default::default()
        }
    } else {
        AlsBenchConfig {
            strong_tile: 9,
            ..Default::default()
        }
    };
    let table = als_scaling(&cfg, ScalingMode::Strong).expect("figA7 bench failed");
    println!("{}", table.to_markdown());
    table.save("figA7A8_als_strong").expect("save results");
    println!("saved results/figA7A8_als_strong.{{md,csv}}");
}
