//! Ablation: gather/broadcast (MLI) vs AllReduce tree (VW) — the paper's
//! own open question ("We are unsure whether this is due to our simpler
//! (broadcast/gather) communication paradigm", §IV-A). Sweeps machines x
//! model size and reports the per-round aggregate time of each topology,
//! locating the crossover.
//!
//! Also ablates: local-SGD averaging frequency and dense-vs-CSR ALS
//! storage (DESIGN.md §6).

use mli::cluster::{CommTopology, NetworkModel};
use mli::data::netflix::{self, NetflixConfig};
use mli::localmatrix::{CsrMatrix, DenseMatrix};
use mli::metrics::Table;
use mli::util::timer;

fn comm_crossover() -> Table {
    let mut t = Table::new(
        "Ablation: star gather/broadcast vs AllReduce tree (s/round)",
        &["machines", "model_KB", "star_s", "tree_s", "winner"],
    );
    let net = NetworkModel::ec2_2013();
    for &m in &[2usize, 4, 8, 16, 32, 64] {
        for &kb in &[4u64, 64, 640, 2560] {
            let bytes = kb * 1024;
            let star = CommTopology::StarGatherBroadcast.allreduce_time(&net, m, bytes);
            let tree = CommTopology::AllReduceTree.allreduce_time(&net, m, bytes);
            t.row(vec![
                m.to_string(),
                kb.to_string(),
                format!("{star:.5}"),
                format!("{tree:.5}"),
                if star <= tree { "star" } else { "tree" }.into(),
            ]);
        }
    }
    t
}

fn dense_vs_csr_als_storage() -> Table {
    // the gather step of ALS per round: iterate each user's rated items.
    // CSR iterates nnz; dense scans the full row. This is the §IV-B
    // "support for CSR-compressed sparse representations" design choice.
    let mut t = Table::new(
        "Ablation: ALS ratings storage — CSR vs dense row scan",
        &["users", "items", "nnz", "csr_ms", "dense_ms", "speedup"],
    );
    for &(users, items) in &[(512usize, 64usize), (2048, 128), (4096, 256)] {
        let data = netflix::generate(&NetflixConfig {
            users,
            items,
            mean_nnz_per_user: 12,
            max_nnz_per_user: 25,
            ..Default::default()
        });
        let csr: &CsrMatrix = &data.ratings;
        let dense: DenseMatrix = csr.to_dense();
        let csr_s = timer::sample(1, 5, || {
            let mut acc = 0.0f64;
            for u in 0..users {
                for (i, r) in csr.row_iter(u) {
                    acc += r * (i as f64 + 1.0);
                }
            }
            acc
        });
        let dense_s = timer::sample(1, 5, || {
            let mut acc = 0.0f64;
            for u in 0..users {
                for (i, &r) in dense.row(u).iter().enumerate() {
                    if r != 0.0 {
                        acc += r * (i as f64 + 1.0);
                    }
                }
            }
            acc
        });
        let (c, d) = (mli::util::median(&csr_s), mli::util::median(&dense_s));
        t.row(vec![
            users.to_string(),
            items.to_string(),
            csr.nnz().to_string(),
            format!("{:.3}", c * 1e3),
            format!("{:.3}", d * 1e3),
            format!("{:.1}x", d / c.max(1e-12)),
        ]);
    }
    t
}

fn averaging_frequency() -> Table {
    // local-SGD averaging frequency: average every epoch (paper) vs every
    // minibatch (communication-heavy, Mahout-SGD-like). Time per data
    // pass = rounds * comm; quality explored in integration tests.
    let mut t = Table::new(
        "Ablation: parameter-averaging frequency (comm s per data pass)",
        &["machines", "avg_per", "allreduces", "comm_s"],
    );
    let net = NetworkModel::ec2_2013();
    let model_bytes = 512 * 4u64;
    let minibatches_per_epoch = 16u64;
    for &m in &[4usize, 16, 32] {
        for (name, count) in [("epoch", 1u64), ("minibatch", minibatches_per_epoch)] {
            let per = CommTopology::StarGatherBroadcast.allreduce_time(&net, m, model_bytes);
            t.row(vec![
                m.to_string(),
                name.into(),
                count.to_string(),
                format!("{:.5}", per * count as f64),
            ]);
        }
    }
    t
}

fn main() {
    for table in [comm_crossover(), dense_vs_csr_als_storage(), averaging_frequency()] {
        println!("{}", table.to_markdown());
        let stem = table
            .title
            .chars()
            .filter_map(|c| {
                if c.is_alphanumeric() {
                    Some(c.to_ascii_lowercase())
                } else if c == ' ' {
                    Some('_')
                } else {
                    None
                }
            })
            .take(40)
            .collect::<String>();
        table.save(&format!("ablation_{stem}")).expect("save");
    }
    println!("ablation_comm OK");
}
