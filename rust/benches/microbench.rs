//! Microbenchmarks for the L3 hot paths (DESIGN.md §7 Perf): engine op
//! dispatch, partition materialization, XLA execution overhead vs compute,
//! LocalMatrix matmul, CSR ops, and the GLM rust inner loop. These are the
//! profile targets of EXPERIMENTS.md §Perf.

use mli::engine::EngineContext;
use mli::localmatrix::{CsrMatrix, DenseMatrix};
use mli::metrics::Table;
use mli::runtime::{Runtime, Tensor};
use mli::util::rng::Rng;
use mli::util::timer;
use mli::util::median;

fn bench(name: &str, iters: usize, f: impl FnMut() -> ()) -> (String, f64) {
    let mut f = f;
    let samples = timer::sample(2, iters, || f());
    (name.to_string(), median(&samples))
}

fn main() {
    let mut t = Table::new("L3 microbenchmarks", &["name", "median", "unit"]);
    let mut rng = Rng::new(1);

    // engine: per-op dispatch overhead (map over tiny partitions)
    let ctx = EngineContext::new();
    let ds = ctx.parallelize((0..1024i64).collect(), 8);
    let (name, s) = bench("engine map+collect 1024 elems x 8 parts", 50, || {
        let _ = ds.map(|x| x + 1).collect().unwrap();
    });
    t.row(vec![name, format!("{:.1}", s * 1e6), "us".into()]);

    // engine: cached partition access
    let cached = ds.map(|x| x * 2).cache();
    cached.materialize().unwrap();
    let (name, s) = bench("engine cached partition fetch", 200, || {
        let _ = cached.partition(3).unwrap();
    });
    t.row(vec![name, format!("{:.2}", s * 1e9), "ns".into()]);

    // localmatrix: matmul 128x128
    let a = DenseMatrix::randn(128, 128, &mut rng);
    let b = DenseMatrix::randn(128, 128, &mut rng);
    let (name, s) = bench("dense matmul 128x128", 20, || {
        let _ = a.matmul(&b).unwrap();
    });
    t.row(vec![
        name,
        format!("{:.2}", 2.0 * 128f64.powi(3) / s / 1e9),
        "GFLOP/s".into(),
    ]);

    // CSR transpose
    let dense_src = DenseMatrix::randn(512, 256, &mut rng).map(|x| if x > 1.0 { x } else { 0.0 });
    let csr = CsrMatrix::from_dense(&dense_src);
    let (name, s) = bench("csr transpose 512x256", 50, || {
        let _ = csr.transpose();
    });
    t.row(vec![name, format!("{:.1}", s * 1e6), "us".into()]);

    // runtime: XLA dispatch overhead (tiny grad) vs real compute
    if let Ok(rt) = Runtime::global() {
        let n = 256;
        let d = 64;
        let x = Tensor::F32(vec![0.1; n * d], vec![n, d]);
        let y = Tensor::F32(vec![0.0; n], vec![n]);
        let w = Tensor::F32(vec![0.0; d], vec![d]);
        // warm the executable cache
        let _ = rt
            .execute("logreg_grad_batch", "small", &[x.clone(), y.clone(), w.clone()])
            .unwrap();
        let (name, s) = bench("XLA logreg_grad_batch small (256x64)", 50, || {
            let _ = rt
                .execute("logreg_grad_batch", "small", &[x.clone(), y.clone(), w.clone()])
                .unwrap();
        });
        t.row(vec![name, format!("{:.1}", s * 1e6), "us".into()]);

        let nb = 2048;
        let db = 512;
        let xb = Tensor::F32(vec![0.1; nb * db], vec![nb, db]);
        let yb = Tensor::F32(vec![0.0; nb], vec![nb]);
        let wb = Tensor::F32(vec![0.0; db], vec![db]);
        let lr = Tensor::Scalar(0.01);
        let _ = rt
            .execute(
                "local_sgd_epoch",
                "bench",
                &[xb.clone(), yb.clone(), wb.clone(), lr.clone()],
            )
            .unwrap();
        let (name, s) = bench("XLA local_sgd_epoch bench (2048x512)", 20, || {
            let _ = rt
                .execute(
                    "local_sgd_epoch",
                    "bench",
                    &[xb.clone(), yb.clone(), wb.clone(), lr.clone()],
                )
                .unwrap();
        });
        t.row(vec![name, format!("{:.2}", s * 1e3), "ms".into()]);
        // effective flops of the epoch: 2 passes (fwd+grad) * 2*n*d per block pass
        let flops = 4.0 * nb as f64 * db as f64;
        t.row(vec![
            "  -> epoch effective".into(),
            format!("{:.2}", flops / s / 1e9),
            "GFLOP/s".into(),
        ]);
    } else {
        eprintln!("warning: artifacts missing, skipping XLA microbenches");
    }

    println!("{}", t.to_markdown());
    t.save("microbench").expect("save");
    println!("microbench OK");
}
