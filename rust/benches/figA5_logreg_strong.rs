//! Fig. A5 + A6: logistic regression **strong scaling** — fixed dataset
//! (paper: 5% of the weak-scaling base), machines 1..32.
//!
//! Expected shape (paper §IV-A): "our solution actually outperforms VW in
//! raw time to train a model on a fixed dataset size when using 16 and 32
//! machines, and exhibits better strong scaling properties."

use mli::algorithms::logreg::Backend;
use mli::bench_harness::{logreg_scaling, LogregBenchConfig, ScalingMode};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick {
        LogregBenchConfig {
            machines: vec![1, 2, 4],
            rows: 1024,
            d: 64,
            iters: 3,
            backend: Backend::Xla,
            seed: 43,
            reps: 1,
            threads: 0,
        }
    } else {
        LogregBenchConfig {
            machines: vec![1, 2, 4, 8, 16, 32],
            rows: 8192, // total rows, fixed across machine counts
            d: 512,
            iters: 10,
            backend: Backend::Xla,
            seed: 43,
            reps: 3,
            threads: 0,
        }
    };
    let table = logreg_scaling(&cfg, ScalingMode::Strong).expect("figA5 bench failed");
    println!("{}", table.to_markdown());
    table.save("figA5A6_logreg_strong").expect("save results");
    println!("saved results/figA5A6_logreg_strong.{{md,csv}}");
}
