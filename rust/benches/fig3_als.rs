//! Fig. 3b + 3c: ALS **weak scaling** — Netflix-surrogate tiled by the
//! machine count (1x..25x), rank 10, lambda .01, 10 iterations (the
//! paper's exact hyper-parameters), MLI vs GraphLab vs Mahout vs MATLAB
//! vs MATLAB-mex.
//!
//! Expected shape (paper §IV-B): MLI within 4x of GraphLab with a similar
//! scaling pattern; Mahout slowest (HDFS per-iteration overhead); both
//! MATLABs OOM at 16x/25x.

use mli::bench_harness::{als_scaling, AlsBenchConfig, ScalingMode};
use mli::data::netflix::NetflixConfig;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick {
        AlsBenchConfig {
            machines: vec![1, 4],
            base: NetflixConfig {
                users: 256,
                items: 32,
                mean_nnz_per_user: 8,
                max_nnz_per_user: 20,
                ..Default::default()
            },
            iters: 2,
            use_xla: true,
            reps: 1,
            ..Default::default()
        }
    } else {
        AlsBenchConfig::default() // 1,4,9,16,25 machines; full base config
    };
    let table = als_scaling(&cfg, ScalingMode::Weak).expect("fig3 bench failed");
    println!("{}", table.to_markdown());
    table.save("fig3bc_als_weak").expect("save results");
    println!("saved results/fig3bc_als_weak.{{md,csv}}");
}
