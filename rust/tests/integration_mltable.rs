//! Integration: the full MLTable data-preparation story — CSV in,
//! relational + MapReduce ops, feature extraction, numeric cast, and the
//! Fig. A2 pipeline wired end to end.

use mli::algorithms::kmeans::{KMeans, KMeansParams};
use mli::algorithms::Algorithm;
use mli::cluster::SimCluster;
use mli::data::text_gen::{self, CorpusConfig};
use mli::engine::EngineContext;
use mli::features::{ngrams, standard_scale, tfidf};
use mli::localmatrix::LocalMatrix;
use mli::mltable::{csv_from_str, MLRow, Schema, Value};

#[test]
fn csv_to_model_pipeline() {
    let ctx = EngineContext::new();
    // semi-structured input: names, empties, mixed numerics
    let csv = "\
name,age,height,city
ann,34,1.62,berkeley
bob,,1.80,oakland
cat,29,,berkeley
dan,41,1.75,albany
eve,38,1.68,berkeley
";
    let t = csv_from_str(&ctx, csv, true, 2).unwrap();
    assert_eq!(t.num_rows().unwrap(), 5);

    // relational: filter + project
    let berkeley = t
        .filter(|r| r[3].as_str() == Some("berkeley"))
        .project_named(&["age", "height"])
        .unwrap();
    assert_eq!(berkeley.num_rows().unwrap(), 3);

    // empties coerce to 0.0 in the numeric cast
    let numeric = berkeley.to_numeric().unwrap();
    let m = numeric.collect_matrix().unwrap();
    assert_eq!(m.rows, 3);
    assert_eq!(m.get(1, 1), 0.0); // cat's missing height

    // standardized features have mean ~0
    let scaled = standard_scale(&numeric, 0).unwrap();
    let sm = scaled.collect_matrix().unwrap();
    let col0: f64 = (0..3).map(|r| sm.get(r, 0)).sum();
    assert!(col0.abs() < 1e-9);
}

#[test]
fn reduce_by_key_aggregation_report() {
    let ctx = EngineContext::new();
    let csv = "\
city,sales
berkeley,10
oakland,5
berkeley,7
albany,2
oakland,3
";
    let t = csv_from_str(&ctx, csv, true, 2).unwrap();
    let per_city = t
        .reduce_by_key(0, |a, b| {
            MLRow::new(vec![
                a[0].clone(),
                Value::Int(a[1].as_int().unwrap() + b[1].as_int().unwrap()),
            ])
        })
        .unwrap();
    let mut rows = per_city.collect().unwrap();
    rows.sort_by_key(|r| r[0].as_str().unwrap().to_string());
    assert_eq!(rows.len(), 3);
    assert_eq!(rows[1][0].as_str().unwrap(), "berkeley");
    assert_eq!(rows[1][1].as_int().unwrap(), 17);
}

#[test]
fn matrix_batch_map_distributed_gram() {
    // distributed X^T X via per-partition grams + driver-side reduce —
    // the canonical LocalMatrix "operate locally, combine globally"
    // pattern of §III-B.
    let ctx = EngineContext::new();
    let rows: Vec<MLRow> = (0..40)
        .map(|i| MLRow::from_scalars(&[(i % 7) as f64, (i % 3) as f64]))
        .collect();
    let t = mli::mltable::MLTable::from_rows(&ctx, rows.clone(), Schema::numeric(2), 4).unwrap();
    let nt = t.to_numeric().unwrap();

    let grams = nt
        .matrix_batch_map(|_, part| {
            let pt = part.transpose();
            pt.times(part)
        })
        .unwrap();
    // each partition contributed a 2x2 gram; stack is (4*2) x 2
    assert_eq!(grams.num_rows().unwrap(), 8);
    let stacked = grams.collect_matrix().unwrap();
    let mut total = LocalMatrix::zeros(2, 2);
    for p in 0..4 {
        let block = LocalMatrix::dense(
            2,
            2,
            vec![
                stacked.get(p * 2, 0),
                stacked.get(p * 2, 1),
                stacked.get(p * 2 + 1, 0),
                stacked.get(p * 2 + 1, 1),
            ],
        )
        .unwrap();
        total = total.try_add(&block).unwrap();
    }
    // reference: full X^T X
    let full = nt.collect_matrix().unwrap();
    let x = LocalMatrix::Dense(full);
    let want = x.transpose().times(&x).unwrap();
    for r in 0..2 {
        for c in 0..2 {
            assert!((total.get(r, c) - want.get(r, c)).abs() < 1e-9);
        }
    }
}

#[test]
fn fig_a2_pipeline_text_to_clusters() {
    // the paper's flagship data-prep example, end to end
    let ctx = EngineContext::new();
    let (raw, truth) = text_gen::generate_table(
        &ctx,
        &CorpusConfig {
            docs: 120,
            topics: 3,
            vocab: 300,
            words_per_doc: 50,
            seed: 2,
        },
        4,
    )
    .unwrap();
    let grams = ngrams(&raw, 0, 1, 256).unwrap();
    let feats = tfidf(&grams.table).unwrap();
    let model = KMeans::new(KMeansParams {
        k: 3,
        iters: 10,
        seed: 5,
        ..Default::default()
    })
    .train(&feats, &SimCluster::ec2(4))
    .unwrap();
    // purity above chance (1/3)
    let assignments: Vec<usize> = feats
        .collect_vectors()
        .unwrap()
        .iter()
        .map(|v| {
            use mli::algorithms::Model;
            model.predict(v).unwrap() as usize
        })
        .collect();
    let mut counts = vec![vec![0usize; 3]; 3];
    for (a, &t) in assignments.iter().zip(&truth) {
        counts[*a][t] += 1;
    }
    let purity: usize = counts.iter().map(|r| *r.iter().max().unwrap()).sum();
    assert!(
        purity as f64 / truth.len() as f64 > 0.5,
        "purity {}",
        purity as f64 / truth.len() as f64
    );
}
