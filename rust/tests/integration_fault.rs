//! Node-level fault-tolerance integration tests: lineage recovery under a
//! multi-threaded executor, mid-job machine kills with a checkpointed
//! input, fail-stop on total cluster loss, and the `mli chaos` CLI.

use std::sync::Arc;

use mli::algorithms::logreg::{Backend, LogRegParams};
use mli::algorithms::{Algorithm, LogisticRegression};
use mli::data::dense_gen;
use mli::prelude::*;

/// Build an 8-partition cached dataset, lose partitions 1/3/6, and force
/// recovery through a full action. Returns (data, recoveries, losses).
fn run_lineage_recovery(threads: Option<usize>) -> (Vec<i64>, u64, usize) {
    let ctx = match threads {
        Some(t) => EngineContext::new().with_executor(t),
        None => EngineContext::new(),
    };
    let d = ctx
        .parallelize((0..400).collect::<Vec<i64>>(), 8)
        .map(|x| x * 7 + 1)
        .cache();
    d.materialize().unwrap();
    for p in [1, 3, 6] {
        d.invalidate_partition(p);
        assert!(!d.is_cached(p));
    }
    let out = d.collect().unwrap();
    (out, ctx.stats().2, ctx.failures.losses())
}

#[test]
fn lineage_recovery_on_pool_bitwise_matches_serial() {
    let (serial, serial_rec, serial_loss) = run_lineage_recovery(None);
    let (par, par_rec, par_loss) = run_lineage_recovery(Some(4));
    assert_eq!(serial, par, "recovered results must be bitwise identical");
    assert_eq!(serial, (0..400).map(|x| x * 7 + 1).collect::<Vec<_>>());
    assert_eq!((serial_rec, serial_loss), (3, 3));
    assert_eq!((par_rec, par_loss), (3, 3));
}

#[test]
fn mid_job_kill_with_checkpoint_is_bitwise_identical_to_failure_free() {
    // Acceptance path: 8 machines, machine 2 crashes at round 3 mid-job
    // (back after 2 rounds); the cached input is bound to the cluster and
    // checkpointed, so its lost partition recovers from the snapshot. The
    // trained weights must be bitwise-identical to the failure-free run.
    let train = |plan: Option<Arc<FaultPlan>>| {
        let ctx = EngineContext::new();
        let data = dense_gen::generate(&ctx, 1024, 16, 8, 5).unwrap();
        let table = data.table.cache();
        let mut c = SimCluster::ec2(8);
        if let Some(p) = plan {
            c = c.with_faults(p);
        }
        table.dataset().bind_cluster(&c);
        table.dataset().checkpoint(&c).unwrap();
        assert!(table.dataset().is_checkpointed());
        let algo = LogisticRegression::new(LogRegParams {
            sgd: SgdParams {
                iters: 6,
                ..Default::default()
            },
            backend: Backend::Rust,
        });
        let model = algo.train(&table, &c).unwrap();
        assert_eq!(table.num_rows().unwrap(), 1024, "table recovers fully");
        (
            model.weights,
            c.fault_stats(),
            ctx.checkpoint_hits(),
            ctx.stats().2,
        )
    };

    let (base_w, base_faults, _, _) = train(None);
    assert_eq!(base_faults, (0, 0));

    let plan = Arc::new(FaultPlan::new());
    plan.kill_at(3, 2, FaultKind::Crash { restart_after: 2 });
    let (w, faults, ck_hits, recoveries) = train(Some(plan));
    assert_eq!(w, base_w, "faulted run must match failure-free bitwise");
    assert_eq!(faults, (1, 1), "one kill, one restart");
    assert!(ck_hits >= 1, "recovery must read the checkpoint");
    assert!(recoveries >= 1, "lost partition counted as recovered");
}

#[test]
fn permanent_kill_all_fails_with_typed_fault_recovery() {
    // Killing every machine permanently mid-job must fail-stop with
    // Error::FaultRecovery — no panic, no hang.
    let ctx = EngineContext::new();
    let data = dense_gen::generate(&ctx, 256, 8, 4, 3).unwrap();
    let plan = Arc::new(FaultPlan::new());
    for m in 0..4 {
        plan.kill_at(2, m, FaultKind::Permanent);
    }
    let c = SimCluster::ec2(4).with_faults(plan);
    let algo = LogisticRegression::new(LogRegParams {
        sgd: SgdParams {
            iters: 5,
            ..Default::default()
        },
        backend: Backend::Rust,
    });
    let err = algo.train(&data.table, &c).unwrap_err();
    assert!(err.is_fault_recovery(), "expected FaultRecovery, got: {err}");
    assert_eq!(c.num_alive(), 0);
}

#[test]
fn chaos_cli_smoke_logreg() {
    // `mli chaos` end-to-end at CI scale: seeded random kills with
    // restarts; the subcommand itself asserts baseline equivalence and
    // returns Err (-> test failure) on any divergence.
    use mli::util::cli::Args;
    let argv: Vec<String> = [
        "chaos",
        "--algo",
        "logreg",
        "--machines",
        "8",
        "--iters",
        "4",
        "--seed",
        "7",
        "--kill-rate",
        "0.1",
        "--restart-after",
        "2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    mli::run_cli(Args::parse(&argv)).unwrap();
}
