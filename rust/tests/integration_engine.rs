//! Integration: engine + cluster — multi-stage dataflows, fault
//! injection with recovery mid-pipeline, and simulated-time accounting
//! across a full training-shaped loop.

use mli::cluster::{CommTopology, SimCluster};
use mli::engine::EngineContext;

#[test]
fn multi_stage_pipeline_with_shuffles() {
    let ctx = EngineContext::new();
    // word-count-like pipeline over synthetic records
    let records = ctx.parallelize(
        (0..1000).map(|i| format!("user{} action{}", i % 37, i % 5)).collect::<Vec<_>>(),
        8,
    );
    let counts = records
        .flat_map(|line| line.split(' ').map(|s| s.to_string()).collect::<Vec<_>>())
        .map(|tok| (tok.clone(), 1u64))
        .reduce_by_key(|a, b| a + b);
    let total: u64 = counts.collect().unwrap().iter().map(|(_, c)| c).sum();
    assert_eq!(total, 2000); // 2 tokens per record

    // join the counts with a lookup table
    let lookup = ctx.parallelize(
        (0..37).map(|i| (format!("user{i}"), i)).collect::<Vec<_>>(),
        4,
    );
    let joined = counts.join(&lookup);
    let rows = joined.collect().unwrap();
    assert_eq!(rows.len(), 37);
    for (k, (count, id)) in rows {
        assert!(k == format!("user{id}"));
        // 1000 records over 37 users: 27 or 28 occurrences
        assert!(count == 27 || count == 28, "{k}: {count}");
    }
}

#[test]
fn recovery_during_iterative_computation() {
    // an iterative job that loses cached partitions midway and recovers
    // (the paper's §IV motivation for Spark's lineage)
    let ctx = EngineContext::new();
    let base = ctx
        .parallelize((0..400i64).collect::<Vec<_>>(), 8)
        .map(|x| x * 3)
        .cache();
    base.materialize().unwrap();

    let mut acc = 0i64;
    for round in 0..6 {
        if round == 2 {
            base.invalidate_partition(1);
            base.invalidate_partition(5);
        }
        if round == 4 {
            base.invalidate_partition(1); // lose the same one again
        }
        acc += base.dataset_sum();
    }
    let expected: i64 = (0..400).map(|x| x * 3).sum::<i64>() * 6;
    assert_eq!(acc, expected);
    let (_, _, recoveries) = ctx.stats();
    assert_eq!(recoveries, 3);
}

trait SumExt {
    fn dataset_sum(&self) -> i64;
}

impl SumExt for mli::engine::Dataset<i64> {
    fn dataset_sum(&self) -> i64 {
        self.reduce(|a, b| a + b).unwrap().unwrap_or(0)
    }
}

#[test]
fn transient_task_failures_do_not_corrupt_results() {
    let ctx = EngineContext::new();
    let d = ctx.parallelize((0..100i64).collect::<Vec<_>>(), 4).map(|x| x + 1);
    // partitions 0 and 2 fail twice each before succeeding
    ctx.failures.fail_times(d.id(), 0, 2);
    ctx.failures.fail_times(d.id(), 2, 2);
    let out = d.collect().unwrap();
    assert_eq!(out, (1..=100).collect::<Vec<_>>());
}

#[test]
fn simulated_time_for_training_shaped_loop() {
    // 4 machines, 8 partitions, 5 rounds of (compute + star allreduce):
    // verify the ledger composes the way the model says it should.
    let cluster = SimCluster::ec2(4);
    let model_bytes = 512 * 4;
    for _round in 0..5 {
        cluster.begin_round();
        for p in 0..8 {
            let m = cluster.machine_of(p);
            cluster.charge_compute(m, 0.1); // 2 tasks/machine
        }
        cluster.charge_allreduce(CommTopology::StarGatherBroadcast, model_bytes);
        cluster.end_round();
    }
    assert_eq!(cluster.rounds(), 5);
    // per round: 2 tasks x 0.1s on 8 cores -> 0.2/2 = 0.1s + comm
    let t = cluster.total_sim_seconds();
    assert!(t > 0.5 && t < 0.6, "sim time {t}");
    // comm scales with machines: same loop on 16 machines costs more comm
    let big = SimCluster::ec2(16);
    for _ in 0..5 {
        big.begin_round();
        for p in 0..16 {
            big.charge_compute(big.machine_of(p), 0.0);
        }
        big.charge_allreduce(CommTopology::StarGatherBroadcast, model_bytes);
        big.end_round();
    }
    assert!(big.total_comm_seconds() > cluster.total_comm_seconds());
}

#[test]
fn oom_surfaces_as_typed_error() {
    let cluster = SimCluster::new(
        2,
        mli::cluster::MachineSpec::default().with_mem_bytes(1_000),
        mli::cluster::NetworkModel::ec2_2013(),
    );
    cluster.alloc(0, 500).unwrap();
    cluster.alloc(1, 900).unwrap();
    let err = cluster.alloc(1, 200).unwrap_err();
    assert!(err.is_oom());
    // machine 0 still has room
    assert!(cluster.alloc(0, 400).is_ok());
}
