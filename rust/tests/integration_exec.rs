//! Integration tests for the `exec` work-stealing executor under the
//! dataflow engine: bitwise determinism across thread counts, work
//! stealing under skew, and failure injection racing parallel evaluation.

use std::sync::Arc;

use mli::engine::EngineContext;
use mli::exec::ThreadPool;

/// The same map + reduce_by_key pipeline, evaluated at a given thread
/// count (0 = no executor, serial).
fn kv_pipeline(threads: usize) -> Vec<(usize, f64)> {
    let ctx = if threads == 0 {
        EngineContext::new()
    } else {
        EngineContext::new().with_executor(threads)
    };
    let d = ctx.parallelize((0..1000i64).collect::<Vec<_>>(), 16);
    // floats chosen so accumulation order would show: 1/(i+1) sums are
    // not associative in f64
    d.map(|i| ((i % 17) as usize, 1.0 / (i as f64 + 1.0)))
        .reduce_by_key(|a, b| a + b)
        .collect()
        .unwrap()
}

#[test]
fn map_reduce_by_key_identical_across_thread_counts() {
    let serial = kv_pipeline(0);
    assert_eq!(serial.len(), 17);
    for threads in [1, 2, 8] {
        let par = kv_pipeline(threads);
        // bitwise equality: same keys, same order, same f64 bits
        assert_eq!(serial, par, "diverged at {threads} threads");
    }
}

#[test]
fn collect_and_count_identical_across_thread_counts() {
    let run = |threads: usize| {
        let ctx = EngineContext::new().with_executor(threads);
        let d = ctx
            .parallelize((0..500i64).collect::<Vec<_>>(), 9)
            .map(|x| x as f64 * 0.3)
            .filter(|x| *x < 120.0);
        (d.collect().unwrap(), d.count().unwrap())
    };
    let (c1, n1) = run(1);
    for threads in [2, 8] {
        let (c, n) = run(threads);
        assert_eq!(c1, c);
        assert_eq!(n1, n);
    }
}

#[test]
fn work_stealing_under_skewed_task_sizes() {
    // Round-robin submission puts every third task in worker 0's deque;
    // making exactly those tasks heavy (20ms vs ~0) leaves worker 0 with
    // ~440ms of queued work while the other two workers go idle almost
    // immediately — the stage can only finish on time if they steal from
    // worker 0's queue, so steals are guaranteed, not timing-dependent.
    let pool = ThreadPool::new(3);
    let out = pool.run(64, |i| {
        if i % 3 == 0 {
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        i * 2
    });
    assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    let stats = pool.worker_stats();
    let tasks: u64 = stats.iter().map(|s| s.tasks).sum();
    let steals: u64 = stats.iter().map(|s| s.steals).sum();
    assert_eq!(tasks, 64);
    assert!(steals > 0, "expected steals under skew, stats: {stats:?}");
}

#[test]
fn skewed_partitions_balance_across_workers() {
    // Dataset-level skew: partition 0 carries ~16x the rows of the rest.
    // With a pool attached the stage still completes and every row is
    // accounted for exactly once.
    let ctx = EngineContext::new().with_executor(4);
    let mut rows: Vec<(usize, u64)> = Vec::new();
    for p in 0..8usize {
        let n = if p == 0 { 1600 } else { 100 };
        for i in 0..n {
            rows.push((p, i as u64));
        }
    }
    let expected: u64 = rows.iter().map(|(_, v)| v).sum();
    let d = ctx.parallelize(rows, 8);
    let total: u64 = d
        .map(|(_, v)| v)
        .collect()
        .unwrap()
        .into_iter()
        .sum();
    assert_eq!(total, expected);
    let pool = ctx.executor().unwrap();
    let worked: usize = pool
        .worker_stats()
        .iter()
        .filter(|s| s.tasks > 0)
        .count();
    assert!(worked >= 1);
}

#[test]
fn failure_injection_retries_race_parallel_evaluation() {
    let ctx = EngineContext::new().with_executor(4);
    let d = ctx
        .parallelize((0..400i64).collect::<Vec<_>>(), 8)
        .map(|x| x * 3);
    // 3 injected failures per partition stays under the 4-attempt budget;
    // retries happen concurrently on pool workers
    for p in 0..8 {
        ctx.failures.fail_times(d.id(), p, 3);
    }
    let got = d.collect().unwrap();
    assert_eq!(got, (0..400i64).map(|x| x * 3).collect::<Vec<_>>());
    let (tasks, _, _) = ctx.stats();
    // every partition burned 3 failed attempts + 1 success
    assert!(tasks >= 8 * 4, "expected retried attempts, saw {tasks}");

    // exhausting the budget fails the action even in parallel
    let d2 = ctx.parallelize(vec![1, 2, 3], 3).map(|x| x + 1);
    ctx.failures.fail_times(d2.id(), 1, 99);
    assert!(d2.collect().is_err());
}

#[test]
fn lineage_recovery_with_executor_attached() {
    let ctx = EngineContext::new().with_executor(4);
    let d = ctx
        .parallelize((0..240i64).collect::<Vec<_>>(), 6)
        .map(|x| x * x)
        .cache();
    let before = d.collect().unwrap();
    assert!(d.is_cached(3));
    d.invalidate_partition(2);
    d.invalidate_partition(4);
    let after = d.collect().unwrap();
    assert_eq!(before, after);
    let (_, _, recoveries) = ctx.stats();
    assert_eq!(recoveries, 2);
}

#[test]
fn panicking_taskset_errors_and_pool_runs_subsequent_stages() {
    // One bad task fails its TaskSet with a typed error naming the stage;
    // the pool itself survives and executes later TaskSets normally.
    let pool = ThreadPool::new(2);
    let err = mli::exec::TaskSet::new("boom", 8)
        .try_run(Some(&pool), |i| {
            if i == 5 {
                panic!("task 5 exploded");
            }
            i * 10
        })
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("boom"), "missing stage label: {msg}");
    assert!(msg.contains("task 5 exploded"), "missing payload: {msg}");
    let ok = mli::exec::TaskSet::new("after", 16)
        .try_run(Some(&pool), |i| i + 1)
        .unwrap();
    assert_eq!(ok, (1..=16).collect::<Vec<_>>());
}

#[test]
fn tracing_on_preserves_bitwise_determinism() {
    // The acceptance contract: enabling the tracer must not perturb
    // results — same f64 bits at 1, 2, and 8 threads as the untraced
    // serial run.
    use mli::trace::Tracer;
    let serial = kv_pipeline(0);
    for threads in [1, 2, 8] {
        let (tracer, sink) = Tracer::recording();
        let ctx = EngineContext::new().with_executor(threads);
        ctx.set_tracer(tracer);
        let d = ctx.parallelize((0..1000i64).collect::<Vec<_>>(), 16);
        let got = d
            .map(|i| ((i % 17) as usize, 1.0 / (i as f64 + 1.0)))
            .reduce_by_key(|a, b| a + b)
            .collect()
            .unwrap();
        assert_eq!(serial, got, "diverged at {threads} threads with tracing on");
        assert!(sink.span_count() > 0, "no spans recorded at {threads} threads");
    }
}

#[test]
fn exec_bench_trace_out_emits_chrome_trace_with_worker_counters() {
    // End-to-end through the CLI: `mli exec-bench --trace-out F` must
    // write valid Chrome-trace JSON whose per-worker park and
    // steal-attempt counters are nonzero at 2 threads.
    use mli::util::cli::Args;
    use mli::util::json::Json;
    let path = std::env::temp_dir().join("mli_exec_bench_trace.json");
    let path_s = path.to_str().unwrap().to_string();
    let argv: Vec<String> = [
        "exec-bench",
        "--threads",
        "2",
        "--partitions",
        "8",
        "--n",
        "2048",
        "--d",
        "16",
        "--iters",
        "6",
        "--trace-out",
        &path_s,
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    mli::run_cli(Args::parse(&argv)).unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let json = Json::parse(&text).unwrap();
    let events = json.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "empty trace");
    let counter_value = |name: &str| -> f64 {
        events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(|p| p.as_str()).ok() == Some("C")
                    && e.get("name").and_then(|n| n.as_str()).ok() == Some(name)
            })
            .filter_map(|e| e.get("args").and_then(|a| a.get("value")?.as_f64()).ok())
            .next_back()
            .unwrap_or(0.0)
    };
    assert!(
        counter_value("exec.worker0.parks") > 0.0,
        "worker 0 never parked"
    );
    assert!(
        counter_value("exec.worker0.steal_attempts") > 0.0,
        "worker 0 never attempted a steal"
    );
    let has_task_span = events.iter().any(|e| {
        e.get("ph").and_then(|p| p.as_str()).ok() == Some("X")
            && e.get("name")
                .and_then(|n| n.as_str())
                .map(|n| n.starts_with("task:"))
                .unwrap_or(false)
    });
    assert!(has_task_span, "no task spans in trace");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn shared_pool_between_context_and_cluster() {
    // SimCluster and EngineContext can share one pool; stats accumulate
    // in the same place.
    let cluster = Arc::new(mli::cluster::SimCluster::ec2(4).with_executor(2));
    let pool = cluster.pool().unwrap();
    let ctx = EngineContext::new();
    ctx.set_executor(Some(pool.clone()));
    let d = ctx.parallelize((0..100i64).collect::<Vec<_>>(), 4);
    assert_eq!(d.count().unwrap(), 100);
    let tasks: u64 = pool.worker_stats().iter().map(|s| s.tasks).sum();
    assert!(tasks >= 4);
}
