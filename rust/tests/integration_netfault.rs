//! Network fault-tolerance integration tests: shuffle determinism under
//! lossy and duplicating links, end-to-end training bitwise-equivalence
//! under drop/degrade/partition windows, partition policies, typed
//! exhaustion, and the `mli chaos --net` CLI.

use std::sync::Arc;

use mli::algorithms::logreg::{Backend, LogRegParams};
use mli::algorithms::{Algorithm, LogisticRegression};
use mli::data::dense_gen;
use mli::engine::shuffle::{
    shuffle_group, shuffle_group_on, shuffle_reduce, shuffle_reduce_on,
};
use mli::prelude::*;

/// A word-count-shaped pair dataset: 400 keys spread over 8 partitions,
/// with repeated keys so reduce actually merges.
fn pairs(ctx: &EngineContext) -> mli::engine::Dataset<(u32, u64)> {
    ctx.parallelize(
        (0..400u32).map(|i| (i % 37, 1u64)).collect::<Vec<_>>(),
        8,
    )
}

fn lossy_cluster(drop_p: f64, dup_p: f64) -> SimCluster {
    let plan = NetFaultPlan::new(11);
    // windows open at round 0 and stay open: every shuffle round is faulted
    if drop_p > 0.0 {
        plan.window(0, 100, NetFaultKind::Drop { machine: None, prob: drop_p });
    }
    if dup_p > 0.0 {
        plan.window(0, 100, NetFaultKind::Duplicate { machine: None, prob: dup_p });
    }
    SimCluster::ec2(4).with_netfaults(Arc::new(plan))
}

#[test]
fn shuffle_reduce_is_bitwise_deterministic_under_drops_and_dups() {
    let ctx = EngineContext::new();
    let base = shuffle_reduce(&pairs(&ctx), 8, &|a, b| a + b).unwrap();

    let c = lossy_cluster(0.4, 0.3);
    let faulted = shuffle_reduce_on(&pairs(&ctx), 8, &|a, b| a + b, Some(&c)).unwrap();
    assert_eq!(faulted, base, "lossy links must not change shuffle output");

    let stats = c.net_stats();
    assert!(stats.sends > 0, "bucket transfers must route through the fault layer");
    assert!(stats.drops > 0, "drop window must cost deliveries: {stats:?}");
    assert!(stats.retries > 0, "drops must be retried: {stats:?}");
    assert!(stats.dups > 0, "duplicate window must fire: {stats:?}");
    assert!(c.total_comm_seconds() > 0.0, "retries charge simulated comm time");

    // identical seed + schedule => identical accounting, bit for bit
    let c2 = lossy_cluster(0.4, 0.3);
    let again = shuffle_reduce_on(&pairs(&ctx), 8, &|a, b| a + b, Some(&c2)).unwrap();
    assert_eq!(again, base);
    assert_eq!(c2.net_stats(), stats, "replay must be deterministic");
    assert_eq!(c2.total_comm_seconds().to_bits(), c.total_comm_seconds().to_bits());
}

#[test]
fn shuffle_group_is_bitwise_deterministic_under_drops_and_dups() {
    let ctx = EngineContext::new();
    let base = shuffle_group(&pairs(&ctx), 8).unwrap();

    let c = lossy_cluster(0.4, 0.3);
    let faulted = shuffle_group_on(&pairs(&ctx), 8, Some(&c)).unwrap();
    assert_eq!(faulted, base, "grouping must be unchanged under link faults");
    let stats = c.net_stats();
    assert!(stats.drops > 0 && stats.retries > 0 && stats.dups > 0, "{stats:?}");
}

#[test]
fn healthy_links_charge_exactly_like_the_analytic_path() {
    // With no fault plan, shuffle_*_on must reproduce the failure-free
    // ledger bit-for-bit (the fault layer only activates inside windows).
    let ctx = EngineContext::new();
    let c_on = SimCluster::ec2(4);
    let c_plan = SimCluster::ec2(4).with_netfaults(Arc::new(NetFaultPlan::new(3)));
    let a = shuffle_reduce_on(&pairs(&ctx), 8, &|a, b| a + b, Some(&c_on)).unwrap();
    let b = shuffle_reduce_on(&pairs(&ctx), 8, &|a, b| a + b, Some(&c_plan)).unwrap();
    assert_eq!(a, b);
    assert_eq!(
        c_on.total_comm_seconds().to_bits(),
        c_plan.total_comm_seconds().to_bits(),
        "an empty plan must not perturb the ledger"
    );
    assert_eq!(c_plan.net_stats(), NetStats::default());
}

fn train_logreg(
    plan: Option<Arc<NetFaultPlan>>,
    policy: PartitionPolicy,
) -> (MLVector, f64, NetStats) {
    let ctx = EngineContext::new();
    let data = dense_gen::generate(&ctx, 1024, 16, 8, 5).unwrap();
    let mut c = SimCluster::ec2(8).with_partition_policy(policy);
    if let Some(p) = plan {
        c = c.with_netfaults(p);
    }
    let algo = LogisticRegression::new(LogRegParams {
        sgd: SgdParams {
            iters: 6,
            ..Default::default()
        },
        backend: Backend::Rust,
    });
    let model = algo.train(&data.table, &c).unwrap();
    (model.weights, c.total_sim_seconds(), c.net_stats())
}

#[test]
fn training_under_lossy_degraded_partitioned_links_is_bitwise_identical() {
    let (base_w, base_sim, base_stats) = train_logreg(None, PartitionPolicy::WaitOut);
    assert_eq!(base_stats, NetStats::default());

    let plan = Arc::new(NetFaultPlan::new(23));
    plan.window(1, 2, NetFaultKind::Drop { machine: None, prob: 0.3 });
    plan.window(2, 1, NetFaultKind::Degrade { machine: Some(1), latency_x: 8.0, bandwidth_div: 4.0 });
    plan.window(3, 2, NetFaultKind::Partition { minority: vec![6, 7] });
    let (w, sim_s, stats) = train_logreg(Some(plan), PartitionPolicy::WaitOut);

    assert_eq!(w, base_w, "network faults must move time, never values");
    assert!(stats.drops > 0 && stats.retries > 0, "{stats:?}");
    assert!(stats.partition_waits > 0, "WaitOut must wait out the cut: {stats:?}");
    assert_eq!(stats.replacements, 0, "WaitOut never re-places work");
    assert!(
        sim_s > base_sim,
        "faulted run must cost simulated time: {sim_s} vs {base_sim}"
    );
}

#[test]
fn replace_policy_reroutes_placement_and_stays_bitwise_identical() {
    let (base_w, _, _) = train_logreg(None, PartitionPolicy::Replace);
    let plan = Arc::new(NetFaultPlan::new(29));
    plan.window(2, 2, NetFaultKind::Partition { minority: vec![6, 7] });
    let (w, _, stats) = train_logreg(Some(plan), PartitionPolicy::Replace);
    assert_eq!(w, base_w, "re-placement must not change merge order or values");
    assert!(
        stats.replacements > 0,
        "partitions resident on cut machines must re-place: {stats:?}"
    );
    assert_eq!(stats.partition_waits, 0, "Replace never waits out the cut");
}

#[test]
fn total_loss_surfaces_as_typed_net_fault() {
    // A link that drops everything exhausts the per-message retry budget
    // and fails the job with Error::NetFault — no panic, no hang.
    let ctx = EngineContext::new();
    let data = dense_gen::generate(&ctx, 256, 8, 4, 3).unwrap();
    let plan = Arc::new(NetFaultPlan::new(31));
    plan.window(0, 100, NetFaultKind::Drop { machine: None, prob: 1.0 });
    let c = SimCluster::ec2(4).with_netfaults(plan);
    let algo = LogisticRegression::new(LogRegParams {
        sgd: SgdParams {
            iters: 3,
            ..Default::default()
        },
        backend: Backend::Rust,
    });
    let err = algo.train(&data.table, &c).unwrap_err();
    assert!(err.is_net_fault(), "expected NetFault, got: {err}");
    let stats = c.net_stats();
    assert!(stats.drops > stats.retries, "final attempt is a drop, not a retry");
}

#[test]
fn chaos_cli_smoke_net() {
    // `mli chaos --net` end-to-end at CI scale: the subcommand itself
    // asserts bitwise baseline equivalence and nonzero fault activity,
    // returning Err (-> test failure) otherwise.
    use mli::util::cli::Args;
    let trace = std::env::temp_dir().join("mli-test-chaos-net-trace.json");
    let argv: Vec<String> = [
        "chaos",
        "--net",
        "--machines",
        "8",
        "--iters",
        "4",
        "--seed",
        "7",
        "--drop-rate",
        "0.25",
        "--trace-out",
        trace.to_str().unwrap(),
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    mli::run_cli(Args::parse(&argv)).unwrap();
    let json = std::fs::read_to_string(&trace).unwrap();
    assert!(json.contains("net.drops"), "trace export must carry net counters");
    let _ = std::fs::remove_file(&trace);
}
