//! Integration tests for `mli lint`: the checker must pass on its own
//! repository (self-scan), fail `--deny` on a planted violation, and
//! emit a parseable JSON report.

use std::fs;
use std::path::PathBuf;

use mli::error::Error;
use mli::lint::{self, LintConfig};
use mli::util::cli::Args;
use mli::util::json::Json;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn self_scan_is_clean() {
    let report = lint::run(&LintConfig::all(repo_root())).expect("lint run");
    assert!(
        report.clean(),
        "mli lint found violations in its own tree:\n{}",
        report.to_text()
    );
    // sanity: the walk really covered the tree, and the documented
    // allow-sites were honored rather than silently missed
    assert!(report.files > 50, "only scanned {} files", report.files);
    assert!(
        report.suppressed > 0,
        "expected the annotated allow() sites to register as suppressed"
    );
}

#[test]
fn cli_deny_passes_on_clean_tree() {
    let root = repo_root();
    let args = Args::parse(&[
        "lint".to_string(),
        "--deny".to_string(),
        "--root".to_string(),
        root.to_string_lossy().into_owned(),
    ]);
    mli::run_cli(args).expect("mli lint --deny on a clean tree");
}

#[test]
fn cli_deny_fails_on_planted_violation() {
    // build a scratch crate layout with one deliberate D001 hit
    let dir = std::env::temp_dir().join(format!("mli-lint-deny-{}", std::process::id()));
    let engine = dir.join("src").join("engine");
    fs::create_dir_all(&engine).unwrap();
    fs::write(
        engine.join("planted.rs"),
        "pub fn merge() { let m = std::collections::HashMap::<u32, u32>::new(); drop(m); }\n",
    )
    .unwrap();

    let report = lint::run(&LintConfig::all(&dir)).expect("lint run");
    assert_eq!(report.diags.len(), 1, "{}", report.to_text());
    assert_eq!(report.diags[0].rule, "D001");
    assert_eq!(report.diags[0].file, "rust/src/engine/planted.rs");

    let args = Args::parse(&[
        "lint".to_string(),
        "--deny".to_string(),
        "--root".to_string(),
        dir.to_string_lossy().into_owned(),
    ]);
    let err = mli::run_cli(args).expect_err("--deny must fail on a violation");
    assert!(
        matches!(err, Error::Lint(_)),
        "expected Error::Lint, got: {err}"
    );

    // an allow annotation flips the same tree back to passing
    fs::write(
        engine.join("planted.rs"),
        "pub fn merge() {\n    // mli-lint: allow(D001) scratch fixture\n    \
         let m = std::collections::HashMap::<u32, u32>::new();\n    drop(m);\n}\n",
    )
    .unwrap();
    let report = lint::run(&LintConfig::all(&dir)).expect("lint run");
    assert!(report.clean(), "{}", report.to_text());
    assert_eq!(report.suppressed, 1);

    fs::remove_dir_all(&dir).ok();
}

#[test]
fn json_report_is_parseable_and_stable() {
    let cfg = LintConfig::all(repo_root());
    let a = lint::run(&cfg).expect("lint run");
    let b = lint::run(&cfg).expect("lint run");
    // deterministic: two runs serialize identically
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    let parsed = Json::parse(&a.to_json().to_string()).expect("valid JSON");
    assert_eq!(parsed.get("tool").unwrap().as_str().unwrap(), "mli-lint");
    assert_eq!(
        parsed.get("diagnostics").unwrap().as_arr().unwrap().len(),
        0
    );
    assert_eq!(
        parsed.get("files_scanned").unwrap().as_usize().unwrap(),
        a.files
    );
}

#[test]
fn rule_subset_and_unknown_rule_handling() {
    // a rule filter runs only the requested rule
    let cfg = LintConfig {
        root: repo_root(),
        rules: vec!["C001".to_string()],
    };
    let report = lint::run(&cfg).expect("lint run");
    assert!(report.clean(), "{}", report.to_text());

    // unknown rule id through the CLI is a config error, not a panic
    let args = Args::parse(&[
        "lint".to_string(),
        "--rule".to_string(),
        "Z999".to_string(),
    ]);
    let err = mli::run_cli(args).expect_err("unknown rule must be rejected");
    assert!(matches!(err, Error::Config(_)), "got: {err}");
}
