//! Integration: full training runs across systems — the cross-system
//! claims the paper makes, verified end to end at test scale.

use mli::algorithms::als::{AlsParams, ALS};
use mli::algorithms::logreg::{Backend, LogRegParams, LogisticRegression};
use mli::algorithms::Algorithm;
use mli::baselines::{graphlab, mahout, matlab, vw, SystemProfile};
use mli::data::netflix::{self, NetflixConfig};
use mli::data::dense_gen;
use mli::engine::EngineContext;
use mli::optim::{GdParams, SgdParams};

fn logreg_data(n: usize, d: usize, parts: usize) -> mli::mltable::MLNumericTable {
    let ctx = EngineContext::new();
    dense_gen::generate(&ctx, n, d, parts, 77).unwrap().table
}

/// Median simulated time over repeated runs: single-core wall-clock
/// measurements jitter heavily (XLA thread pool, allocator, page cache),
/// so ordering assertions use medians.
fn median_time(reps: usize, mut f: impl FnMut() -> f64) -> f64 {
    let times: Vec<f64> = (0..reps).map(|_| f()).collect();
    mli::util::median(&times)
}

#[test]
fn mli_vs_vw_same_quality_different_time() {
    if !mli::runtime::require_artifacts_or_skip("mli_vs_vw_same_quality_different_time") {
        return;
    }
    // compute-dominated scale (the paper's regime): per-partition XLA
    // epochs cost milliseconds, comm costs fractions of that. At tiny
    // compute the orderings legitimately invert (latency-dominated; see
    // the ablation_comm bench), so this test uses the bench artifact.
    let data = logreg_data(4 * 2048, 512, 4);
    let sgd = SgdParams {
        iters: 4,
        learning_rate: 0.03,
        track_loss: true,
        ..Default::default()
    };

    // MLI (once, for quality) then medians for timing
    let mli_profile = SystemProfile::mli();
    let cluster = mli_profile.cluster(4);
    let model = LogisticRegression::new(LogRegParams {
        sgd: sgd.clone(),
        backend: Backend::Xla,
    })
    .train(&data, &cluster)
    .unwrap();
    let mli_loss = *model.loss_history.last().unwrap();

    // VW: same math (weights identical up to topology-independent
    // averaging), so losses match; time differs via compute factor
    let run = vw::run_logreg(&data, 4, &sgd, Backend::Xla).unwrap();
    let vw_loss = run.quality.unwrap();
    assert!((mli_loss - vw_loss).abs() < 1e-6, "{mli_loss} vs {vw_loss}");

    let mli_time = median_time(3, || {
        let cluster = SystemProfile::mli().cluster(4);
        LogisticRegression::new(LogRegParams {
            sgd: sgd.clone(),
            backend: Backend::Xla,
        })
        .train(&data, &cluster)
        .unwrap();
        cluster.total_sim_seconds()
    });
    let vw_time = median_time(3, || {
        vw::run_logreg(&data, 4, &sgd, Backend::Xla)
            .unwrap()
            .sim_seconds
            .unwrap()
    });
    // VW's C++ factor makes it faster at this compute-dominated scale
    // (paper: "on average 35% faster"), but never 2x (paper: "never
    // twice as fast"). Allow measurement slack on the shared single core.
    assert!(
        vw_time < mli_time * 1.1,
        "vw {vw_time} vs mli {mli_time}"
    );
    assert!(mli_time / vw_time < 2.5, "vw more than ~2x faster");
}

#[test]
fn matlab_gd_competitive_small_but_oom_at_scale() {
    // small data: MATLAB completes and converges
    let data = logreg_data(256, 16, 2);
    let run = matlab::run_logreg(
        &data,
        &GdParams {
            iters: 10,
            track_loss: true,
            ..Default::default()
        },
        false,
        false,
    )
    .unwrap();
    assert!(run.sim_seconds.is_some());
    assert!(run.quality.unwrap() < 0.7);
    // the OOM boundary itself is asserted in baselines::matlab tests
}

#[test]
fn als_all_systems_comparable_error() {
    if !mli::runtime::require_artifacts_or_skip("als_all_systems_comparable_error") {
        return;
    }
    // the paper: "ALS methods from all systems achieved comparable error
    // rates at the end of 10 iterations"
    let data = netflix::generate(&NetflixConfig {
        users: 160,
        items: 48,
        rank: 4,
        mean_nnz_per_user: 8,
        max_nnz_per_user: 16,
        noise: 0.1,
        seed: 5,
        ..Default::default()
    });
    let params = AlsParams {
        rank: 6,
        iters: 5,
        lambda: 0.05,
        track_rmse: true,
        ..Default::default()
    };

    // MLI (xla)
    let profile = SystemProfile::mli();
    let cluster = profile.cluster(4);
    let mut p = params.clone();
    p.use_xla = true;
    let mli = ALS::new(p).train_ratings(&data, &cluster).unwrap();
    let mli_rmse = *mli.rmse_history.last().unwrap();

    let gl = graphlab::run_als(&data, 4, &params).unwrap();
    let mh = mahout::run_als(&data, 4, &params).unwrap();

    for (name, q) in [("graphlab", gl.quality.unwrap()), ("mahout", mh.quality.unwrap())] {
        assert!(
            (q - mli_rmse).abs() < 0.05,
            "{name} rmse {q} vs mli {mli_rmse}"
        );
    }

    // ordering of simulated walltime: graphlab < mli < mahout (fig 3b)
    let mli_t = cluster.total_sim_seconds();
    assert!(gl.sim_seconds.unwrap() < mli_t);
    assert!(mh.sim_seconds.unwrap() > mli_t);
}

#[test]
fn weak_scaling_time_grows_sublinearly_for_mli() {
    // weak scaling: data/machine fixed; ideal = flat. With the star
    // topology comm grows ~linearly in machines but stays a small
    // fraction at this model size -> relative walltime should stay < 3x
    // from 1 to 8 machines (paper fig 2c shows ~1.0-1.5x).
    let sgd = SgdParams {
        iters: 4,
        ..Default::default()
    };
    let mut times = Vec::new();
    for &m in &[1usize, 8] {
        // per-machine work must dominate the per-round comm (paper
        // regime): 4096 x 256 rust epochs cost ~ms
        let data = logreg_data(4096 * m, 256, m);
        let t = median_time(3, || {
            let cluster = SystemProfile::mli().cluster(m);
            LogisticRegression::new(LogRegParams {
                sgd: sgd.clone(),
                backend: Backend::Rust,
            })
            .train(&data, &cluster)
            .unwrap();
            cluster.total_sim_seconds()
        });
        times.push(t);
    }
    let rel = times[1] / times[0];
    assert!(rel < 3.0, "weak-scaling blowup: {rel}");
}

#[test]
fn strong_scaling_uses_more_machines_effectively() {
    if !mli::runtime::require_artifacts_or_skip("strong_scaling_uses_more_machines_effectively") {
        return;
    }
    // fixed data, more machines => less simulated time (until comm wins)
    let sgd = SgdParams {
        iters: 4,
        ..Default::default()
    };
    // 16 partitions fixed: at 1 machine that is 2 waves on 8 cores; at 4
    // machines 1 wave of 4 tasks/machine — XLA epochs (~ms) dominate the
    // ~1ms comm, so 4 machines must win (medians, see median_time).
    let data = logreg_data(16 * 2048, 512, 16);
    let mut times = Vec::new();
    for &m in &[1usize, 4] {
        let t = median_time(3, || {
            let cluster = SystemProfile::mli().cluster(m);
            LogisticRegression::new(LogRegParams {
                sgd: sgd.clone(),
                backend: Backend::Xla,
            })
            .train(&data, &cluster)
            .unwrap();
            cluster.total_sim_seconds()
        });
        times.push(t);
    }
    assert!(
        times[1] < times[0],
        "4 machines should beat 1: {times:?}"
    );
}
