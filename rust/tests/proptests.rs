//! Property-based tests (util::prop, the in-tree proptest surrogate) over
//! the coordinator's invariants: engine routing/partitioning, shuffle
//! key-locality, lineage-recovery idempotence, LocalMatrix algebra, the
//! cluster cost model, and SGD averaging.

use mli::cluster::{CommTopology, NetworkModel, SimCluster};
use mli::engine::EngineContext;
use mli::localmatrix::{linalg, CsrMatrix, DenseMatrix, LocalMatrix};
use mli::optim::average_weights;
use mli::util::prop::{check, close, ensure};
use mli::util::rng::Rng;

#[test]
fn prop_partitioning_preserves_multiset_and_order() {
    check("partitioning", 11, 60, 12, |rng, size| {
        let n = rng.below(50 * size + 1);
        let parts = 1 + rng.below(size.max(1) * 2);
        let data: Vec<i64> = (0..n).map(|_| rng.next_u64() as i64).collect();
        let ctx = EngineContext::new();
        let d = ctx.parallelize(data.clone(), parts);
        // collect reproduces the exact sequence
        ensure(d.collect().unwrap() == data, "collect != input")?;
        // partition sizes balanced within 1
        let sizes: Vec<usize> = (0..parts)
            .map(|p| d.partition(p).unwrap().len())
            .collect();
        let (mn, mx) = (
            sizes.iter().min().copied().unwrap(),
            sizes.iter().max().copied().unwrap(),
        );
        ensure(mx - mn <= 1, format!("unbalanced: {sizes:?}"))?;
        ensure(sizes.iter().sum::<usize>() == n, "size sum")
    });
}

#[test]
fn prop_shuffle_reduce_matches_hashmap() {
    check("reduce_by_key", 13, 40, 8, |rng, size| {
        let n = rng.below(100 * size + 1);
        let keys = 1 + rng.below(20);
        let data: Vec<(u64, u64)> = (0..n)
            .map(|_| (rng.next_u64() % keys as u64, rng.next_u64() % 100))
            .collect();
        let mut want = std::collections::HashMap::new();
        for (k, v) in &data {
            *want.entry(*k).or_insert(0u64) += v;
        }
        let ctx = EngineContext::new();
        let parts = 1 + rng.below(6);
        let got: std::collections::HashMap<u64, u64> = ctx
            .parallelize(data, parts)
            .reduce_by_key(|a, b| a + b)
            .collect()
            .unwrap()
            .into_iter()
            .collect();
        ensure(got == want, "reduce_by_key mismatch")
    });
}

#[test]
fn prop_lineage_recovery_is_idempotent() {
    check("recovery", 17, 30, 6, |rng, size| {
        let n = 20 * (size + 1);
        let parts = 1 + rng.below(size + 1);
        let data: Vec<i64> = (0..n as i64).collect();
        let ctx = EngineContext::new();
        let d = ctx.parallelize(data, parts).map(|x| x * 7 + 1).cache();
        d.materialize().unwrap();
        let want = d.collect().unwrap();
        // lose random partitions, possibly repeatedly
        for _ in 0..rng.below(2 * parts + 1) {
            d.invalidate_partition(rng.below(parts));
        }
        ensure(d.collect().unwrap() == want, "recovered data differs")
    });
}

#[test]
fn prop_csr_roundtrip_and_transpose_involution() {
    check("csr", 19, 40, 8, |rng, size| {
        let rows = 1 + rng.below(10 * size);
        let cols = 1 + rng.below(10 * size);
        let nnz = rng.below(rows * cols / 2 + 1);
        let triplets: Vec<(usize, usize, f64)> = (0..nnz)
            .map(|_| (rng.below(rows), rng.below(cols), rng.f64() + 0.1))
            .collect();
        let m = CsrMatrix::from_triplets(rows, cols, triplets).unwrap();
        // dense roundtrip
        ensure(
            CsrMatrix::from_dense(&m.to_dense()) == m,
            "dense roundtrip",
        )?;
        // transpose twice = identity
        ensure(m.transpose().transpose() == m, "transpose involution")?;
        // transpose preserves nnz and flips lookup
        let t = m.transpose();
        ensure(t.nnz() == m.nnz(), "nnz")?;
        for _ in 0..5.min(nnz) {
            let r = rng.below(rows);
            let c = rng.below(cols);
            ensure(m.get(r, c) == t.get(c, r), "lookup flip")?;
        }
        Ok(())
    });
}

#[test]
fn prop_solve_residual_small() {
    check("lu_solve", 23, 30, 8, |rng, size| {
        let n = 1 + rng.below(size + 2);
        let mut r = Rng::new(rng.next_u64());
        let a = DenseMatrix::randn(n, n, &mut r);
        // ensure well-conditioned-ish: add n*I
        let a = a.zip(&DenseMatrix::eye(n), |x, e| x + (n as f64) * e).unwrap();
        let x_true = DenseMatrix::randn(n, 1, &mut r);
        let b = a.matmul(&x_true).unwrap();
        let x = linalg::solve(&a, &b).unwrap();
        for i in 0..n {
            close(x.get(i, 0), x_true.get(i, 0), 1e-6)?;
        }
        Ok(())
    });
}

#[test]
fn prop_matrix_algebra_identities() {
    check("algebra", 29, 30, 6, |rng, size| {
        let mut r = Rng::new(rng.next_u64());
        let n = 1 + rng.below(size + 3);
        let m = 1 + rng.below(size + 3);
        let a = LocalMatrix::Dense(DenseMatrix::randn(n, m, &mut r));
        let b = LocalMatrix::Dense(DenseMatrix::randn(n, m, &mut r));
        // (A + B) - B = A
        let ab = a.try_add(&b).unwrap().try_sub(&b).unwrap();
        close(ab.frob_norm(), a.frob_norm(), 1e-9)?;
        // (A^T)^T = A
        ensure(a.transpose().transpose() == a, "transpose involution")?;
        // frobenius via dot
        close(a.dot(&a).unwrap(), a.frob_norm().powi(2), 1e-9)?;
        // composition shapes
        let v = a.on(&b).unwrap();
        ensure(v.dims() == (2 * n, m), "on dims")?;
        let h = a.then(&b).unwrap();
        ensure(h.dims() == (n, 2 * m), "then dims")
    });
}

#[test]
fn prop_topology_costs_sane() {
    check("topology", 31, 50, 10, |rng, _| {
        let net = NetworkModel::ec2_2013();
        let m = 2 + rng.below(63);
        let bytes = 1 + rng.next_u64() % 10_000_000;
        for topo in [
            CommTopology::StarGatherBroadcast,
            CommTopology::AllReduceTree,
            CommTopology::PeerToPeer,
        ] {
            let t = topo.allreduce_time(&net, m, bytes);
            ensure(t.is_finite() && t > 0.0, "non-positive cost")?;
            // monotone in machines and bytes
            ensure(
                topo.allreduce_time(&net, m + 1, bytes) >= t * 0.999,
                "not monotone in machines",
            )?;
            ensure(
                topo.allreduce_time(&net, m, bytes * 2) >= t,
                "not monotone in bytes",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_average_weights_convexity() {
    check("averaging", 37, 50, 8, |rng, size| {
        let d = 1 + rng.below(size + 4);
        let parts = 1 + rng.below(6);
        let locals: Vec<(Vec<f32>, f64)> = (0..parts)
            .map(|_| {
                (
                    (0..d).map(|_| rng.f32() * 2.0 - 1.0).collect(),
                    1.0 + rng.f64() * 9.0,
                )
            })
            .collect();
        let avg = average_weights(&locals);
        // average stays inside the coordinate-wise hull
        for j in 0..d {
            let lo = locals.iter().map(|(v, _)| v[j]).fold(f32::INFINITY, f32::min);
            let hi = locals
                .iter()
                .map(|(v, _)| v[j])
                .fold(f32::NEG_INFINITY, f32::max);
            ensure(
                avg[j] >= lo - 1e-5 && avg[j] <= hi + 1e-5,
                format!("avg[{j}]={} outside [{lo}, {hi}]", avg[j]),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_round_time_dominated_by_slowest_machine() {
    check("round_time", 41, 40, 8, |rng, _| {
        let machines = 1 + rng.below(16);
        let cluster = SimCluster::ec2(machines);
        cluster.begin_round();
        let mut max_t = 0.0f64;
        for m in 0..machines {
            let t = rng.f64();
            cluster.charge_compute(m, t);
            max_t = max_t.max(t);
        }
        let stats = cluster.end_round();
        let round = stats.round_time(&cluster.specs);
        // one task/machine: round == slowest machine's time
        close(round, max_t, 1e-9)
    });
}
