//! Integration: the AOT artifact contract — every artifact in the
//! manifest loads, compiles, and produces outputs matching its manifest
//! shape and the pure-rust reference math.

use mli::runtime::{require_artifacts_or_skip, Runtime, Tensor};
use mli::util::rng::Rng;

fn rt() -> Runtime {
    Runtime::new(Runtime::artifact_dir()).expect("artifacts present (run `make artifacts`)")
}

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    if shape.is_empty() {
        return Tensor::Scalar(rng.f32() * 0.1);
    }
    let n: usize = shape.iter().product();
    Tensor::F32(
        (0..n).map(|_| rng.normal_f32() * 0.1).collect(),
        shape.to_vec(),
    )
}

#[test]
fn every_artifact_loads_and_runs() {
    if !require_artifacts_or_skip("every_artifact_loads_and_runs") {
        return;
    }
    let rt = rt();
    let manifest = rt.manifest().clone();
    let mut rng = Rng::new(99);
    assert!(manifest.artifacts.len() >= 15, "expected a full artifact set");
    for spec in &manifest.artifacts {
        let inputs: Vec<Tensor> = spec
            .inputs
            .iter()
            .map(|t| rand_tensor(&mut rng, &t.shape))
            .collect();
        let outs = rt
            .execute(&spec.entry, &spec.variant, &inputs)
            .unwrap_or_else(|e| panic!("{} failed: {e}", spec.key()));
        assert_eq!(outs.len(), spec.outputs.len(), "{}", spec.key());
        for (o, os) in outs.iter().zip(&spec.outputs) {
            assert_eq!(o.len(), os.numel(), "{} output size", spec.key());
            assert!(
                o.iter().all(|x| x.is_finite()),
                "{} produced non-finite values",
                spec.key()
            );
        }
    }
}

#[test]
fn grad_matches_rust_reference() {
    if !require_artifacts_or_skip("grad_matches_rust_reference") {
        return;
    }
    let rt = rt();
    let mut rng = Rng::new(7);
    let (n, d) = (256, 64);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal_f32()).collect();
    let y: Vec<f32> = (0..n).map(|_| f32::from(rng.f64() > 0.5)).collect();
    let w: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.1).collect();
    let outs = rt
        .execute(
            "logreg_grad_batch",
            "small",
            &[
                Tensor::F32(x.clone(), vec![n, d]),
                Tensor::F32(y.clone(), vec![n]),
                Tensor::F32(w.clone(), vec![d]),
            ],
        )
        .unwrap();
    // rust reference
    let mut grad = vec![0.0f64; d];
    let mut loss = 0.0f64;
    for i in 0..n {
        let margin: f64 = (0..d).map(|j| (x[i * d + j] * w[j]) as f64).sum();
        let p = 1.0 / (1.0 + (-margin).exp());
        let r = p - y[i] as f64;
        loss += (1.0 + margin.exp()).ln() - y[i] as f64 * margin;
        for j in 0..d {
            grad[j] += r * x[i * d + j] as f64;
        }
    }
    for j in 0..d {
        assert!(
            (outs[0][j] as f64 - grad[j]).abs() < 1e-2,
            "grad[{j}]: {} vs {}",
            outs[0][j],
            grad[j]
        );
    }
    assert!((outs[1][0] as f64 - loss).abs() < 0.05 * loss.abs().max(1.0));
}

#[test]
fn executable_cache_compiles_once() {
    if !require_artifacts_or_skip("executable_cache_compiles_once") {
        return;
    }
    let rt = rt();
    let x = Tensor::F32(vec![0.0; 256 * 64], vec![256, 64]);
    let w = Tensor::F32(vec![0.0; 64], vec![64]);
    assert_eq!(rt.cached_executables(), 0);
    let _ = rt.execute("logreg_predict", "small", &[x.clone(), w.clone()]).unwrap();
    assert_eq!(rt.cached_executables(), 1);
    let _ = rt.execute("logreg_predict", "small", &[x, w]).unwrap();
    assert_eq!(rt.cached_executables(), 1, "recompiled instead of cache hit");
}

#[test]
fn shape_mismatch_rejected_before_xla() {
    if !require_artifacts_or_skip("shape_mismatch_rejected_before_xla") {
        return;
    }
    let rt = rt();
    let bad = Tensor::F32(vec![0.0; 10], vec![10]);
    let err = rt
        .execute("logreg_predict", "small", &[bad.clone(), bad])
        .unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
    let err = rt.execute("logreg_predict", "small", &[]).unwrap_err();
    assert!(err.to_string().contains("inputs"), "{err}");
    assert!(rt.execute("nope", "small", &[]).is_err());
}

#[test]
fn scan_epoch_equals_manual_minibatch_sgd() {
    if !require_artifacts_or_skip("scan_epoch_equals_manual_minibatch_sgd") {
        return;
    }
    // local_sgd_epoch (scan+pallas) == sequential rust minibatch SGD
    let rt = rt();
    let mut rng = Rng::new(3);
    let (n, d, block) = (256usize, 64usize, 64usize);
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal_f32() * 0.5).collect();
    let y: Vec<f32> = (0..n).map(|_| f32::from(rng.f64() > 0.5)).collect();
    let w0: Vec<f32> = vec![0.0; d];
    let lr = 0.05f32;
    let outs = rt
        .execute(
            "local_sgd_epoch",
            "small",
            &[
                Tensor::F32(x.clone(), vec![n, d]),
                Tensor::F32(y.clone(), vec![n]),
                Tensor::F32(w0.clone(), vec![d]),
                Tensor::Scalar(lr),
            ],
        )
        .unwrap();
    // rust reference: sequential minibatches of `block`
    let mut w: Vec<f64> = w0.iter().map(|&v| v as f64).collect();
    let mut s = 0;
    while s < n {
        let e = (s + block).min(n);
        let mut g = vec![0.0f64; d];
        for i in s..e {
            let margin: f64 = (0..d).map(|j| x[i * d + j] as f64 * w[j]).sum();
            let r = 1.0 / (1.0 + (-margin).exp()) - y[i] as f64;
            for j in 0..d {
                g[j] += r * x[i * d + j] as f64;
            }
        }
        for j in 0..d {
            w[j] -= lr as f64 * g[j];
        }
        s = e;
    }
    for j in 0..d {
        assert!(
            (outs[0][j] as f64 - w[j]).abs() < 5e-3,
            "w[{j}]: {} vs {}",
            outs[0][j],
            w[j]
        );
    }
}
