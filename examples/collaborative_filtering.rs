//! Collaborative filtering with ALS (paper §IV-B): factor a
//! Netflix-shaped ratings matrix on the simulated cluster, XLA-backed
//! normal-equation assembly, and produce recommendations.
//!
//! Run: `cargo run --release --example collaborative_filtering`

use mli::algorithms::als::{AlsParams, ALS};
use mli::cluster::SimCluster;
use mli::data::netflix::{self, NetflixConfig};

fn main() -> mli::Result<()> {
    let data = netflix::generate(&NetflixConfig {
        users: 512,
        items: 96,
        rank: 8,
        mean_nnz_per_user: 14,
        max_nnz_per_user: 25,
        noise: 0.15,
        seed: 23,
    });
    println!(
        "ratings: {} users x {} items, {} observed ({}% dense)",
        data.users,
        data.items,
        data.ratings.nnz(),
        100 * data.ratings.nnz() / (data.users * data.items)
    );

    let cluster = SimCluster::ec2(4);
    let model = ALS::new(AlsParams {
        rank: 10,
        iters: 10,   // the paper's setting
        lambda: 0.01,
        use_xla: true,
        track_rmse: true,
        ..Default::default()
    })
    .train_ratings(&data, &cluster)?;

    println!("train RMSE per iteration: {:?}", model.rmse_history);
    println!(
        "simulated walltime {:.3}s (comm {:.3}s over {} rounds)",
        cluster.total_sim_seconds(),
        cluster.total_comm_seconds(),
        cluster.rounds()
    );

    // top-3 recommendations for user 0 among unrated items
    let rated: std::collections::HashSet<usize> =
        data.ratings.row_iter(0).map(|(i, _)| i).collect();
    let mut scored: Vec<(usize, f64)> = (0..data.items)
        .filter(|i| !rated.contains(i))
        .map(|i| (i, model.predict_rating(0, i)))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("user 0 top-3 recommendations: {:?}", &scored[..3]);

    let final_rmse = *model.rmse_history.last().unwrap();
    assert!(final_rmse < 0.5, "RMSE too high: {final_rmse}");
    println!("collaborative_filtering OK");
    Ok(())
}
