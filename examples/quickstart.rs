//! Quickstart: the MLI workflow end to end on a small CSV —
//! load semi-structured data, featurize, train logistic regression on the
//! simulated cluster (XLA-compiled hot path), and predict.
//!
//! Run: `cargo run --release --example quickstart`

use mli::algorithms::logreg::{Backend, LogRegParams, LogisticRegression};
use mli::algorithms::{Algorithm, Model};
use mli::cluster::SimCluster;
use mli::engine::EngineContext;
use mli::features::standard_scale;
use mli::mltable::csv_from_str;
use mli::optim::SgdParams;
use mli::util::rng::Rng;

fn main() -> mli::Result<()> {
    // 1. "Load" a CSV (here: synthesized in-memory; swap for
    //    csv_from_file on real data). Schema: label, then 8 features.
    let mut rng = Rng::new(7);
    let mut csv = String::from("label,f0,f1,f2,f3,f4,f5,f6,f7\n");
    for _ in 0..512 {
        let x: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        let margin = 2.0 * x[0] - 1.5 * x[3] + 0.5 * x[7];
        let y = i32::from(rng.f64() < 1.0 / (1.0 + (-margin).exp()));
        csv.push_str(&format!(
            "{y},{}\n",
            x.iter().map(|v| format!("{v:.4}")).collect::<Vec<_>>().join(",")
        ));
    }

    let ctx = EngineContext::new();
    let table = csv_from_str(&ctx, &csv, true, 4)?;
    println!(
        "loaded MLTable: {} rows x {} cols over {} partitions",
        table.num_rows()?,
        table.num_cols(),
        table.num_partitions()
    );

    // 2. featurize: standardize the feature columns (label col skipped)
    let numeric = standard_scale(&table.to_numeric()?, 1)?;

    // 3. train on a simulated 4-machine cluster; local SGD epochs run as
    //    AOT-compiled XLA programs via PJRT (python never runs here)
    let cluster = SimCluster::ec2(4);
    let algo = LogisticRegression::new(LogRegParams {
        sgd: SgdParams {
            learning_rate: 0.05,
            iters: 15,
            track_loss: true,
            ..Default::default()
        },
        backend: Backend::Xla,
    });
    let model = algo.train(&numeric, &cluster)?;

    println!("loss curve: {:?}", model.loss_history);
    println!(
        "simulated walltime: {:.3}s (compute measured, network modelled)",
        model.sim_seconds
    );

    // 4. predict + report training accuracy
    let rows = numeric.table().collect()?;
    let mut correct = 0;
    for r in &rows {
        let v = r.to_vector()?;
        let p = model.predict(&v.slice(1, v.len()))?;
        if (p > 0.5) == (v[0] > 0.5) {
            correct += 1;
        }
    }
    println!(
        "training accuracy: {:.1}% ({} / {})",
        100.0 * correct as f64 / rows.len() as f64,
        correct,
        rows.len()
    );
    assert!(correct as f64 / rows.len() as f64 > 0.7);
    println!("quickstart OK");
    Ok(())
}
