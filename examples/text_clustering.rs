//! The Fig. A2 pipeline: load a text corpus, extract top bigram features,
//! weight with tf-idf, and cluster with K-means —
//!
//! ```scala
//! val featurizedTable = tfIdf(nGrams(rawTextTable, n=2, top=30000))
//! val kMeansModel = KMeans(featurizedTable, k=50)
//! ```
//!
//! Run: `cargo run --release --example text_clustering`

use mli::algorithms::kmeans::{KMeans, KMeansParams};
use mli::algorithms::{Algorithm, Model};
use mli::cluster::SimCluster;
use mli::data::text_gen::{self, CorpusConfig};
use mli::engine::EngineContext;
use mli::features::{ngrams, tfidf};

fn main() -> mli::Result<()> {
    let ctx = EngineContext::new();
    let cfg = CorpusConfig {
        docs: 240,
        topics: 4,
        vocab: 600,
        words_per_doc: 60,
        seed: 11,
    };
    let (raw_text, truth) = text_gen::generate_table(&ctx, &cfg, 4)?;
    println!("corpus: {} documents, {} latent topics", cfg.docs, cfg.topics);

    // nGrams(raw, n=1, top=512): unigrams keep the demo small; bump n=2
    // for the paper's exact bigram setting.
    let grams = ngrams(&raw_text, 0, 1, 512)?;
    println!("vocabulary: {} n-grams", grams.vocab.len());

    let feats = tfidf(&grams.table)?;
    println!(
        "featurized: {} x {} tf-idf matrix",
        feats.num_rows()?,
        feats.num_cols()
    );

    let cluster = SimCluster::ec2(4);
    let model = KMeans::new(KMeansParams {
        k: cfg.topics,
        iters: 12,
        seed: 3,
        use_xla: false, // feature dim is data-dependent; rust lloyd here
        ..Default::default()
    })
    .train(&feats, &cluster)?;
    println!("SSE per iteration: {:?}", model.sse_history);

    // purity against the generator's ground truth
    let assignments: Vec<usize> = feats
        .collect_vectors()?
        .iter()
        .map(|v| model.predict(v).map(|c| c as usize))
        .collect::<mli::Result<_>>()?;
    let k = cfg.topics;
    let mut counts = vec![vec![0usize; k]; k];
    for (a, &t) in assignments.iter().zip(&truth) {
        counts[*a][t] += 1;
    }
    let purity: usize = counts.iter().map(|row| row.iter().max().unwrap()).sum();
    let purity = purity as f64 / truth.len() as f64;
    println!("cluster purity vs ground truth: {purity:.2}");
    assert!(purity > 0.6, "pipeline failed to recover topics");
    println!("text_clustering OK");
    Ok(())
}
