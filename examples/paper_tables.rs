//! Regenerate the paper's tables/figures at a reduced scale (fast
//! preview of what `cargo bench` produces at full scale) and print them
//! as markdown. Writes results/*.md + *.csv.
//!
//! Run: `cargo run --release --example paper_tables`

use mli::algorithms::logreg::Backend;
use mli::bench_harness::{
    als_scaling, logreg_scaling, AlsBenchConfig, LogregBenchConfig, ScalingMode,
};
use mli::bench_harness::loc;
use mli::data::netflix::NetflixConfig;

fn main() -> mli::Result<()> {
    // Fig 2a / 3a: lines of code
    let t2a = loc::fig2a();
    println!("{}", t2a.to_markdown());
    t2a.save("fig2a_loc")?;
    let t3a = loc::fig3a();
    println!("{}", t3a.to_markdown());
    t3a.save("fig3a_loc")?;

    // Fig 2b/2c preview (reduced scale; benches run the full version)
    let cfg = LogregBenchConfig {
        machines: vec![1, 2, 4, 8],
        rows: 512,
        d: 64,
        iters: 5,
        backend: Backend::Xla,
        seed: 42,
        reps: 1,
        threads: 0,
    };
    let t = logreg_scaling(&cfg, ScalingMode::Weak)?;
    println!("{}", t.to_markdown());
    t.save("fig2bc_preview")?;

    // Fig 3b/3c preview
    let acfg = AlsBenchConfig {
        machines: vec![1, 4, 9],
        base: NetflixConfig {
            users: 512,
            items: 48,
            mean_nnz_per_user: 8,
            max_nnz_per_user: 20,
            ..Default::default()
        },
        iters: 3,
        use_xla: true,
        reps: 1,
        ..Default::default()
    };
    let t = als_scaling(&acfg, ScalingMode::Weak)?;
    println!("{}", t.to_markdown());
    t.save("fig3bc_preview")?;

    println!("paper_tables OK (full-scale versions: `cargo bench`)");
    Ok(())
}
