//! Fault tolerance demo: the paper's §IV motivation for building on
//! Spark — "automatic recovery from node failure is a necessity" — shown
//! live: a training job loses cached partitions (and suffers transient
//! task failures) mid-run and recovers through lineage, producing
//! *exactly* the same model as the failure-free run.
//!
//! Run: `cargo run --release --example fault_tolerance`

use mli::algorithms::logreg::{Backend, LogRegParams, LogisticRegression};
use mli::algorithms::Algorithm;
use mli::cluster::SimCluster;
use mli::data::dense_gen;
use mli::engine::EngineContext;
use mli::optim::SgdParams;

fn main() -> mli::Result<()> {
    let params = LogRegParams {
        sgd: SgdParams {
            learning_rate: 0.05,
            iters: 8,
            track_loss: true,
            ..Default::default()
        },
        backend: Backend::Rust,
    };

    // run 1: failure-free
    let ctx1 = EngineContext::new();
    let clean = dense_gen::generate(&ctx1, 512, 32, 4, 99)?;
    let m_clean = LogisticRegression::new(params.clone())
        .train(&clean.table, &SimCluster::ec2(4))?;

    // run 2: same data/seed, but we lose cached partitions mid-run and
    // inject transient task failures (retried by the scheduler)
    let ctx2 = EngineContext::new();
    let hostile = dense_gen::generate(&ctx2, 512, 32, 4, 99)?;
    // materialize the cached partitions (as a long-running job would
    // have), so that invalidation below models losing *live* state
    let ds = hostile.table.dataset();
    ds.materialize()?;
    // transient task failures on the underlying dataset (budget < the
    // scheduler's 4 attempts, so training proceeds after retries)
    ctx2.failures.fail_times(ds.id(), 1, 2);
    ctx2.failures.fail_times(ds.id(), 3, 1);
    // simulate executor loss: drop cached partitions, forcing lineage
    // recomputation on next access
    ds.invalidate_partition(0);
    ds.invalidate_partition(2);
    let m_hostile = LogisticRegression::new(params)
        .train(&hostile.table, &SimCluster::ec2(4))?;

    let (_, _, recoveries) = ctx2.stats();
    println!("clean   final loss: {:.6}", m_clean.loss_history.last().unwrap());
    println!("hostile final loss: {:.6}", m_hostile.loss_history.last().unwrap());
    println!("lineage recoveries during hostile run: {recoveries}");

    // identical models bit for bit: recovery is exact, not approximate
    let mut max_diff = 0.0f64;
    for j in 0..m_clean.weights.len() {
        max_diff = max_diff.max((m_clean.weights[j] - m_hostile.weights[j]).abs());
    }
    println!("max weight divergence: {max_diff:e}");
    assert_eq!(max_diff, 0.0, "recovery must be exact");
    assert!(recoveries >= 2, "expected lineage recoveries to be exercised");
    println!("fault_tolerance OK — failures were invisible to the algorithm");
    Ok(())
}
