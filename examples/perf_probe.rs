//! Perf probe for the §Perf pass (EXPERIMENTS.md): times every
//! `local_sgd_epoch` artifact of a given shape through the PJRT runtime.
//! Point MLI_ARTIFACTS at an experimental artifact dir to compare
//! alternative lowerings (block sizes, pallas-vs-jnp).
//!
//! Run: `cargo run --release --example perf_probe`

use mli::runtime::{Runtime, Tensor};
use mli::util::{median, timer};

fn main() -> mli::Result<()> {
    let rt = Runtime::new(Runtime::artifact_dir())?;
    let (n, d) = (2048usize, 512usize);
    let x = Tensor::F32(vec![0.1; n * d], vec![n, d]);
    let y = Tensor::F32(vec![0.0; n], vec![n]);
    let w = Tensor::F32(vec![0.0; d], vec![d]);
    let lr = Tensor::Scalar(0.01);
    let mut variants: Vec<_> = rt.manifest().clone().artifacts;
    variants.retain(|a| a.entry == "local_sgd_epoch" && a.inputs[0].shape == vec![n, d]);
    for a in &variants {
        let args = [x.clone(), y.clone(), w.clone(), lr.clone()];
        let _ = rt.execute(&a.entry, &a.variant, &args)?;
        let s = timer::sample(1, 8, || rt.execute(&a.entry, &a.variant, &args).unwrap());
        let ms = median(&s) * 1e3;
        let gflops = 4.0 * (n * d) as f64 / (ms / 1e3) / 1e9;
        println!(
            "{:<28} block={:<5} {:>8.2} ms  {:>6.2} GFLOP/s",
            a.variant,
            a.block.map(|b| b.to_string()).unwrap_or_else(|| "?".into()),
            ms,
            gflops
        );
    }
    Ok(())
}
