//! END-TO-END DRIVER (DESIGN.md §5 `e2e`): the full system on a real
//! training workload, proving all layers compose —
//!
//!   synthetic ImageNet-surrogate (data/) -> MLTable partitions (mltable/
//!   + engine/) -> distributed local-SGD (optim/) whose per-partition
//!   epochs execute AOT-compiled XLA programs containing the Pallas
//!   gradient kernel (runtime/ + artifacts/) -> parameter averaging on a
//!   simulated 8-machine cluster with modelled communication (cluster/)
//!   -> loss curve logged and written to results/e2e_loss.csv.
//!
//! The workload mirrors the paper's §IV-A at sandbox scale: d=2048 dense
//! features (paper: 160K), 8192 examples over 8 machines, 200 SGD rounds.
//!
//! Run: `cargo run --release --example e2e_train` (~2 min). Recorded in
//! EXPERIMENTS.md §e2e.

use std::io::Write as _;
use std::sync::Arc;

use mli::algorithms::glm::{GlmData, XlaLogregStep};
use mli::baselines::SystemProfile;
use mli::data::dense_gen;
use mli::engine::EngineContext;
use mli::optim::{SgdParams, SGD};
use mli::runtime::Runtime;

fn main() -> mli::Result<()> {
    const MACHINES: usize = 8;
    const N: usize = 8192;
    const D: usize = 2048;
    const ROUNDS: usize = 200;

    println!("=== MLI end-to-end training driver ===");
    println!("workload: logistic regression, n={N}, d={D}, {MACHINES} machines, {ROUNDS} rounds");

    // L3 data plane: generate + partition (one partition per machine)
    let ctx = EngineContext::new();
    let t0 = std::time::Instant::now();
    let data = dense_gen::generate(&ctx, N, D, MACHINES, 20260710)?;
    println!("data generated in {:.1}s", t0.elapsed().as_secs_f64());

    // XLA hot path: the 'wide' artifact (1024 x 2048) fits 8192/8 = 1024
    // rows per partition exactly
    let rt = Runtime::global()?;
    let (variant, n_pad, d_pad) = XlaLogregStep::pick_variant(&rt, N / MACHINES, D)?;
    println!("artifact: local_sgd_epoch__{variant} ({n_pad} x {d_pad})");
    let glm = Arc::new(GlmData::prepare(&data.table, n_pad, d_pad, 128)?);
    let step = XlaLogregStep::new(glm, rt.clone(), &variant)?;

    // simulated cluster + optimizer
    let profile = SystemProfile::mli();
    let cluster = profile.cluster(MACHINES);
    let params = SgdParams {
        learning_rate: 0.01,
        decay: 0.05,
        iters: ROUNDS,
        track_loss: true,
        loss_every: 5,
        topology: profile.topology,
        ..Default::default()
    };
    let wall = std::time::Instant::now();
    let res = SGD::run(&step, &cluster, &params)?;
    let wall = wall.elapsed().as_secs_f64();

    // report
    println!("\nloss curve (every 5 rounds):");
    for (i, l) in res.loss_history.iter().enumerate() {
        if i % 4 == 0 || i + 1 == res.loss_history.len() {
            println!("  round {:>4}  loss {:.6}", i * 5, l);
        }
    }
    let first = res.loss_history.first().unwrap();
    let last = res.loss_history.last().unwrap();
    println!("\nhost walltime:        {wall:.1}s");
    println!("simulated walltime:   {:.2}s", res.sim_seconds);
    println!(
        "  of which comm:      {:.2}s over {} rounds",
        cluster.total_comm_seconds(),
        cluster.rounds()
    );
    println!(
        "network bytes moved:  {}",
        mli::util::human_bytes(cluster.total_net_bytes())
    );
    println!(
        "XLA executions:       {}",
        rt.exec_count
            .lock()
            .unwrap()
            .values()
            .sum::<u64>()
    );
    println!("loss: {first:.4} -> {last:.4}");

    // persist the loss curve for EXPERIMENTS.md
    std::fs::create_dir_all("results")?;
    let mut f = std::fs::File::create("results/e2e_loss.csv")?;
    writeln!(f, "round,loss")?;
    for (i, l) in res.loss_history.iter().enumerate() {
        writeln!(f, "{},{}", i * 5, l)?;
    }
    println!("wrote results/e2e_loss.csv");

    assert!(last < first, "training must reduce loss");
    assert!(last < &0.45, "final loss too high: {last}");
    println!("e2e_train OK");
    Ok(())
}
